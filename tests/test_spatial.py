"""Spatial (patch + halo) partitioning mode: parity against the eager oracle
and the unsplit model, fused-block semantics, memory/comm accounting.

The parity contract mirrors the compiled-executor suite: float to 1e-5, int8
bit-for-bit (same int32 accumulation + multiply-only epilogue on every path).
Deterministic parametrized tests cover the grid directly; the hypothesis
properties sweep strides, padding, halo widths, and worker mixes more widely
(they skip cleanly when hypothesis is not installed — see conftest).
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (CompiledSplitExecutor, SplitExecutor, WorkerParams,
                        band_heights, calibrate_scales, comm_volume,
                        compare_modes, group_blocks, plan_memory,
                        quantize_model, reference_forward, split_model,
                        trace_sequential)
from repro.core.reinterpret import ReinterpretedModel
from repro.core.splitting import SpatialShard, spatial_band_geometry
from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke

RATINGS = ([1.0], [1, 1, 1], list(np.ones(8)), [3, 1, 2, 0.5], [1, 0, 1])


def _acts_fn(model, x):
    return reference_forward(model, x, collect_activations=True)[1]


def _quantized(model, rng, shape, n_calib=3):
    calib = [rng.standard_normal(shape).astype(np.float32)
             for _ in range(n_calib)]
    scales = calibrate_scales(model, calib, _acts_fn)
    return quantize_model(model, scales)


def _conv_net(kernel, stride, padding, hw, cin=3, cout=5, depthwise=False,
              seed=0):
    """Single conv/dwconv + pointwise tail (the tail makes dwconv nets a
    fusable dw->pw block, exercising fused halo execution)."""
    spec = [dict(kind="dwconv" if depthwise else "conv",
                 kernel=(kernel, kernel), stride=(stride, stride),
                 padding=(padding, padding), activation="relu6",
                 **({} if depthwise else {"out_channels": cout})),
            dict(kind="conv", out_channels=4, kernel=(1, 1), stride=(1, 1),
                 padding=(0, 0))]
    return trace_sequential(spec, (cin, hw, hw),
                            rng=np.random.default_rng(seed))


class TestStructure:
    def test_group_blocks_mobilenet(self):
        m = mobilenet_v2_smoke()
        blocks = [b.indices for b in group_blocks(m)]
        # stem singleton, then dw+pw (t=1 block), then expand+dw+project
        assert blocks[0] == (0,)
        assert blocks[1] == (1, 2)
        assert all(len(b) == 3 for b in blocks[2:6])
        # head conv, avgpool, linear stay singletons
        assert blocks[-3:] == [(15,), (16,), (17,)]
        # interior layers never carry residual bookkeeping
        for b in blocks:
            for i in b[:-1]:
                assert m.layers[i].save_as is None
                assert m.layers[i].residual_from is None

    def test_bands_partition_output_rows(self):
        m = mobilenet_v2_smoke()
        for ratings in RATINGS:
            plan = split_model(m, ratings, mode="spatial")
            for idxs in plan.block_groups:
                split = plan.splits[idxs[-1]]
                if split.mode != "spatial":
                    continue
                h_out = split.layer.out_shape[1]
                rows = []
                for sh in split.shards:
                    assert isinstance(sh, SpatialShard)
                    rows.extend(range(sh.row_lo, sh.row_hi))
                # block-output bands tile [0, h_out) exactly, in order
                assert rows == list(range(h_out))

    def test_band_heights_proportional(self):
        h = band_heights(np.array([3.0, 1.0]), 100)
        assert h.sum() == 100 and h[0] == 75
        assert band_heights(np.array([1, 0, 1]), 9).sum() == 9

    def test_interior_band_includes_halo(self):
        """A fused dwconv stage's input window must exceed its stride-mapped
        band interior (the halo rows), and the geometry pads must close the
        receptive-field window exactly."""
        m = mobilenet_v2_smoke()
        plan = split_model(m, [1, 1, 1], mode="spatial")
        checked = 0
        for idxs in plan.block_groups:
            for i in idxs:
                split = plan.splits[i]
                layer = split.layer
                if split.mode != "spatial" or layer.kind != "dwconv":
                    continue
                for g in spatial_band_geometry(layer, split):
                    if g is None:
                        continue
                    kh = layer.kernel[0]
                    sh = layer.stride[0]
                    win = (g.n_rows - 1) * sh + kh
                    assert (g.pad_top + (g.in_hi - g.in_lo)
                            + g.pad_bot) == win
                    checked += 1
        assert checked > 0

    def test_spatial_weight_replication(self):
        m = mobilenet_v2_smoke()
        plan = split_model(m, [1, 1, 1, 1], mode="spatial")
        for split in plan.splits:
            if split.mode != "spatial":
                continue
            full = split.layer.weight_bytes(1) + split.layer.out_shape[0]
            for sh in split.shards:
                assert sh.weight_bytes in (0, full)

    def test_collect_activations_rejected(self, rng):
        m = mobilenet_v2_smoke()
        plan = split_model(m, [1, 1], mode="spatial")
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        with pytest.raises(ValueError, match="spatial"):
            SplitExecutor(plan).run(x, collect_activations=True)


class TestFloatParity:
    def test_smoke_eager_matches_reference(self, rng):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        ref = reference_forward(m, x)
        for ratings in RATINGS:
            plan = split_model(m, ratings, mode="spatial")
            out = SplitExecutor(plan).run(x)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_smoke_compiled_matches_reference(self, rng):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        ref = reference_forward(m, x)
        for ratings in ([1, 1, 1], [3, 1, 2, 0.5]):
            plan = split_model(m, ratings, mode="spatial")
            out = CompiledSplitExecutor(plan).run(x)
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("kernel,stride,padding", [
        (1, 1, 0), (1, 2, 0), (3, 1, 1), (3, 2, 1), (3, 3, 1), (3, 1, 0),
        (5, 1, 2), (5, 2, 2), (5, 2, 0), (3, 2, 2),
    ])
    @pytest.mark.parametrize("depthwise", [False, True])
    def test_conv_grid(self, rng, kernel, stride, padding, depthwise):
        """Strides x paddings x halo widths, dense + depthwise."""
        m = _conv_net(kernel, stride, padding, hw=13, depthwise=depthwise)
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        ref = reference_forward(m, x)
        for ratings in ([1.0], [2, 1, 1], list(np.ones(8))):
            plan = split_model(m, ratings, mode="spatial")
            out = SplitExecutor(plan).run(x)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


class TestInt8Parity:
    def test_smoke_bit_exact_vs_oracle(self, rng):
        """Spatial int8 must agree bit-for-bit with the single-worker eager
        oracle (the unsplit int8 model) on every path: eager, compiled-jnp,
        compiled-Pallas, batched."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
        for ratings in ([1, 1, 1], list(np.ones(8)), [3, 1, 2, 0.5]):
            plan = split_model(m, ratings, mode="spatial")
            eager = SplitExecutor(plan, qm).run(x, mode="int8")
            np.testing.assert_array_equal(eager, oracle)
            compiled = CompiledSplitExecutor(plan, qm)
            np.testing.assert_array_equal(compiled.run(x, mode="int8"),
                                          oracle)

    def test_smoke_pallas_bit_exact(self, rng):
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        plan = split_model(m, [3, 1, 2, 0.5], mode="spatial")
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        out = CompiledSplitExecutor(plan, qm, use_pallas=True,
                                    interpret=True).run(x, mode="int8")
        np.testing.assert_array_equal(out, eager)

    def test_batch_bit_exact(self, rng):
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        plan = split_model(m, [2, 1, 1], mode="spatial")
        xs = np.stack([rng.standard_normal((3, 32, 32)).astype(np.float32)
                       for _ in range(4)])
        eager = SplitExecutor(plan, qm)
        outs = CompiledSplitExecutor(plan, qm).run_batch(xs, mode="int8")
        for i in range(4):
            np.testing.assert_array_equal(outs[i],
                                          eager.run(xs[i], mode="int8"))


class TestMemoryAndComm:
    def test_first_five_mnv2_blocks_peak_below_channel_modes(self):
        """Acceptance: at 8 workers, spatial max per-worker peak RAM beats
        both channel-axis modes on the first five MobileNetV2 blocks (the
        early high-resolution stages where routed inputs dominate)."""
        full = mobilenet_v2_paper()
        blocks = group_blocks(full)
        end = blocks[5].last + 1          # stem + inverted residuals b0..b4
        sub = ReinterpretedModel(layers=full.layers[:end],
                                 input_shape=full.input_shape)
        r8 = np.ones(8)
        peaks = {}
        for mode in ("neuron", "kernel", "spatial"):
            mems = plan_memory(split_model(sub, r8, mode=mode))
            peaks[mode] = max(m.per_worker_peak.max() for m in mems)
        assert peaks["spatial"] < peaks["neuron"]
        assert peaks["spatial"] < peaks["kernel"]

    def test_fused_interior_layers_move_no_bytes(self):
        m = mobilenet_v2_smoke()
        plan = split_model(m, np.ones(4), mode="spatial")
        prev = None
        for idxs in plan.block_groups:
            for i in idxs:
                split = plan.splits[i]
                vol = comm_volume(prev, split.layer, split)
                if split.mode == "spatial" and not split.block_first:
                    assert vol.download_bytes.sum() == 0
                if prev is not None and not prev.block_last:
                    assert vol.upload_bytes.sum() == 0
                prev = split

    def test_spatial_cuts_total_traffic_on_smoke(self):
        m = mobilenet_v2_smoke()
        total = {}
        for mode in ("neuron", "spatial"):
            plan = split_model(m, np.ones(4), mode=mode)
            prev, t = None, 0
            for split in plan.splits:
                t += comm_volume(prev, split.layer, split).total_bytes
                prev = split
            total[mode] = t
        assert total["spatial"] < total["neuron"]

    def test_compare_modes_reports(self):
        m = mobilenet_v2_smoke()
        workers = [WorkerParams(f_mhz=f) for f in (600, 450, 150)]
        reports = compare_modes(m, workers)
        assert set(reports) == {"neuron", "kernel", "spatial"}
        for rep in reports.values():
            assert rep.total_time_s > 0 and rep.max_peak_ram > 0
        assert reports["spatial"].total_bytes < reports["neuron"].total_bytes


# ---------------------------------------------------------------------------
# hypothesis property sweeps (skip when hypothesis is unavailable)
# ---------------------------------------------------------------------------

@st.composite
def conv_cases(draw):
    kernel = draw(st.sampled_from([1, 3, 5]))
    stride = draw(st.integers(1, 3))
    padding = draw(st.integers(0, max(kernel // 2, 1)))
    hw = draw(st.integers(7, 14))
    depthwise = draw(st.booleans())
    n_workers = draw(st.sampled_from([1, 3, 8]))
    ratings = draw(st.lists(st.integers(0, 4), min_size=n_workers,
                            max_size=n_workers).filter(lambda r: sum(r) > 0))
    return kernel, stride, padding, hw, depthwise, ratings


@given(conv_cases())
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_spatial_float_parity(case):
    """Spatial-mode float output matches the unsplit reference to 1e-5 across
    strides, padding, halo widths, and heterogeneous worker mixes."""
    kernel, stride, padding, hw, depthwise, ratings = case
    m = _conv_net(kernel, stride, padding, hw, depthwise=depthwise)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(m.input_shape).astype(np.float32)
    ref = reference_forward(m, x)
    out = SplitExecutor(split_model(m, ratings, mode="spatial")).run(x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@given(conv_cases())
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_spatial_int8_exact(case):
    """Spatial-mode int8 output is bit-identical to the single-worker eager
    oracle (integer accumulation + multiply-only epilogue on both paths)."""
    kernel, stride, padding, hw, depthwise, ratings = case
    m = _conv_net(kernel, stride, padding, hw, depthwise=depthwise)
    rng = np.random.default_rng(2)
    x = rng.standard_normal(m.input_shape).astype(np.float32)
    qm = _quantized(m, rng, m.input_shape, n_calib=2)
    oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
    out = SplitExecutor(split_model(m, ratings, mode="spatial"),
                        qm).run(x, mode="int8")
    np.testing.assert_array_equal(out, oracle)


@given(st.sampled_from([1, 3, 8]), st.integers(1, 2), st.integers(0, 3))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_fused_block_parity(n_workers, stride, seed):
    """A full inverted-residual stack (expand->dw->project with residual)
    executes fused per band and still matches the reference bit-for-bit in
    int8 and to 1e-5 in float."""
    rng = np.random.default_rng(seed)
    spec = [
        dict(kind="conv", out_channels=4, kernel=(3, 3), stride=(1, 1),
             padding=(1, 1), activation="relu6", save_as="blk"),
        dict(kind="conv", out_channels=12, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0), activation="relu6"),
        dict(kind="dwconv", kernel=(3, 3), stride=(stride, stride),
             padding=(1, 1), activation="relu6"),
        dict(kind="conv", out_channels=4, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0),
             residual_from="blk" if stride == 1 else None),
    ]
    m = trace_sequential(spec, (3, 12, 12), rng=rng)
    x = rng.standard_normal((3, 12, 12)).astype(np.float32)
    ref = reference_forward(m, x)
    ratings = list(range(1, n_workers + 1))
    plan = split_model(m, ratings, mode="spatial")
    np.testing.assert_allclose(SplitExecutor(plan).run(x), ref,
                               rtol=1e-5, atol=1e-5)
    qm = _quantized(m, rng, (3, 12, 12), n_calib=2)
    oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
    np.testing.assert_array_equal(
        SplitExecutor(plan, qm).run(x, mode="int8"), oracle)
