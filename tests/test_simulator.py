"""Simulator + memory-model tests: Eq. 1 timing, paper-trend assertions
(Table II orderings, Figs 8-12 qualitative claims)."""
import numpy as np

from repro.core.allocation import WorkerParams, ratings_evenly, ratings_for, ratings_freq_only
from repro.core.memory import (layerwise_peak, peak_ram_per_worker,
                               single_device_peak)
from repro.core.simulator import SimConfig, measured_kc, simulate, simulated_k1
from repro.core.splitting import split_model
from repro.models import mobilenet_v2_smoke
from conftest import small_cnn


def test_k1_rises_as_clock_drops():
    """Table I: K1(150MHz) > K1(450) > K1(600) — memory-bound fraction grows."""
    m = mobilenet_v2_smoke()
    k600 = simulated_k1(m, 600)
    k450 = simulated_k1(m, 450)
    k150 = simulated_k1(m, 150)
    assert k150 > k450 > k600
    # paper ratio K1(150)/K1(600) ~ 0.211/0.133 ~ 1.59
    assert 1.2 < k150 / k600 < 2.1


def test_kc_grows_with_workers():
    m = mobilenet_v2_smoke()
    assert measured_kc(m, 8) > measured_kc(m, 2) > 0


class TestSimulateTrends:
    def setup_method(self):
        self.m = mobilenet_v2_smoke()

    def test_compute_decreases_with_workers(self):
        """Fig. 11: computation time falls monotonically with N."""
        times = [simulate(self.m, [WorkerParams()] * n).comp_time
                 for n in (1, 2, 4, 8)]
        assert times == sorted(times, reverse=True)

    def test_comm_increases_with_workers(self):
        """Fig. 10: communication rises with N (duplication + transfers)."""
        cfg = SimConfig()
        c2 = simulate(self.m, [WorkerParams(d_s_per_kb=0.005)] * 2, cfg=cfg)
        c8 = simulate(self.m, [WorkerParams(d_s_per_kb=0.005)] * 8, cfg=cfg)
        assert c8.comm_time > c2.comm_time

    def test_slow_worker_dominates_even_split(self):
        """Table II: 'Evenly' is worst under heterogeneity."""
        het = [WorkerParams(f_mhz=600), WorkerParams(f_mhz=150),
               WorkerParams(f_mhz=450)]
        even = simulate(self.m, het, ratings_evenly(het)).total_time
        freq = simulate(self.m, het, ratings_freq_only(het)).total_time
        assert freq < even

    def test_rating_beats_freq_only_under_delays(self):
        """Table II cases 5-8: optimized wins once delays differ."""
        het = [WorkerParams(f_mhz=600, d_s_per_kb=0.02),
               WorkerParams(f_mhz=396, d_s_per_kb=0.005),
               WorkerParams(f_mhz=150, d_s_per_kb=0.010)]
        kc = measured_kc(self.m, 3)
        k1 = simulated_k1(self.m, 600)
        freq = simulate(self.m, het, ratings_freq_only(het)).total_time
        opt = simulate(self.m, het, ratings_for(het, k1, kc)).total_time
        assert opt < freq

    def test_overlap_reduces_latency(self):
        w = [WorkerParams(d_s_per_kb=0.01)] * 3
        base = simulate(self.m, w, cfg=SimConfig(overlap=False)).total_time
        ovl = simulate(self.m, w, cfg=SimConfig(overlap=True)).total_time
        assert ovl <= base


class TestMemoryModel:
    def test_single_device_infeasible_full_model(self):
        """§VII.B.1: full MobileNetV2@112 exceeds a 512 KB budget."""
        from repro.models import mobilenet_v2
        m = mobilenet_v2()
        assert single_device_peak(m) > 512 * 1024

    def test_split_reduces_peak(self):
        m = mobilenet_v2_smoke()
        single = single_device_peak(m)
        p4 = peak_ram_per_worker(split_model(m, np.ones(4))).max()
        assert p4 < single

    def test_peak_decreases_then_saturates(self):
        """Fig. 12: biggest gains early, diminishing returns later."""
        m = mobilenet_v2_smoke()
        peaks = [peak_ram_per_worker(split_model(m, np.ones(n))).max()
                 for n in (1, 2, 4, 8, 16)]
        assert peaks[0] > peaks[1] > peaks[2]
        gain_early = peaks[0] - peaks[2]
        gain_late = peaks[3] - peaks[4]
        assert gain_early > gain_late

    def test_layerwise_within_budget_for_enough_workers(self):
        """Fig. 8 shape: with enough workers every layer fits a budget that
        the single device exceeds."""
        m = mobilenet_v2_smoke()
        single = single_device_peak(m)
        budget = single * 0.6
        lw = layerwise_peak(split_model(m, np.ones(4)))
        assert lw.max() <= budget

    def test_memory_terms_positive_and_consistent(self):
        m = small_cnn()
        plan = split_model(m, np.ones(3))
        lw = layerwise_peak(plan)
        assert lw.shape == (len(m.layers), 3)
        assert np.all(lw >= 0)
        np.testing.assert_array_equal(peak_ram_per_worker(plan),
                                      lw.max(axis=0))
