"""Per-architecture smoke tests (reduced configs, deliverable f) + the
strongest model invariant: prefill+decode must reproduce the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, LM_SHAPES, get_config, shape_applicable
from repro.models import lm


def _inputs(cfg, key, batch=2, seq=12, extra=1):
    toks = jax.random.randint(key, (batch, seq + extra), 0, cfg.vocab_size)
    base = {"tokens": toks}
    if cfg.family == "audio":
        base["frames"] = jax.random.normal(
            key, (batch, cfg.n_audio_frames, cfg.d_model))
    if cfg.family == "vlm":
        base["patches"] = jax.random.normal(
            key, (batch, cfg.n_patches, cfg.d_model))
    return base


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_forward_and_grad(self, arch):
        """Reduced config: one forward/train step on CPU; shapes + no NaNs."""
        cfg = get_config(arch + "-smoke")
        key = jax.random.PRNGKey(0)
        params = lm.init_model(cfg, key)
        base = _inputs(cfg, key, extra=0)
        logits = lm.forward(params, base, cfg, mode="train")
        s_total = base["tokens"].shape[1] + (
            cfg.n_patches if cfg.family == "vlm" else 0)
        assert logits.shape == (2, s_total, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        loss, grads = jax.value_and_grad(lm.lm_loss)(params, base, cfg)
        assert bool(jnp.isfinite(loss))
        gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                 for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_prefill_decode_matches_full_forward(self, arch):
        cfg = get_config(arch + "-smoke")
        if cfg.n_experts:
            # capacity drops depend on batching; disable for the equality test
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        key = jax.random.PRNGKey(1)
        params = lm.init_model(cfg, key)
        B, S = 2, 12
        base = _inputs(cfg, key, batch=B, seq=S)
        toks = base["tokens"]
        full = lm.forward(params, base, cfg, mode="train")
        cache = lm.init_cache(cfg, B, max_seq=S + 8)
        pre = dict(base)
        pre["tokens"] = toks[:, :S]
        lg_pre, cache = lm.forward(params, pre, cfg, mode="prefill",
                                   cache=cache)
        lg_dec, cache = lm.forward(params, {"tokens": toks[:, S:S + 1]}, cfg,
                                   mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(lg_dec),
                                   np.asarray(full[:, -1, :]),
                                   rtol=2e-3, atol=2e-4)

    def test_param_count_close_to_analytic(self, arch):
        from repro.nn.layers import param_count
        cfg = get_config(arch)          # FULL config — shapes only, no init
        defs = lm.model_defs(cfg)
        actual = param_count(defs)
        analytic = cfg.n_params()
        # analytic formula ignores norms/pos-embeds; must agree within 15%
        assert abs(actual - analytic) / analytic < 0.15, (actual, analytic)


def test_shape_applicability_matrix():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runnable = 0
    for arch, cfg in ARCHS.items():
        for shape in LM_SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if shape.name == "long_500k":
                assert ok == (cfg.family in ("ssm", "hybrid")), arch
            else:
                assert ok, (arch, shape.name, why)
            runnable += ok
    assert runnable == 32   # 3 shapes x 10 archs + 2 long_500k


def test_multi_token_decode_consistency():
    """Decoding 3 tokens sequentially == full forward at each position."""
    cfg = get_config("qwen3-14b-smoke")
    key = jax.random.PRNGKey(2)
    params = lm.init_model(cfg, key)
    B, S, n_dec = 2, 8, 3
    toks = jax.random.randint(key, (B, S + n_dec), 0, cfg.vocab_size)
    cache = lm.init_cache(cfg, B, max_seq=S + n_dec + 2)
    _, cache = lm.forward(params, {"tokens": toks[:, :S]}, cfg,
                          mode="prefill", cache=cache)
    for t in range(n_dec):
        lg, cache = lm.forward(params, {"tokens": toks[:, S + t:S + t + 1]},
                               cfg, mode="decode", cache=cache)
        full = lm.forward(params, {"tokens": toks[:, :S + t + 1]}, cfg,
                          mode="train")
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, -1, :]),
                                   rtol=2e-3, atol=2e-4)


def test_local_window_ring_cache():
    """Hybrid arch: decode with a ring-buffer window cache must equal the
    full forward (window semantics + ring phase)."""
    cfg = get_config("recurrentgemma-9b-smoke")
    key = jax.random.PRNGKey(3)
    params = lm.init_model(cfg, key)
    B = 1
    S = cfg.local_window + 5         # force ring wrap
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    cache = lm.init_cache(cfg, B, max_seq=S + 2)
    _, cache = lm.forward(params, {"tokens": toks[:, :S]}, cfg,
                          mode="prefill", cache=cache)
    for t in range(2):
        lg, cache = lm.forward(params, {"tokens": toks[:, S + t:S + t + 1]},
                               cfg, mode="decode", cache=cache)
        full = lm.forward(params, {"tokens": toks[:, :S + t + 1]}, cfg,
                          mode="train")
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, -1, :]),
                                   rtol=2e-3, atol=2e-4)
