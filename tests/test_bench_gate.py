"""The benchmark driver's failure contract and the CI regression gate:
a raising sub-benchmark must fail the run (non-zero exit), and
check_regression must hold the >20% line in both directions."""
import json
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))

from benchmarks import check_regression, run as bench_run  # noqa: E402


class TestRunExitCode:
    def test_failing_suite_exits_nonzero(self, monkeypatch, capsys):
        def boom():
            raise RuntimeError("kaboom")

        monkeypatch.setattr(bench_run, "build_suites",
                            lambda quick: [("ok", lambda: [("a", 1.0, "")]),
                                           ("broken", boom)])
        assert bench_run.main([]) == 1
        out = capsys.readouterr().out
        assert "broken,ERROR,RuntimeError: kaboom" in out
        assert "a,1.0" in out  # healthy suites still report

    def test_all_green_exits_zero(self, monkeypatch):
        monkeypatch.setattr(bench_run, "build_suites",
                            lambda quick: [("ok", lambda: [("a", 1.0, "")])])
        assert bench_run.main([]) == 0

    def test_smoke_flag_parses(self, monkeypatch):
        seen = {}

        def suites(quick):
            seen["quick"] = quick
            return []

        monkeypatch.setattr(bench_run, "build_suites", suites)
        # no suites -> "compares nothing" is fine here; exit 0 (no failures)
        assert bench_run.main(["--smoke"]) == 0
        assert seen["quick"] is True


def _payload(speedup=50.0, peak=10000, speedup2=None):
    rows = [dict(config="smoke", split="neuron", mode="int8",
                 batch=8, eager_s=1.0, compiled_s=1.0 / speedup,
                 speedup=speedup)]
    if speedup2 is not None:
        rows.append(dict(config="smoke", split="spatial", mode="int8",
                         batch=8, eager_s=1.0, compiled_s=1.0 / speedup2,
                         speedup=speedup2))
    return dict(rows=rows, peaks=dict(smoke=dict(neuron=peak)))


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


class TestRegressionGate:
    def test_within_threshold_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(speedup=50.0, peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(speedup=42.0, peak=11000))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_speedup_regression_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(speedup=50.0))
        f = _write(tmp_path, "fresh.json", _payload(speedup=30.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_single_row_wobble_passes_but_collapse_fails(self, tmp_path):
        """One noisy row within the geomean budget passes; one row losing
        its fast path (below half of baseline) fails outright."""
        b = _write(tmp_path, "base.json",
                   _payload(speedup=50.0, speedup2=40.0))
        wobble = _write(tmp_path, "wobble.json",
                        _payload(speedup=35.0, speedup2=40.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(wobble)]) == 0
        collapse = _write(tmp_path, "collapse.json",
                          _payload(speedup=20.0, speedup2=40.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(collapse)]) == 1

    def test_peak_ram_regression_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(peak=12500))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_empty_overlap_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", dict(rows=[], peaks={}))
        f = _write(tmp_path, "fresh.json", _payload())
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_missing_file_fails(self, tmp_path):
        f = _write(tmp_path, "fresh.json", _payload())
        assert check_regression.main(
            ["--baseline", str(tmp_path / "nope.json"),
             "--fresh", str(f)]) == 1

    def test_planner_latency_regression_fails(self, tmp_path):
        """The planner section's deterministic metrics hold the same line:
        a >20% worse chosen-plan latency is a search regression."""
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=True, wall_s=9.0,
                                            plan_latency_s=0.07,
                                            max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_planner_wall_time_not_gated(self, tmp_path):
        """Wall time is machine-bound — only the analytic metrics gate."""
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=True, wall_s=50.0,
                                            plan_latency_s=0.05,
                                            max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_planner_feasibility_flip_fails(self, tmp_path):
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=False, wall_s=1.0,
                                            binding="ram_cap")}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_transport_regression_fails(self, tmp_path):
        """The async-transport rows are analytic: a >20% slower pipelined
        makespan is a cost-model regression."""
        base = _payload()
        base["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                    pipelined_s=0.11)}
        fresh = _payload()
        fresh["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                     pipelined_s=0.15)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_transport_overlap_invariant_fails(self, tmp_path):
        """A pipelined makespan above its own serial total breaks the
        machine-independent overlap invariant regardless of the baseline."""
        base = _payload()
        base["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                    pipelined_s=0.11)}
        fresh = _payload()
        fresh["transport"] = {"smoke@8/neuron": dict(serial_s=0.10,
                                                     pipelined_s=0.12)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_sections_flag_restricts_comparison(self, tmp_path):
        """--sections lets the analytic-only CI cell gate planner/peaks/
        transport while ignoring timing rows it never produced."""
        b = _write(tmp_path, "base.json", _payload(speedup=50.0, peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(speedup=10.0, peak=10000))
        # the speedup collapse fails a full comparison...
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1
        # ...but is out of scope when only the analytic sections are gated
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "peaks,planner,transport"
                                      ]) == 0
        # an unknown section name is a hard configuration error
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "vibes"]) == 1

    def test_committed_baseline_selfcompare_passes(self, capsys):
        """The committed baseline must pass the gate against itself (the CI
        invariant: identical results are never a regression)."""
        baseline = _ROOT / "BENCH_executor.json"
        if not baseline.exists():
            pytest.skip("no committed baseline")
        assert check_regression.main(["--baseline", str(baseline),
                                      "--fresh", str(baseline)]) == 0
