"""The benchmark driver's failure contract and the CI regression gate:
a raising sub-benchmark must fail the run (non-zero exit), and
check_regression must hold the >20% line in both directions."""
import json
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))

from benchmarks import check_regression, executor_bench  # noqa: E402
from benchmarks import run as bench_run  # noqa: E402


class TestRunExitCode:
    def test_failing_suite_exits_nonzero(self, monkeypatch, capsys):
        def boom():
            raise RuntimeError("kaboom")

        monkeypatch.setattr(bench_run, "build_suites",
                            lambda quick: [("ok", lambda: [("a", 1.0, "")]),
                                           ("broken", boom)])
        assert bench_run.main([]) == 1
        out = capsys.readouterr().out
        assert "broken,ERROR,RuntimeError: kaboom" in out
        assert "a,1.0" in out  # healthy suites still report

    def test_all_green_exits_zero(self, monkeypatch):
        monkeypatch.setattr(bench_run, "build_suites",
                            lambda quick: [("ok", lambda: [("a", 1.0, "")])])
        assert bench_run.main([]) == 0

    def test_smoke_flag_parses(self, monkeypatch):
        seen = {}

        def suites(quick):
            seen["quick"] = quick
            return []

        monkeypatch.setattr(bench_run, "build_suites", suites)
        # no suites -> "compares nothing" is fine here; exit 0 (no failures)
        assert bench_run.main(["--smoke"]) == 0
        assert seen["quick"] is True


class TestSharedJsonSections:
    def test_write_results_preserves_foreign_sections(self, tmp_path,
                                                      monkeypatch):
        """Regression: executor_bench.write_results whitelisted
        planner/transport and silently deleted the mixed section (and would
        delete any future shared section) from BENCH_executor.json."""
        p = tmp_path / "BENCH_executor.json"
        p.write_text(json.dumps(dict(
            rows=[], peaks={"old": {"neuron": 1}},
            planner={"a": 1}, transport={"b": 2}, mixed={"c": 3},
            future_section={"d": 4})))
        monkeypatch.setattr(executor_bench, "RESULT_PATH", p)
        payload = executor_bench.write_results(
            rows=[dict(config="x")], peaks={"new": {"neuron": 2}})
        on_disk = json.loads(p.read_text())
        for out in (payload, on_disk):
            assert out["planner"] == {"a": 1}
            assert out["transport"] == {"b": 2}
            assert out["mixed"] == {"c": 3}
            assert out["future_section"] == {"d": 4}
            # own sections are replaced/merged, not preserved wholesale
            assert out["rows"] == [dict(config="x")]
            assert out["peaks"] == {"old": {"neuron": 1},
                                    "new": {"neuron": 2}}


def _payload(speedup=50.0, peak=10000, speedup2=None):
    rows = [dict(config="smoke", split="neuron", mode="int8",
                 batch=8, eager_s=1.0, compiled_s=1.0 / speedup,
                 speedup=speedup)]
    if speedup2 is not None:
        rows.append(dict(config="smoke", split="spatial", mode="int8",
                         batch=8, eager_s=1.0, compiled_s=1.0 / speedup2,
                         speedup=speedup2))
    return dict(rows=rows, peaks=dict(smoke=dict(neuron=peak)))


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


class TestRegressionGate:
    def test_within_threshold_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(speedup=50.0, peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(speedup=42.0, peak=11000))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_speedup_regression_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(speedup=50.0))
        f = _write(tmp_path, "fresh.json", _payload(speedup=30.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_single_row_wobble_passes_but_collapse_fails(self, tmp_path):
        """One noisy row within the geomean budget passes; one row losing
        its fast path (below half of baseline) fails outright."""
        b = _write(tmp_path, "base.json",
                   _payload(speedup=50.0, speedup2=40.0))
        wobble = _write(tmp_path, "wobble.json",
                        _payload(speedup=35.0, speedup2=40.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(wobble)]) == 0
        collapse = _write(tmp_path, "collapse.json",
                          _payload(speedup=20.0, speedup2=40.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(collapse)]) == 1

    def test_peak_ram_regression_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _payload(peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(peak=12500))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_empty_overlap_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", dict(rows=[], peaks={}))
        f = _write(tmp_path, "fresh.json", _payload())
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_missing_file_fails(self, tmp_path):
        f = _write(tmp_path, "fresh.json", _payload())
        assert check_regression.main(
            ["--baseline", str(tmp_path / "nope.json"),
             "--fresh", str(f)]) == 1

    def test_planner_latency_regression_fails(self, tmp_path):
        """The planner section's deterministic metrics hold the same line:
        a >20% worse chosen-plan latency is a search regression."""
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=True, wall_s=9.0,
                                            plan_latency_s=0.07,
                                            max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_planner_wall_time_not_gated(self, tmp_path):
        """Wall time is machine-bound — only the analytic metrics gate."""
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=True, wall_s=50.0,
                                            plan_latency_s=0.05,
                                            max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_planner_feasibility_flip_fails(self, tmp_path):
        base = _payload()
        base["planner"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                           plan_latency_s=0.05,
                                           max_peak_ram=16000)}
        fresh = _payload()
        fresh["planner"] = {"smoke@8": dict(feasible=False, wall_s=1.0,
                                            binding="ram_cap")}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_transport_regression_fails(self, tmp_path):
        """The async-transport rows are analytic: a >20% slower pipelined
        makespan is a cost-model regression."""
        base = _payload()
        base["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                    pipelined_s=0.11)}
        fresh = _payload()
        fresh["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                     pipelined_s=0.15)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_transport_overlap_invariant_fails(self, tmp_path):
        """A pipelined makespan above its own serial total breaks the
        machine-independent overlap invariant regardless of the baseline."""
        base = _payload()
        base["transport"] = {"smoke@8/neuron": dict(serial_s=0.25,
                                                    pipelined_s=0.11)}
        fresh = _payload()
        fresh["transport"] = {"smoke@8/neuron": dict(serial_s=0.10,
                                                     pipelined_s=0.12)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_mixed_regression_fails(self, tmp_path):
        """The mode-mixing rows are analytic: a >20% worse chosen score is
        a search/cost-model regression."""
        base = _payload()
        base["mixed"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                         best_uniform_s=0.05,
                                         mixed_s=0.04, max_peak_ram=16000)}
        fresh = _payload()
        fresh["mixed"] = {"smoke@8": dict(feasible=True, wall_s=1.0,
                                          best_uniform_s=0.05,
                                          mixed_s=0.0495,
                                          max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_mixed_invariant_fails(self, tmp_path):
        """A chosen score above the best uniform candidate breaks the
        machine-independent mixing invariant regardless of the baseline
        (the winner is a min over a superset of the uniforms)."""
        base = _payload()
        base["mixed"] = {"smoke@8": dict(feasible=True, best_uniform_s=0.05,
                                         mixed_s=0.04, max_peak_ram=16000)}
        fresh = _payload()
        fresh["mixed"] = {"smoke@8": dict(feasible=True, best_uniform_s=0.03,
                                          mixed_s=0.035,
                                          max_peak_ram=16000)}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_mixed_feasibility_flip_fails(self, tmp_path):
        base = _payload()
        base["mixed"] = {"smoke@8": dict(feasible=True, best_uniform_s=0.05,
                                         mixed_s=0.04, max_peak_ram=16000)}
        fresh = _payload()
        fresh["mixed"] = {"smoke@8": dict(feasible=False, wall_s=1.0,
                                          binding="ram_cap")}
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_committed_mixed_section_holds_acceptance(self):
        """The committed baseline must show per-block mixing strictly
        beating the best uniform plan on the MNv2@112 7-worker demo cluster
        (analytic, so machine-independent)."""
        baseline = _ROOT / "BENCH_executor.json"
        if not baseline.exists():
            pytest.skip("no committed baseline")
        mixed = json.loads(baseline.read_text()).get("mixed", {})
        if "mnv2_112@7" not in mixed:
            pytest.skip("baseline predates the mixed section")
        entry = mixed["mnv2_112@7"]
        assert entry["feasible"]
        assert entry["mode"] == "mixed"
        assert entry["mixed_s"] < entry["best_uniform_s"]

    def test_sections_flag_restricts_comparison(self, tmp_path):
        """--sections lets the analytic-only CI cell gate planner/peaks/
        transport while ignoring timing rows it never produced."""
        b = _write(tmp_path, "base.json", _payload(speedup=50.0, peak=10000))
        f = _write(tmp_path, "fresh.json", _payload(speedup=10.0, peak=10000))
        # the speedup collapse fails a full comparison...
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1
        # ...but is out of scope when only the analytic sections are gated
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "peaks,planner,transport"
                                      ]) == 0
        # an unknown section name is a hard configuration error
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "vibes"]) == 1

    def test_committed_baseline_selfcompare_passes(self, capsys):
        """The committed baseline must pass the gate against itself (the CI
        invariant: identical results are never a regression) — including the
        hot-path invariant, so the committed spatial int8 rows must all show
        compiled beating eager."""
        baseline = _ROOT / "BENCH_executor.json"
        if not baseline.exists():
            pytest.skip("no committed baseline")
        assert check_regression.main(["--baseline", str(baseline),
                                      "--fresh", str(baseline)]) == 0


def _kernels_payload(speedup=1.2, spatial_speedup=2.5):
    p = _payload(speedup=50.0, speedup2=spatial_speedup)
    p["kernels"] = {
        "qgemm_256": dict(ref_us=100.0, impl_us=round(100.0 / speedup, 1),
                          speedup=speedup),
        "dwconv_96x56": dict(ref_us=80.0, impl_us=40.0, speedup=2.0),
    }
    return p


class TestKernelGate:
    def test_kernel_drift_within_threshold_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _kernels_payload(speedup=1.2))
        f = _write(tmp_path, "fresh.json", _kernels_payload(speedup=1.1))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_kernel_geomean_regression_fails(self, tmp_path):
        """Both kernels drifting >20% drags the geomean below the line."""
        base = _kernels_payload(speedup=2.0)
        fresh = _kernels_payload(speedup=1.2)
        fresh["kernels"]["dwconv_96x56"]["speedup"] = 1.2
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_kernel_collapse_fails_outright(self, tmp_path):
        """One kernel below half its baseline is a lost path even when the
        geomean survives."""
        base = _kernels_payload(speedup=2.0)
        fresh = _kernels_payload(speedup=0.9)     # < half of 2.0
        fresh["kernels"]["dwconv_96x56"]["speedup"] = 2.6  # geomean rescued
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_hotpath_invariant_fails_when_spatial_loses(self, tmp_path):
        """A fresh spatial int8 row with compiled slower than eager fails
        regardless of the baseline — the fused band schedule must win at
        every batch size."""
        b = _write(tmp_path, "base.json",
                   _kernels_payload(spatial_speedup=0.9))
        f = _write(tmp_path, "fresh.json",
                   _kernels_payload(spatial_speedup=0.9))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1
        # ...and is out of scope when the kernels section is not selected
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "rows,peaks"]) == 0


def _serving_payload(gain=1.3, bitexact=True, rejection=0.4,
                     accepted_p99=0.3, bound=1.0, gain_gated=True,
                     batches=(1000, 150)):
    p = _payload()
    p["serving"] = {"smoke_2res": dict(
        flush_rps=500.0, continuous_rps=round(500.0 * gain, 1),
        batching_gain=gain, gain_gated=gain_gated,
        flush_batches=batches[0], continuous_batches=batches[1],
        bitexact=bitexact, saturation_rps=500.0,
        overload_offered_rps=1000.0, overload_rejection_rate=rejection,
        overload_accepted_p99_s=accepted_p99, p99_target_s=0.25,
        p99_bound_s=bound)}
    return p


class TestServingGate:
    def test_healthy_serving_row_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(gain=1.2))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_batching_gain_below_one_fails(self, tmp_path):
        """Continuous batching losing to the flush-barrier Session baseline
        is a scheduler regression regardless of the baseline row."""
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(gain=0.97))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_ungated_gain_below_one_passes(self, tmp_path):
        """Heavy-model configs sit at throughput parity (per-sample compute
        dwarfs dispatch overhead) — their gain is reported, not gated."""
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json",
                   _serving_payload(gain=0.97, gain_gated=False))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_dispatch_count_inversion_fails_even_ungated(self, tmp_path):
        """The structural invariant holds on every row: the continuous
        scheduler may never need MORE dispatches than client-driven
        flushes for the same requests."""
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json",
                   _serving_payload(gain=1.0, gain_gated=False,
                                    batches=(150, 1000)))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_bitexact_false_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(bitexact=False))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_zero_overload_rejections_fails(self, tmp_path):
        """2x saturation with no shedding means admission control queued
        unboundedly — the overload story is broken."""
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(rejection=0.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_unbounded_accepted_tail_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json",
                   _serving_payload(accepted_p99=1.4, bound=1.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_serving_invariants_gate_fresh_rows_without_baseline(
            self, tmp_path):
        """Like runtime: the invariants hold on fresh rows even when the
        committed baseline predates the serving section."""
        b = _write(tmp_path, "base.json", _payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(gain=0.9))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_sections_flag_excludes_serving(self, tmp_path):
        b = _write(tmp_path, "base.json", _serving_payload())
        f = _write(tmp_path, "fresh.json", _serving_payload(gain=0.9))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "rows,peaks"]) == 0

    def test_rps_fields_informational_only(self, tmp_path):
        """Saturation/continuous rps are runner wall-clock: a slower runner
        must not fail the gate while the invariants hold."""
        base = _serving_payload()
        fresh = _serving_payload()
        fresh["serving"]["smoke_2res"]["continuous_rps"] = 100.0
        fresh["serving"]["smoke_2res"]["flush_rps"] = 80.0
        fresh["serving"]["smoke_2res"]["saturation_rps"] = 90.0
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0


def _elastic_payload(bitexact=True, reshipped=12880, full=71184,
                     hit_rate=1.0, expected=16, leaked=0):
    p = _payload()
    p["elastic"] = {"mnv2_smoke@3": dict(
        n_workers=3, spawn="inprocess",
        bitexact_after_recovery=bitexact,
        full_setup_bytes=full, reshipped_bytes=reshipped,
        rejoin_full_setup_bytes=100336, rejoin_reshipped_bytes=33016,
        cache_hit_rate=hit_rate, expected_cache_hits=expected,
        leaked_tasks=leaked,
        downtime_kill_s=3.7, downtime_rejoin_s=2.2)}
    return p


class TestElasticGate:
    def test_healthy_elastic_row_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json", _elastic_payload())
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_bitexact_false_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json", _elastic_payload(bitexact=False))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_reship_not_below_full_fails(self, tmp_path):
        """Delta shipping degenerating to a cold re-setup is the replan
        layer losing its point — gated on the fresh row alone."""
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json",
                   _elastic_payload(reshipped=71184))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_cache_miss_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json", _elastic_payload(hit_rate=0.9))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_vacuous_hit_rate_not_gated(self, tmp_path):
        """No unchanged geometry (expected 0) means there is nothing to
        hit — the rate is not gated on such rows."""
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json",
                   _elastic_payload(hit_rate=0.0, expected=0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 0

    def test_leaked_tasks_fail(self, tmp_path):
        b = _write(tmp_path, "base.json", _elastic_payload())
        f = _write(tmp_path, "fresh.json", _elastic_payload(leaked=2))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f)]) == 1

    def test_analytic_row_gates_reship_only(self, tmp_path):
        """--analytic rows (plan diff, no live workers) carry only the
        reship invariant; absent fields are not gated."""
        p = _payload()
        p["elastic"] = {"mnv2_smoke@3": dict(
            n_workers=3, analytic=True,
            full_setup_bytes=188136, reshipped_bytes=51240,
            unchanged_segments=4)}
        b = _write(tmp_path, "base.json", p)
        f = _write(tmp_path, "fresh.json", p)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "elastic"]) == 0

    def test_committed_elastic_section_holds(self):
        """The committed baseline's own elastic rows must satisfy every
        machine-independent invariant the gate enforces."""
        doc = json.loads((_ROOT / "BENCH_executor.json").read_text())
        failures, compared = check_regression.compare(
            doc, doc, 0.2, sections=("elastic",))
        assert compared > 0
        assert failures == []


class TestMergeSections:
    def test_merge_sections_is_per_key(self, tmp_path, monkeypatch):
        """kernel_bench/executor_bench section writes replace only the keys
        they produced: other kernels and foreign sections survive."""
        p = tmp_path / "BENCH_executor.json"
        p.write_text(json.dumps(dict(
            rows=[{"config": "x"}],
            kernels={"qgemm_256": {"speedup": 1.0},
                     "decode_attn_2k": {"speedup": 3.0}})))
        monkeypatch.setattr(executor_bench, "RESULT_PATH", p)
        payload = executor_bench.merge_sections(
            kernels={"qgemm_256": {"speedup": 2.0}},
            roofline={"smoke": {"_peak_gflops": 100.0}})
        on_disk = json.loads(p.read_text())
        for out in (payload, on_disk):
            assert out["kernels"]["qgemm_256"] == {"speedup": 2.0}
            assert out["kernels"]["decode_attn_2k"] == {"speedup": 3.0}
            assert out["roofline"] == {"smoke": {"_peak_gflops": 100.0}}
            assert out["rows"] == [{"config": "x"}]


def _search_payload(key="smoke@8", ladder=0.0445, beam=0.0426,
                    warm_misses=4, cold_misses=28, hit_rate=0.86,
                    dp_serial=0.31, dp_transport=0.29, win=None):
    p = _payload()
    p["search"] = {key: dict(
        ladder_score=ladder, beam_score=beam, beam_width=4,
        beam_subsets=16, cold_wall_s=0.4, beam_wall_s=0.2,
        warm_wall_s=0.01, cold_replan_wall_s=0.3,
        cold_candidates=32, cold_misses=32,
        warm_candidates=28, warm_misses=warm_misses,
        warm_hit_rate=hit_rate,
        cold_replan_candidates=28, cold_replan_misses=cold_misses,
        dp_serial_pipelined_s=dp_serial,
        dp_transport_pipelined_s=dp_transport,
        transport_dp_win=(dp_transport < dp_serial if win is None else win))}
    return p


class TestSearchGate:
    def test_healthy_search_row_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json", _search_payload())
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 0

    def test_ladder_score_regression_fails(self, tmp_path):
        """The ladder score is analytic: >20% growth means the search now
        returns a worse plan, not machine noise."""
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(ladder=0.06, beam=0.0426))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1

    def test_beam_above_ladder_fails_even_without_baseline_row(
            self, tmp_path):
        """Structural invariant on every fresh row: the beam evaluates each
        ladder prefix too, so its plan may never score worse."""
        b = _write(tmp_path, "base.json", _payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(ladder=0.04, beam=0.05))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1

    def test_warm_not_fewer_than_cold_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(warm_misses=28, cold_misses=28))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1

    def test_zero_warm_hit_rate_fails(self, tmp_path):
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json", _search_payload(hit_rate=0.0))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1

    def test_transport_dp_above_serial_fails(self, tmp_path):
        """The planner re-ranks both DP variants under the exact simulated
        metric, so the transport-aware result can never be worse."""
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(dp_serial=0.29, dp_transport=0.31))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1

    def test_mnv2_rows_require_a_transport_dp_win(self, tmp_path):
        """Acceptance gate: at least one fresh paper-scale row must show
        the transport-aware DP strictly beating the serial surrogate."""
        b = _write(tmp_path, "base.json", _payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(key="mnv2_112@7", dp_serial=0.31,
                                   dp_transport=0.31, win=False))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 1
        f2 = _write(tmp_path, "fresh2.json",
                    _search_payload(key="mnv2_112@7", dp_serial=0.31,
                                    dp_transport=0.29))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f2),
                                      "--sections", "search"]) == 0

    def test_smoke_rows_do_not_require_a_win(self, tmp_path):
        """The win requirement applies to paper-scale rows only — the smoke
        model's blocks are too small for pipelined seams to matter."""
        b = _write(tmp_path, "base.json", _payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(dp_serial=0.31, dp_transport=0.31,
                                   win=False))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 0

    def test_wall_clock_fields_informational_only(self, tmp_path):
        """Search walls are runner wall-clock — a slower runner must not
        fail the gate while the analytic invariants hold."""
        base = _search_payload()
        fresh = _search_payload()
        for field in ("cold_wall_s", "beam_wall_s", "warm_wall_s",
                      "cold_replan_wall_s"):
            fresh["search"]["smoke@8"][field] = 50.0
        b = _write(tmp_path, "base.json", base)
        f = _write(tmp_path, "fresh.json", fresh)
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "search"]) == 0

    def test_sections_flag_excludes_search(self, tmp_path):
        b = _write(tmp_path, "base.json", _search_payload())
        f = _write(tmp_path, "fresh.json",
                   _search_payload(ladder=0.04, beam=0.05))
        assert check_regression.main(["--baseline", str(b),
                                      "--fresh", str(f),
                                      "--sections", "rows,peaks"]) == 0

    def test_committed_search_section_holds(self):
        """The committed baseline's own search rows must satisfy every
        machine-independent invariant the gate enforces."""
        doc = json.loads((_ROOT / "BENCH_executor.json").read_text())
        failures, compared = check_regression.compare(
            doc, doc, 0.2, sections=("search",))
        assert compared > 0
        assert failures == []
