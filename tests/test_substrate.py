"""Substrate tests: optimizer, quantization, fusion, checkpoint, data
pipeline, elastic runtime, recurrent-cell math."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.allocation import WorkerParams
from repro.core.fusion import BatchNormParams, fold_batchnorm
from repro.core.quantize import dequantize, quantize_activation, quantize_tensor_per_channel
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import mobilenet_v2_smoke
from repro.runtime.elastic import ElasticCluster, plan_recovery_mesh
from repro.train.optimizer import (OptConfig, adamw_update, fake_quant_grads,
                                   global_norm, init_opt_state, schedule)


class TestOptimizer:
    def test_adamw_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params)
        cfg = OptConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                        total_steps=200, min_lr_frac=1.0)
        for _ in range(150):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw_update(g, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        opt = init_opt_state(params)
        cfg = OptConfig(clip_norm=1.0)
        g = {"w": jnp.full(3, 100.0)}
        _, _, metrics = adamw_update(g, opt, params, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(
            float(global_norm(g)))

    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_frac=0.1)
        assert float(schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
        assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
        assert float(schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1, rel=1e-2)

    @given(bits=st.integers(4, 8), seed=st.integers(0, 50))
    @settings(max_examples=30, deadline=None)
    def test_fake_quant_error_bound(self, bits, seed):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.standard_normal(100).astype(np.float32))}
        gq = fake_quant_grads(g, bits=bits)
        scale = float(jnp.max(jnp.abs(g["w"]))) / (2 ** (bits - 1) - 1)
        assert float(jnp.max(jnp.abs(gq["w"] - g["w"]))) <= scale / 2 + 1e-7


class TestQuantize:
    @given(seed=st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_error_bound(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((8, 4, 3, 3)).astype(np.float32)
        q, s = quantize_tensor_per_channel(w, 0)
        back = q.astype(np.float32) * s[:, None, None, None]
        per_ch_scale = np.abs(w).max(axis=(1, 2, 3)) / 127
        assert np.all(np.abs(back - w) <= per_ch_scale[:, None, None, None]
                      * 0.5 + 1e-7)

    def test_activation_quant(self):
        x = np.linspace(-2, 2, 100).astype(np.float32)
        q = quantize_activation(x, 2.0 / 127)
        assert q.dtype == np.int8
        np.testing.assert_allclose(dequantize(q, 2.0 / 127), x, atol=0.01)


class TestFusion:
    def test_bn_fold_equals_unfused(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((6, 4, 3, 3)).astype(np.float32)
        b = rng.standard_normal(6).astype(np.float32)
        bn = BatchNormParams(
            gamma=rng.uniform(0.5, 1.5, 6).astype(np.float32),
            beta=rng.uniform(-1, 1, 6).astype(np.float32),
            mean=rng.uniform(-1, 1, 6).astype(np.float32),
            var=rng.uniform(0.5, 2.0, 6).astype(np.float32))
        wf, bf = fold_batchnorm(w, b, bn)
        x = rng.standard_normal((4, 8, 8)).astype(np.float32)
        def conv(wt):
            return jax.lax.conv_general_dilated(
                jnp.asarray(x)[None], jnp.asarray(wt), (1, 1), "SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
        y_unfused = (np.asarray(conv(w)) + b[:, None, None] - bn.mean[:, None, None]) \
            / np.sqrt(bn.var + bn.eps)[:, None, None] * bn.gamma[:, None, None] \
            + bn.beta[:, None, None]
        y_fused = np.asarray(conv(wf)) + bf[:, None, None]
        np.testing.assert_allclose(y_fused, y_unfused, rtol=1e-4, atol=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                "opt": {"m": [jnp.zeros(2), jnp.ones(3)],
                        "step": jnp.asarray(7)}}
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        out = restore_checkpoint(str(tmp_path), 7, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_tmp_ignored(self, tmp_path):
        os.makedirs(tmp_path / "step_5.tmp")
        assert latest_step(str(tmp_path)) is None
        save_checkpoint(str(tmp_path), 3, {"w": jnp.zeros(2)})
        assert latest_step(str(tmp_path)) == 3

    def test_async_save(self, tmp_path):
        t = save_checkpoint(str(tmp_path), 1, {"w": jnp.ones(4)},
                            blocking=False)
        t.join()
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3)})

    def test_overwrite_same_step(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
        save_checkpoint(str(tmp_path), 1, {"w": jnp.ones(2)})
        out = restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(2))


class TestData:
    def test_deterministic(self):
        d = SyntheticLM(1000, seed=3)
        b1 = d.batch(5, 8, 16)
        b2 = d.batch(5, 8, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_shards_disjoint_and_cover(self):
        d = SyntheticLM(1000, seed=3)
        shards = [d.batch(2, 8, 16, shard=i, n_shards=4) for i in range(4)]
        assert all(s["tokens"].shape == (2, 16) for s in shards)
        # different shards differ (PRNG keyed on shard)
        assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])

    def test_prefetcher(self):
        seen = []
        pf = Prefetcher(lambda i: {"i": i}, depth=2)
        for _ in range(5):
            seen.append(next(pf)["i"])
        pf.close()
        assert seen == [0, 1, 2, 3, 4]


class TestElastic:
    def _cluster(self):
        m = mobilenet_v2_smoke()
        workers = [WorkerParams(f_mhz=600, flash_bytes=1 << 20)
                   for _ in range(4)]
        # frozen injected clock: staleness only when a test passes `now`
        return ElasticCluster(m, workers, heartbeat_timeout=0.1,
                              clock=lambda: 0.0)

    @staticmethod
    def _share(c, physical_id):
        """MACs assigned to a physical worker under the current plan (0 if
        the planner dropped it from the serving subset)."""
        if physical_id not in c.plan_worker_ids:
            return 0
        return c.plan.split.worker_macs(
            c.plan_worker_ids.index(physical_id))

    def test_failure_replan(self):
        c = self._cluster()
        assert 3 in c.plan_worker_ids
        c.mark_failed(3)
        assert c.check()
        assert 3 not in c.plan_worker_ids
        assert set(c.plan_worker_ids) <= {0, 1, 2}

    def test_heartbeat_timeout(self):
        c = self._cluster()
        now = time.monotonic()
        c.heartbeat(0, now)
        c.heartbeat(1, now)
        c.heartbeat(2, now)
        # worker 3 silent past the timeout
        c.health[3].last_heartbeat = now - 1.0
        assert c.check(now)
        assert 3 not in c.alive_indices

    def test_straggler_demoted(self):
        c = self._cluster()
        for w in range(4):
            c.report_step_time(w, 1.0 if w else 10.0)   # worker 0 is 10x slow
        share_before = self._share(c, 0)
        assert c.check()
        assert c.health[0].params.f_mhz < 600
        assert self._share(c, 0) < share_before

    def test_all_dead_raises(self):
        c = self._cluster()
        for w in range(4):
            c.mark_failed(w)
        with pytest.raises(RuntimeError):
            c.check()

    def test_recovery_mesh(self):
        assert plan_recovery_mesh(512) == (32, 16)
        assert plan_recovery_mesh(250) == (15, 16)
        with pytest.raises(ValueError):
            plan_recovery_mesh(8)


class TestRecurrentCells:
    def test_rglru_scan_equals_stepwise(self):
        """Associative-scan RG-LRU == sequential per-token recurrence."""
        from repro.nn.recurrent import linear_scan
        rng = np.random.default_rng(0)
        B, S, D = 2, 17, 5
        a = jnp.asarray(rng.uniform(0.1, 0.99, (B, S, D)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
        h0 = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))
        got = linear_scan(a, b, h0=h0)
        h = h0
        exp = []
        for t in range(S):
            h = a[:, t] * h + b[:, t]
            exp.append(h)
        np.testing.assert_allclose(np.asarray(got),
                                   np.stack([np.asarray(e) for e in exp], 1),
                                   rtol=1e-5, atol=1e-5)

    def test_mlstm_chunked_equals_stepwise(self):
        """Chunkwise-parallel mLSTM == the sequential step recurrence."""
        from repro.nn.recurrent import mlstm_sequence, mlstm_step
        rng = np.random.default_rng(1)
        B, S, H, dk = 2, 16, 2, 8
        q = jnp.asarray(rng.standard_normal((B, S, H, dk)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((B, S, H, dk)).astype(np.float32)) / np.sqrt(dk)
        v = jnp.asarray(rng.standard_normal((B, S, H, dk)).astype(np.float32))
        ig = jnp.asarray(rng.standard_normal((B, S, H)).astype(np.float32))
        lf = jnp.asarray(jax.nn.log_sigmoid(
            jnp.asarray(rng.standard_normal((B, S, H)).astype(np.float32))))
        h_chunk, final_c = mlstm_sequence(q, k, v, ig, lf, chunk=4)
        state = (jnp.zeros((B, H, dk, dk)), jnp.zeros((B, H, dk)),
                 jnp.zeros((B, H)))
        outs = []
        for t in range(S):
            h_t, state = mlstm_step(q[:, t], k[:, t], v[:, t], ig[:, t],
                                    lf[:, t], state)
            outs.append(h_t)
        exp = np.stack([np.asarray(o) for o in outs], axis=1)
        np.testing.assert_allclose(np.asarray(h_chunk), exp, rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(final_c[0]),
                                   np.asarray(state[0]), rtol=2e-4, atol=2e-4)
