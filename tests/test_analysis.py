"""HLO analysis parser tests: loop-aware FLOP/byte/collective accounting
validated against compiled oracles and synthetic HLO."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import analysis as A


class TestScanOracle:
    def test_scan_flops_exact(self):
        D, L = 128, 7
        def f(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, ws)[0].sum()
        c = jax.jit(f).lower(jnp.zeros((L, D, D)), jnp.zeros((32, D))).compile()
        t = A.analyze_hlo(c.as_text())
        assert t.flops == pytest.approx(2 * 32 * D * D * L, rel=0.02)

    def test_nested_scan_flops(self):
        D, L1, L2 = 64, 3, 5
        def f(ws, x):
            def outer(x, w):
                def inner(x2, _):
                    return jnp.tanh(x2 @ w), None
                return jax.lax.scan(inner, x, jnp.arange(L2))[0], None
            return jax.lax.scan(outer, x, ws)[0].sum()
        c = jax.jit(f).lower(jnp.zeros((L1, D, D)), jnp.zeros((16, D))).compile()
        t = A.analyze_hlo(c.as_text())
        assert t.flops == pytest.approx(2 * 16 * D * D * L1 * L2, rel=0.05)

    def test_unrolled_matches_xla(self):
        D = 128
        def f(a, b):
            return (a @ b).sum()
        c = jax.jit(f).lower(jnp.zeros((D, D)), jnp.zeros((D, D))).compile()
        t = A.analyze_hlo(c.as_text())
        ca = c.cost_analysis()
        if isinstance(ca, list):  # jax < 0.5 returned one dict per computation
            ca = ca[0]
        xla = ca["flops"]
        assert t.flops == pytest.approx(xla, rel=0.02)

    def test_scan_bytes_not_quadratic(self):
        """Stacked scan outputs (DUS into a (L, ...) buffer) must count the
        written slice per step, not the whole buffer."""
        D, L = 256, 64
        def f(x):
            def body(c, _):
                c = jnp.tanh(c) * 1.0001
                return c, c
            _, ys = jax.lax.scan(body, x, None, length=L)
            return ys
        c = jax.jit(f).lower(jnp.zeros((D, D))).compile()
        t = A.analyze_hlo(c.as_text())
        buf = L * D * D * 4
        # traffic should be O(L * slice) ~ a few x buf; the broken model
        # would give O(L * buf) = L x larger
        assert t.bytes < 8 * buf, (t.bytes, buf)


class TestSyntheticHLO:
    HLO = """\
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8,16], b: f32[16,8]) -> f32[8,8] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,8]{1,0} parameter(1)
  %d = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[32,8]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %init), condition=%cond, body=%body
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""

    def test_dot_flops(self):
        t = A.analyze_hlo(self.HLO)
        assert t.flops == 2 * 8 * 8 * 16

    def test_collectives_with_trip_count(self):
        t = A.analyze_hlo(self.HLO)
        # all-gather at entry: result bytes 32*8*4
        assert t.coll["all-gather"] == 32 * 8 * 4
        # all-reduce inside a 12-trip while: 2x operand bytes x 12
        assert t.coll["all-reduce"] == 2 * (8 * 8 * 4) * 12

    def test_trip_count_extraction(self):
        comps = A._split_computations(self.HLO)
        assert A._trip_count(comps["cond"]) == 12


class TestRooflineReport:
    def test_terms_and_bottleneck(self):
        r = A.RooflineReport(
            arch="x", shape="train_4k", mesh="16x16",
            flops=1.97e14, hbm_bytes=8.19e11, coll_bytes={"all-gather": 5e10},
            model_flops=0.985e14, peak_mem_bytes=1e9)
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.t_collective == pytest.approx(1.0)
        assert r.useful_flops_frac == pytest.approx(0.5)
        assert r.roofline_frac == pytest.approx(0.5)

    def test_model_flops_modes(self):
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        cfg = get_config("qwen3-14b")
        n = cfg.n_params()
        tr = A.model_flops_for(cfg, ShapeConfig("t", 4096, 256, "train"))
        pf = A.model_flops_for(cfg, ShapeConfig("p", 4096, 256, "prefill"))
        de = A.model_flops_for(cfg, ShapeConfig("d", 4096, 256, "decode"))
        assert tr == pytest.approx(6 * n * 4096 * 256)
        assert pf == pytest.approx(tr / 3)
        assert de == pytest.approx(2 * n * 256)
