"""Resource-aware allocation (Eq. 1-7) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocation import (WorkerParams, allocate, capability_rating,
                                   execution_time, proportional_allocation,
                                   ratings_evenly, ratings_for,
                                   ratings_freq_only, redistribute_overflow)


class TestRating:
    def test_no_comm_degenerates_to_compute(self):
        p = WorkerParams(f_mhz=600)
        # Eq. 5 with Kc=0: R = f*K1
        assert capability_rating(p, k1=0.133, kc=0.0) == pytest.approx(600 * 0.133)

    def test_rating_monotone_in_frequency(self):
        lo = capability_rating(WorkerParams(f_mhz=150), 0.133, 2.9)
        hi = capability_rating(WorkerParams(f_mhz=600), 0.133, 2.9)
        assert hi > lo

    def test_rating_decreases_with_delay(self):
        base = capability_rating(WorkerParams(d_s_per_kb=0.0), 0.133, 2.9)
        slow = capability_rating(WorkerParams(d_s_per_kb=0.02), 0.133, 2.9)
        assert slow < base

    def test_execution_time_eq1(self):
        p = WorkerParams(f_mhz=600, d_s_per_kb=0.001, b_kb_s=10000)
        w = 1200.0  # Mcycles
        t = execution_time(w, p, k1=0.133, kc=2.0)
        expected = w / 600 + (0.001 + 1e-4) * 0.133 * 2.0 * w
        assert t == pytest.approx(expected)


class TestRedistribution:
    def test_preserves_sum(self):
        r = np.array([5.0, 1.0, 1.0])
        caps = np.array([100.0, 1000.0, 1000.0])
        r2 = redistribute_overflow(r, caps, total_size=700.0)
        assert r2.sum() == pytest.approx(r.sum())

    def test_respects_capacity(self):
        r = np.array([5.0, 1.0, 1.0])
        caps = np.array([100.0, 1000.0, 1000.0])
        r2 = redistribute_overflow(r, caps, total_size=700.0)
        sizes = proportional_allocation(r2, 700.0)
        assert np.all(sizes <= caps + 1e-6)

    def test_infeasible_raises(self):
        with pytest.raises(ValueError):
            redistribute_overflow(np.ones(2), np.array([10.0, 10.0]), 100.0)

    def test_noop_when_fits(self):
        r = np.array([2.0, 1.0])
        r2 = redistribute_overflow(r, np.array([1e9, 1e9]), 300.0)
        np.testing.assert_allclose(r, r2)

    @given(n=st.integers(1, 10), seed=st.integers(0, 200),
           frac=st.floats(0.3, 0.95))
    @settings(max_examples=100, deadline=None)
    def test_random_instances(self, n, seed, frac):
        rng = np.random.default_rng(seed)
        r = rng.uniform(0.1, 10.0, n)
        caps = rng.uniform(10.0, 100.0, n)
        total = frac * caps.sum()
        r2 = redistribute_overflow(r, caps, total)
        sizes = proportional_allocation(r2, total)
        assert np.all(sizes <= caps + 1e-6)
        assert r2.sum() == pytest.approx(r.sum(), rel=1e-6)
        assert sizes.sum() == pytest.approx(total, rel=1e-6)


def test_allocate_end_to_end():
    workers = [WorkerParams(f_mhz=600, flash_bytes=8 << 20),
               WorkerParams(f_mhz=150, flash_bytes=8 << 20),
               WorkerParams(f_mhz=450, flash_bytes=8 << 20)]
    r, sizes = allocate(workers, k1=0.133, kc=2.9, model_bytes=3.5e6)
    assert sizes.sum() == pytest.approx(3.5e6)
    assert r[0] > r[2] > r[1]   # faster clock -> bigger share


def test_baseline_ratings():
    workers = [WorkerParams(f_mhz=600), WorkerParams(f_mhz=150)]
    assert list(ratings_evenly(workers)) == [1.0, 1.0]
    assert list(ratings_freq_only(workers)) == [600.0, 150.0]
    r = ratings_for(workers, 0.133, 2.9)
    assert r[0] > r[1]
