"""Cross-layer activation mapping (Alg. 3): the scalable region form must
equal the literal brute-force algorithm exactly."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.mapping import (assignm_bruteforce, comm_volume,
                                routem_bruteforce, worker_input_regions)
from repro.core.reinterpret import LayerSpec, conv_out_hw
from repro.core.splitting import split_layer
from conftest import small_cnn


def _layer(kind, c_in, c_out, hw, k, stride, pad):
    rng = np.random.default_rng(0)
    if kind == "linear":
        w = rng.standard_normal((c_in, c_out)).astype(np.float32)
        return LayerSpec("l", "linear", (c_in, 1, 1), (c_out, 1, 1), w,
                         np.zeros(c_out, np.float32))
    oh, ow = conv_out_hw((hw, hw), (k, k), (stride, stride), (pad, pad))
    if kind == "dwconv":
        w = rng.standard_normal((c_in, 1, k, k)).astype(np.float32)
        return LayerSpec("l", "dwconv", (c_in, hw, hw), (c_in, oh, ow), w,
                         np.zeros(c_in, np.float32), stride=(stride, stride),
                         padding=(pad, pad))
    w = rng.standard_normal((c_out, c_in, k, k)).astype(np.float32)
    return LayerSpec("l", "conv", (c_in, hw, hw), (c_out, oh, ow), w,
                     np.zeros(c_out, np.float32), stride=(stride, stride),
                     padding=(pad, pad))


@given(kind=st.sampled_from(["conv", "dwconv", "linear"]),
       c_in=st.integers(1, 5), c_out=st.integers(1, 5),
       hw=st.integers(3, 8), k=st.integers(1, 3),
       stride=st.integers(1, 2), pad=st.integers(0, 1),
       n_workers=st.integers(1, 5), seed=st.integers(0, 50))
@settings(max_examples=120, deadline=None)
def test_regions_match_bruteforce(kind, c_in, c_out, hw, k, stride, pad,
                                  n_workers, seed):
    layer = _layer(kind, c_in, c_out, hw, k, stride, pad)
    rng = np.random.default_rng(seed)
    split = split_layer(layer, rng.uniform(0.1, 3.0, n_workers))
    bf = assignm_bruteforce(layer, split)
    regions = worker_input_regions(layer, split)
    for w in range(n_workers):
        pts_bf = set(map(tuple, np.argwhere((bf >> w) & 1)))
        pts_reg = set()
        for r in regions[w]:
            pts_reg |= r.point_set()
        assert pts_bf == pts_reg, (kind, w)


def test_routem_producers_cover_outputs():
    layer = _layer("conv", 3, 4, 6, 3, 1, 1)
    split = split_layer(layer, np.ones(3))
    # RouteM over the *previous* layer's producers: use the same layer's
    # output split as producer of a same-shaped next layer input
    prev = split_layer(layer, np.ones(3))
    route = routem_bruteforce(prev, np.zeros(layer.n_out, np.int64)
                              .reshape(layer.out_shape))
    assert len(route) == layer.n_out
    producers = {r for r, _ in route}
    assert producers == {0, 1, 2}


def test_comm_volume_duplication_grows_with_workers():
    """More workers -> more duplicated receptive-field traffic (Fig. 10)."""
    m = small_cnn()
    layer = m.layers[1]   # dwconv with spatial overlap
    prev = split_layer(m.layers[0], np.ones(2))
    v2 = comm_volume(split_layer(m.layers[0], np.ones(2)).shards and prev,
                     layer, split_layer(layer, np.ones(2)))
    v8 = comm_volume(split_layer(m.layers[0], np.ones(8)), layer,
                     split_layer(layer, np.ones(8)))
    assert v8.download_bytes.sum() >= v2.download_bytes.sum()
    assert v8.duplication >= v2.duplication


def test_comm_volume_linear_layer_full_fanin():
    layer = _layer("linear", 12, 8, 0, 0, 0, 0)
    split = split_layer(layer, np.ones(4))
    vol = comm_volume(None, layer, split)
    # every worker needs every input activation
    assert all(b == 12 for b in vol.download_bytes)
    assert vol.duplication == 4.0
