"""Per-block mode mixing: heterogeneous SplitPlans end-to-end.

Covers the mixed plan constructor (per-block modes + worker subsets), the
cross-boundary accounting fixes it forced (producer-sized ``comm_volume``
upload arrays, ``weight_itemsize`` threading, the ``bounding_slices``
over-approximation contract), int8 bit-exactness across every mode seam,
the DP assignment search (exact vs the serial simulator), and the planner's
``"mixed"`` axis with Plan JSON schema v2.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import small_cnn
from repro.api import (Cluster, Objective, Plan, Planner, SEARCH_MODES,
                       build_split_plan)
from repro.core import (CompiledSplitExecutor, SimConfig, SplitExecutor,
                        WorkerParams, calibrate_scales, comm_volume,
                        layerwise_peak, peak_ram_per_worker, plan_memory,
                        quantize_model, reference_forward,
                        search_mixed_assignment, simulate, split_layer,
                        split_model, split_model_mixed, worker_input_regions)
from repro.core.fusion import group_blocks
from repro.core.reinterpret import trace_sequential
from repro.models import mobilenet_v2_smoke


def _acts_fn(model, x):
    return reference_forward(model, x, collect_activations=True)[1]


def _quantized(model, rng, shape, n_calib=3):
    calib = [rng.standard_normal(shape).astype(np.float32)
             for _ in range(n_calib)]
    return quantize_model(model, calibrate_scales(model, calib, _acts_fn))


def _demo_workers(n=8):
    return list(Cluster.heterogeneous_demo(n).workers)


def _seam_assignment(model):
    """An assignment covering every seam type: spatial->kernel,
    kernel->neuron, neuron->spatial and spatial->neuron."""
    n_b = len(group_blocks(model))
    cyc = ["spatial", "kernel", "neuron", "spatial", "neuron"]
    return [cyc[i % len(cyc)] for i in range(n_b)]


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

class TestConstruction:
    def test_mixed_plan_structure(self):
        m = mobilenet_v2_smoke()
        blocks = group_blocks(m)
        assignment = _seam_assignment(m)
        plan = split_model_mixed(m, np.ones(4), assignment)
        assert plan.mode == "mixed" and plan.is_mixed
        assert plan.assignment == tuple(assignment)
        # spatial conv blocks stay grouped; everything else is singleton
        for grp, mode in zip(plan.block_groups, plan.group_modes):
            if mode == "spatial":
                assert all(m.layers[i].kind in ("conv", "dwconv")
                           for i in grp)
            else:
                assert len(grp) == 1
        # block_modes is aligned with block_groups and uses effective modes
        assert len(plan.block_modes) == len(plan.block_groups)
        assert set(plan.block_modes) <= {"neuron", "kernel", "spatial"}
        # every layer appears in exactly one group, in order
        flat = [i for grp in plan.block_groups for i in grp]
        assert flat == list(range(len(m.layers)))

    def test_spatial_over_nonconv_block_falls_back_to_neuron(self):
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        plan = split_model_mixed(m, np.ones(3), ["spatial"] * n_b)
        # avgpool / linear tail cannot band spatially -> effective neuron
        assert plan.block_modes[-1] == "neuron"
        assert plan.assignment == ("spatial",) * n_b

    def test_uniform_assignment_matches_uniform_plan(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers(4)
        ratings = np.array([1.0, 2.0, 0.5, 1.5])
        n_b = len(group_blocks(m))
        for mode in ("neuron", "kernel", "spatial"):
            uni = simulate(m, ws, ratings,
                           plan=split_model(m, ratings, mode=mode))
            mix = simulate(m, ws, ratings,
                           plan=split_model_mixed(m, ratings, [mode] * n_b))
            assert mix.serial_total_time == pytest.approx(
                uni.serial_total_time, rel=1e-12)
            assert mix.total_bytes == uni.total_bytes
            assert int(mix.peak_ram.max()) == int(uni.peak_ram.max())

    def test_validation_errors(self):
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        with pytest.raises(ValueError, match="assignment length"):
            split_model_mixed(m, np.ones(2), ["neuron"] * (n_b - 1))
        with pytest.raises(ValueError, match="unknown mode"):
            split_model_mixed(m, np.ones(2), ["banded"] * n_b)
        with pytest.raises(ValueError, match="block_workers length"):
            split_model_mixed(m, np.ones(2), ["neuron"] * n_b,
                              block_workers=[None])
        with pytest.raises(ValueError, match="outside cluster"):
            split_model_mixed(m, np.ones(2), ["neuron"] * n_b,
                              block_workers=[(5,)] + [None] * (n_b - 1))
        with pytest.raises(ValueError, match="no positive rating"):
            split_model_mixed(m, np.array([1.0, 0.0]), ["neuron"] * n_b,
                              block_workers=[(1,)] + [None] * (n_b - 1))

    def test_block_worker_subsets_empty_elsewhere(self):
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        subsets = [(0, 1)] + [None] * (n_b - 1)
        plan = split_model_mixed(m, np.ones(4), ["kernel"] * n_b,
                                 block_workers=subsets)
        first = plan.splits[0]
        assert len(first.shards) == 4          # full cluster width everywhere
        assert first.shard_of(2).n_positions == 0
        assert first.shard_of(3).n_positions == 0
        assert sum(s.n_positions for s in first.shards) == m.layers[0].n_out


# ---------------------------------------------------------------------------
# boundary-accounting bugfixes
# ---------------------------------------------------------------------------

class TestCommVolumeAsymmetric:
    def test_producer_sized_upload_array(self):
        """Regression: ``up`` was sized by the *consumer* split's worker
        count but indexed by *producer* worker ids — IndexError as soon as
        the producer side had more workers than the consumer side."""
        m = small_cnn()
        prev = split_layer(m.layers[0], np.ones(3))     # 3 producers
        nxt = split_layer(m.layers[1], np.ones(2))      # 2 consumers
        vol = comm_volume(prev, m.layers[1], nxt)
        assert vol.upload_bytes.shape == (3,)
        assert vol.download_bytes.shape == (2,)
        assert vol.upload_bytes.sum() == m.layers[0].n_out
        # the symmetric direction (fewer producers than consumers) keeps
        # every producer byte in the right slot too
        vol2 = comm_volume(nxt, m.layers[2], split_layer(m.layers[2],
                                                         np.ones(4)))
        assert vol2.upload_bytes.shape == (2,)
        assert vol2.download_bytes.shape == (4,)
        assert vol2.upload_bytes.sum() == m.layers[1].n_out

    def test_first_layer_upload_keeps_consumer_width(self):
        m = small_cnn()
        split = split_layer(m.layers[0], np.ones(3))
        vol = comm_volume(None, m.layers[0], split)
        assert vol.upload_bytes.shape == (3,)
        assert vol.upload_bytes.sum() == 0

    def test_spatial_to_flat_seam_regathers_full_tensor(self):
        """At a spatial->flat seam the producer bands tile the output rows,
        so the seam upload is exactly the full tensor once, and the flat
        consumers download their exact input regions."""
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        plan = split_model_mixed(m, np.ones(3),
                                 ["spatial"] + ["kernel"] * (n_b - 1))
        li = plan.block_groups[1][0]
        prev, cur = plan.splits[li - 1], plan.splits[li]
        assert prev.mode == "spatial" and cur.mode == "kernel"
        vol = comm_volume(prev, cur.layer, cur)
        assert vol.upload_bytes.sum() == m.layers[li - 1].n_out
        regions = worker_input_regions(cur.layer, cur)
        np.testing.assert_array_equal(
            vol.download_bytes,
            [sum(r.n_points for r in regs) for regs in regions])

    def test_flat_to_spatial_seam_downloads_band_windows(self):
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        plan = split_model_mixed(m, np.ones(3),
                                 ["neuron", "spatial"] + ["neuron"]
                                 * (n_b - 2))
        li = plan.block_groups[1][0]
        prev, cur = plan.splits[li - 1], plan.splits[li]
        assert prev.mode == "neuron" and cur.mode == "spatial"
        vol = comm_volume(prev, cur.layer, cur)
        ci, _, wi = cur.layer.in_shape
        for w, shard in enumerate(cur.shards):
            expect = ci * wi * max(shard.in_hi - shard.in_lo, 0)
            assert vol.download_bytes[w] == expect


class TestMemoryWeightItemsize:
    def test_helpers_thread_weight_itemsize(self):
        """Regression: the public peak helpers silently dropped the
        ``weight_itemsize`` plan_memory supports, so a float-weights /
        int8-activations peak query was impossible."""
        plan = split_model(mobilenet_v2_smoke(), np.ones(3))
        mems = plan_memory(plan, itemsize=1, weight_itemsize=4)
        expect_lw = np.stack([mm.per_worker_peak for mm in mems])
        np.testing.assert_array_equal(
            layerwise_peak(plan, 1, weight_itemsize=4), expect_lw)
        np.testing.assert_array_equal(
            peak_ram_per_worker(plan, 1, weight_itemsize=4),
            expect_lw.max(axis=0))
        # wider weights must strictly raise the peak of weight-carrying layers
        assert (peak_ram_per_worker(plan, 1, weight_itemsize=4)
                > peak_ram_per_worker(plan, 1)).all()
        # default stays the old contract: weight_itemsize == itemsize
        np.testing.assert_array_equal(peak_ram_per_worker(plan, 1),
                                      peak_ram_per_worker(plan, 1, 1))


class TestBoundingSlicesContract:
    def _gappy_net(self):
        """stride > kernel: receptive rows/cols of adjacent outputs have
        gaps, so a shard's input region is not contiguous."""
        spec = [dict(kind="conv", out_channels=4, kernel=(2, 2),
                     stride=(3, 3), padding=(0, 0), activation="relu")]
        return trace_sequential(spec, (3, 11, 11),
                                rng=np.random.default_rng(0))

    def test_bbox_overapproximates_gappy_regions(self):
        m = self._gappy_net()
        split = split_layer(m.layers[0], np.ones(2))
        regions = worker_input_regions(m.layers[0], split)
        gaps_seen = False
        for regs in regions:
            for r in regs:
                assert r.bbox_points >= r.n_points
                if r.bbox_points > r.n_points:
                    gaps_seen = True
                cs, rs, wsl = r.bounding_slices()
                assert {c for c, _, _ in r.point_set()} <= set(
                    range(cs.start, cs.stop))
        assert gaps_seen, "stride>kernel net should produce gappy regions"

    def test_byte_accounting_uses_exact_points_not_bbox(self):
        """comm_volume and plan_memory must count n_points (exact), never
        the bbox volume — the two diverge on gappy regions."""
        m = self._gappy_net()
        plan = split_model(m, np.ones(2))
        split = plan.splits[0]
        regions = worker_input_regions(m.layers[0], split)
        exact = np.array([sum(r.n_points for r in regs) for regs in regions])
        bbox = np.array([sum(r.bbox_points for r in regs) for regs in regions])
        assert (bbox > exact).any()
        vol = comm_volume(None, m.layers[0], split)
        np.testing.assert_array_equal(vol.download_bytes, exact)
        np.testing.assert_array_equal(plan_memory(plan)[0].per_worker_in,
                                      exact)


# ---------------------------------------------------------------------------
# executor parity across mode seams
# ---------------------------------------------------------------------------

class TestSeamParity:
    def test_int8_bit_exact_across_all_seams(self, rng):
        """Eager and compiled mixed execution must match the unsplit int8
        oracle bit-for-bit across spatial->kernel, kernel->neuron,
        neuron->spatial and spatial->neuron seams."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, m.input_shape)
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
        plan = split_model_mixed(m, np.array([1.0, 2.0, 0.5, 1.5]),
                                 _seam_assignment(m))
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(eager, oracle)
        compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, oracle)

    def test_float_parity_across_seams(self, rng):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        ref = reference_forward(m, x)
        plan = split_model_mixed(m, np.ones(3), _seam_assignment(m))
        np.testing.assert_allclose(SplitExecutor(plan).run(x), ref,
                                   atol=1e-5)
        np.testing.assert_allclose(CompiledSplitExecutor(plan).run(x), ref,
                                   atol=1e-5)

    def test_int8_bit_exact_with_block_worker_subsets(self, rng):
        """Adjacent blocks on different worker subsets: the seam re-gathers
        across producer/consumer sets of different sizes."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, m.input_shape)
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
        n_b = len(group_blocks(m))
        subsets = [None, (0, 1), (1, 2, 3)] + [None] * (n_b - 3)
        plan = split_model_mixed(m, np.ones(4), _seam_assignment(m),
                                 block_workers=subsets)
        np.testing.assert_array_equal(
            SplitExecutor(plan, qm).run(x, mode="int8"), oracle)

    def test_collect_activations_rejected_for_mixed_spatial(self, rng):
        m = mobilenet_v2_smoke()
        plan = split_model_mixed(m, np.ones(2), _seam_assignment(m))
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        with pytest.raises(ValueError, match="spatial"):
            SplitExecutor(plan).run(x, collect_activations=True)
        # all-flat mixed plans still support calibration collection
        n_b = len(group_blocks(m))
        flat = split_model_mixed(m, np.ones(2), ["kernel"] * n_b)
        out, acts = SplitExecutor(flat).run(x, collect_activations=True)
        assert len(acts) == len(flat.block_groups)


# ---------------------------------------------------------------------------
# DP assignment search
# ---------------------------------------------------------------------------

class TestMixedSearch:
    def test_dp_latency_exact_vs_simulator(self):
        """The DP's predicted latency must equal the serial simulator on the
        assembled plan bit-for-bit — the cost decomposition is exact."""
        m = mobilenet_v2_smoke()
        for n, ratings in ((4, np.ones(4)), (8, None),
                           (3, np.array([2.0, 1.0, 0.5]))):
            ws = _demo_workers(n)
            res = search_mixed_assignment(m, ws, ratings)
            plan = split_model_mixed(
                m, np.ones(n) if ratings is None else ratings,
                res.assignment)
            sim = simulate(m, ws, ratings, plan=plan)
            assert res.predicted_latency_s == pytest.approx(
                sim.serial_total_time, rel=1e-12)
            assert res.predicted_comm_bytes == sim.total_bytes
            assert res.predicted_peak_ram == int(sim.peak_ram.max())

    def test_dp_never_worse_than_any_uniform(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers(8)
        res = search_mixed_assignment(m, ws, minimize="latency")
        for mode in ("neuron", "kernel", "spatial"):
            uni = simulate(m, ws, plan=split_model(m, np.ones(8), mode=mode))
            assert res.predicted_latency_s <= uni.serial_total_time + 1e-12

    def test_dp_strictly_beats_best_uniform_on_demo(self):
        """The acceptance regime: early blocks spatial, late blocks flat
        beats every uniform plan on the heterogeneous demo cluster."""
        m = mobilenet_v2_smoke()
        ws = _demo_workers(8)
        res = search_mixed_assignment(m, ws)
        assert len(set(res.assignment)) > 1   # actually mixes
        best_uni = min(
            simulate(m, ws,
                     plan=split_model(m, np.ones(8),
                                      mode=mode)).serial_total_time
            for mode in ("neuron", "kernel", "spatial"))
        assert res.predicted_latency_s < best_uni

    def test_dp_per_objective_metrics(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers(4)
        by_bytes = search_mixed_assignment(m, ws, minimize="comm_bytes")
        plan = split_model_mixed(m, np.ones(4), by_bytes.assignment)
        assert by_bytes.predicted_score == float(
            simulate(m, ws, plan=plan).total_bytes)
        by_peak = search_mixed_assignment(m, ws, minimize="peak_ram")
        plan = split_model_mixed(m, np.ones(4), by_peak.assignment)
        assert by_peak.predicted_score == float(
            peak_ram_per_worker(plan).max())

    def test_ram_caps_prune_states(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers(4)
        free = search_mixed_assignment(m, ws, minimize="latency")
        capped = search_mixed_assignment(
            m, ws, minimize="latency",
            ram_caps=np.full(4, 12 * 1024))
        plan = split_model_mixed(m, np.ones(4), capped.assignment)
        assert peak_ram_per_worker(plan).max() <= 12 * 1024
        assert capped.predicted_latency_s >= free.predicted_latency_s - 1e-12
        with pytest.raises(ValueError, match="no cap-feasible mode"):
            search_mixed_assignment(m, ws, ram_caps=np.full(4, 64))

    def test_validation(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers(2)
        with pytest.raises(ValueError, match="unknown minimize"):
            search_mixed_assignment(m, ws, minimize="vibes")
        with pytest.raises(ValueError, match="unknown mode"):
            search_mixed_assignment(m, ws, modes=("banded",))
        with pytest.raises(ValueError, match="at least one mode"):
            search_mixed_assignment(m, ws, modes=())
        with pytest.raises(ValueError, match="ratings for"):
            search_mixed_assignment(m, ws, ratings=np.ones(3))


# ---------------------------------------------------------------------------
# planner integration + Plan JSON schema v2
# ---------------------------------------------------------------------------

class TestPlannerMixedAxis:
    def test_objective_accepts_mixed(self):
        assert "mixed" in SEARCH_MODES
        o = Objective(modes=SEARCH_MODES)
        assert o.modes == SEARCH_MODES
        with pytest.raises(ValueError, match="unknown mode"):
            Objective(modes=("mixed", "banded"))

    def test_build_split_plan_mixed_needs_assignment(self):
        m = mobilenet_v2_smoke()
        with pytest.raises(ValueError, match="assignment"):
            build_split_plan(m, np.ones(2), "mixed")
        n_b = len(group_blocks(m))
        plan = build_split_plan(m, np.ones(2), "mixed",
                                assignment=["neuron"] * n_b)
        assert plan.mode == "mixed"

    def test_mixed_candidates_enter_the_search(self):
        m = mobilenet_v2_smoke()
        planner = Planner(m, Cluster.heterogeneous_demo(3))
        obj = Objective(minimize="latency", ram_cap_bytes=512 * 1024,
                        modes=SEARCH_MODES, transports=("serial",))
        plan = planner.plan(obj)
        mixed = [c for c in plan.candidates
                 if c.mode == "mixed" and c.feasible]
        assert mixed, "mixed candidates missing from the search table"
        for c in mixed:
            assert c.assignment is not None
            assert len(c.assignment) == len(group_blocks(m))
        # the DP candidate never loses to a uniform candidate of the same
        # subset/transport on the serial objective it optimizes exactly
        for c in mixed:
            uniforms = [u for u in plan.candidates
                        if u.feasible and u.mode in ("neuron", "kernel")
                        and u.worker_indices == c.worker_indices
                        and u.transport == c.transport]
            for u in uniforms:
                assert c.latency_s <= u.latency_s + 1e-12

    def test_mixed_never_worse_than_uniform_search(self):
        m = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(4)
        for minimize in ("latency", "peak_ram"):
            uni = Planner(m, cluster).plan(
                Objective(minimize=minimize, ram_cap_bytes=512 * 1024))
            mix = Planner(m, cluster).plan(
                Objective(minimize=minimize, ram_cap_bytes=512 * 1024,
                          modes=SEARCH_MODES))
            assert mix.score <= uni.score + 1e-12

    def test_plan_json_v2_round_trip(self):
        m = mobilenet_v2_smoke()
        plan = Planner(m, Cluster.heterogeneous_demo(3)).plan(
            Objective(minimize="latency", ram_cap_bytes=512 * 1024,
                      modes=("mixed",), transports=("serial",)))
        assert plan.mode == "mixed" and plan.assignment is not None
        d = plan.to_dict()
        assert d["version"] == 2
        assert d["assignment"] == list(plan.assignment)
        loaded = Plan.from_json(plan.to_json(), m)
        assert loaded.assignment == plan.assignment
        np.testing.assert_array_equal(loaded.peak_ram, plan.peak_ram)
        cands = {(c.mode, c.assignment) for c in loaded.candidates}
        assert cands == {(c.mode, c.assignment) for c in plan.candidates}
        assert "per-block modes:" in loaded.report()

    def test_legacy_v1_payload_loads_as_uniform(self):
        m = mobilenet_v2_smoke()
        plan = Planner(m, Cluster.heterogeneous_demo(2)).plan(
            Objective(minimize="latency", transports=("serial",)))
        d = plan.to_dict()
        d.pop("assignment")
        d["version"] = 1
        for c in d["candidates"]:
            c.pop("assignment", None)
        legacy = Plan.from_dict(d, m)
        assert legacy.mode == plan.mode
        assert legacy.assignment is None

    def test_mixed_payload_requires_assignment(self):
        m = mobilenet_v2_smoke()
        plan = Planner(m, Cluster.heterogeneous_demo(2)).plan(
            Objective(minimize="latency", modes=("mixed",),
                      transports=("serial",)))
        d = plan.to_dict()
        d["assignment"] = None
        with pytest.raises(ValueError, match="assignment"):
            Plan.from_dict(d, m)


# ---------------------------------------------------------------------------
# hypothesis: random assignments stay bit-exact and well-accounted
# ---------------------------------------------------------------------------

@st.composite
def mixed_cases(draw):
    n_workers = draw(st.integers(2, 4))
    ratings = np.array([draw(st.floats(0.2, 3.0)) for _ in range(n_workers)])
    seed = draw(st.integers(0, 5))
    return n_workers, ratings, seed


@given(mixed_cases())
@settings(max_examples=10, deadline=None)
def test_property_mixed_int8_exact(case):
    """Random per-block assignments on the small net: int8 output stays
    bit-identical to the unsplit oracle across every induced seam."""
    n_workers, ratings, seed = case
    rng = np.random.default_rng(seed)
    m = small_cnn(seed=seed)
    n_b = len(group_blocks(m))
    assignment = [("neuron", "kernel", "spatial")[rng.integers(3)]
                  for _ in range(n_b)]
    qm = _quantized(m, rng, m.input_shape)
    x = rng.standard_normal(m.input_shape).astype(np.float32)
    oracle = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
    plan = split_model_mixed(m, ratings, assignment)
    np.testing.assert_array_equal(
        SplitExecutor(plan, qm).run(x, mode="int8"), oracle)


@given(mixed_cases())
@settings(max_examples=20, deadline=None)
def test_property_mixed_dp_exact(case):
    """DP prediction == serial simulator for every objective, any ratings."""
    n_workers, ratings, seed = case
    m = small_cnn(seed=seed)
    ws = [WorkerParams(f_mhz=150.0 * (w + 1), d_s_per_kb=0.001 * w)
          for w in range(n_workers)]
    res = search_mixed_assignment(m, ws, ratings)
    plan = split_model_mixed(m, ratings, res.assignment)
    sim = simulate(m, ws, ratings, plan=plan)
    assert res.predicted_latency_s == pytest.approx(sim.serial_total_time,
                                                    rel=1e-12)
    assert res.predicted_comm_bytes == sim.total_bytes
    assert res.predicted_peak_ram == int(sim.peak_ram.max())
    # the simulator accepts the mixed plan under SimConfig defaults too
    cfg = SimConfig(transport="pipelined")
    piped = simulate(m, ws, ratings, cfg, plan=plan)
    assert piped.total_time <= sim.serial_total_time + 1e-9
