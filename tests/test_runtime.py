"""Distributed-runtime integration tests: bit-exactness vs the
single-process Session, pipelined dependency structure, and fault
surfacing.

Every async body runs under an outer ``asyncio.wait_for`` — a deadlocked
coordinator fails the test, never hangs the suite (CI additionally runs
these under pytest-timeout).  Fault-injection tests use ``spawn="external"``
with in-loop fake workers speaking the real frame protocol, and every test
asserts the coordinator leaves no orphaned asyncio tasks behind.
"""
import asyncio

import numpy as np
import pytest

from conftest import small_cnn
from repro.api.session import Session
from repro.core.simulator import dependency_edges
from repro.core.splitting import split_model, split_model_mixed
from repro.runtime import protocol
from repro.runtime.coordinator import Coordinator
from repro.runtime.validate import run_distributed

# subprocess workers + localhost sockets: keep the module on one xdist
# worker (serial group) so parallel cells don't oversubscribe the runner
pytestmark = pytest.mark.xdist_group("runtime")

TIMEOUT = 240


def run(coro):
    return asyncio.run(asyncio.wait_for(coro, TIMEOUT))


@pytest.fixture(scope="module")
def model():
    return small_cnn()


@pytest.fixture(scope="module")
def sessions(model):
    """Per-(mode, n) single-process references, shared across tests."""
    cache = {}

    def get(mode, n, precision="int8"):
        key = (mode, n, precision)
        if key not in cache:
            split = split_model(model, np.ones(n), mode=mode)
            cache[key] = (split, Session(split, precision=precision, seed=0))
        return cache[key]

    return get


def _validate(split, sess, **kw):
    return run_distributed(
        split, sess.qmodel, precision=sess.precision, reference=sess,
        spawn="inprocess", n_requests=kw.pop("n_requests", 2), **kw)


# ---------------------------------------------------------------------------
# bit-exactness vs the single-process Session
# ---------------------------------------------------------------------------

class TestBitExact:
    @pytest.mark.parametrize("mode,n", [("spatial", 1), ("spatial", 2),
                                        ("neuron", 2), ("kernel", 2)])
    def test_int8_matches_session(self, sessions, mode, n):
        split, sess = sessions(mode, n)
        rep = _validate(split, sess)
        assert rep.bitexact, f"max |diff| = {rep.max_abs_diff}"

    def test_float_matches_session(self, sessions):
        split, sess = sessions("spatial", 2, "float")
        rep = _validate(split, sess, n_requests=1)
        assert rep.bitexact

    def test_mixed_plan_matches_session(self, model):
        from repro.core.fusion import group_blocks
        n_b = len(group_blocks(model))
        assignment = [("spatial", "neuron")[i % 2] for i in range(n_b)]
        split = split_model_mixed(model, np.ones(2), assignment)
        sess = Session(split, precision="int8", seed=0)
        rep = _validate(split, sess, n_requests=1)
        assert rep.bitexact


# ---------------------------------------------------------------------------
# pipelined schedule structure
# ---------------------------------------------------------------------------

class TestSchedule:
    def test_measured_edges_superset_of_simulator(self, sessions):
        split, sess = sessions("spatial", 2)
        rep = _validate(split, sess)
        assert dependency_edges(split) <= rep.measured_edges
        assert rep.edges_superset and not rep.missing_edges

    def test_timeline_in_simulator_schema(self, sessions):
        split, sess = sessions("spatial", 2)
        rep = _validate(split, sess, n_requests=1)
        tl = rep.timeline
        assert tl.n_workers == split.n_workers
        kinds = {e.kind for e in tl.events}
        assert kinds == {"download", "compute", "upload"}
        for e in tl.events:
            assert 0 <= e.start_s <= e.end_s <= tl.makespan_s + 1e-6
        # transfer events carry wire bytes; simulator helpers work unchanged
        assert all(e.nbytes > 0 for e in tl.events if e.kind != "compute")
        assert float(tl.compute_busy_s.sum()) > 0

    def test_clean_seam_waits_only_on_boundary_deps(self):
        """The pipelined realization: at a clean spatial seam a consumer
        band waits only on its row-overlap producers — strictly fewer than
        all of them — and the output is still bit-exact, proving the
        fine-grained dependency wiring is sufficient."""
        from repro.core.reinterpret import trace_sequential
        from repro.core.simulator import pipelined_dependencies
        spec = [dict(kind="conv", out_channels=4, kernel=(3, 3),
                     stride=(1, 1), padding=(1, 1), activation="relu")] * 3
        model = trace_sequential(spec, (3, 16, 16),
                                 rng=np.random.default_rng(1))
        # layer granularity, no residuals: every seam is spatial->spatial
        split = split_model(model, np.ones(3), mode="spatial", fused=False)
        deps = pipelined_dependencies(split)
        fine = [(b, w) for b, boundary in enumerate(deps)
                for w, producers in enumerate(boundary)
                if 0 < len(producers) < len(
                    {p for ps in boundary for p in ps})]
        assert fine, "expected at least one strict-subset dependency"
        sess = Session(split, precision="int8", seed=0)
        rep = _validate(split, sess)
        assert rep.bitexact          # waiting on the subset was enough
        assert rep.edges_superset and not rep.missing_edges


# ---------------------------------------------------------------------------
# process spawn + api surface
# ---------------------------------------------------------------------------

class TestProcessSpawn:
    def test_subprocess_workers_bitexact(self, sessions, tmp_path):
        split, sess = sessions("neuron", 1)
        rep = run_distributed(split, sess.qmodel, precision="int8",
                              reference=sess, spawn="process",
                              n_requests=1, log_dir=str(tmp_path))
        assert rep.bitexact and rep.edges_superset
        assert (tmp_path / "worker0.log").exists()


class TestApiSurface:
    def test_session_distributed_coordinator(self, sessions):
        split, sess = sessions("spatial", 2)

        async def main():
            async with sess.distributed(spawn="inprocess") as coord:
                x = np.random.default_rng(3).standard_normal(
                    sess.model.input_shape).astype(np.float32)
                y = await coord.infer(x)
                return np.asarray(y), coord.last_timeline
        y, tl = run(main())
        np.testing.assert_array_equal(y, sess.run(
            np.random.default_rng(3).standard_normal(
                sess.model.input_shape).astype(np.float32)))
        assert tl is not None and tl.events

    def test_worker_geometry_summary_is_json(self, sessions):
        import json
        from repro.runtime.shards import worker_geometry_summary
        split, _ = sessions("spatial", 2)
        geo = worker_geometry_summary(split)
        assert len(geo) == 2
        json.dumps(geo)             # serializable end-to-end
        assert all(g["weight_bytes"] == split.worker_weight_bytes(g["worker"])
                   for g in geo)
        covered = {s["segment"] for g in geo for s in g["segments"]}
        local = {gi for gi, idxs in enumerate(split.block_groups)
                 if split.model.layers[idxs[-1]].kind == "avgpool"}
        assert covered == set(range(len(split.block_groups))) - local


# ---------------------------------------------------------------------------
# fault injection: descriptive errors, no hangs, no orphaned tasks
# ---------------------------------------------------------------------------

@pytest.fixture()
def fault_env(model):
    """1-worker neuron plan + qmodel for external fake-worker tests."""
    split = split_model(model, np.ones(1), mode="neuron")
    sess = Session(split, precision="int8", seed=0)
    return split, sess.qmodel


async def _fake_hello(host, port):
    r, w = await asyncio.open_connection(host, port)
    await protocol.write_frame(w, "hello", {"worker": 0})
    await protocol.read_frame(r)        # setup frame
    return r, w


async def _drive(split, qmodel, fake, expect, *, setup_ok, **coord_kw):
    """Start a coordinator against one fake worker and assert the failure
    surfaces as a RuntimeError matching ``expect`` — at start() when
    ``setup_ok`` is False, else at infer()."""
    before = {t for t in asyncio.all_tasks() if not t.done()}
    coord = Coordinator(split, qmodel, spawn="external",
                        setup_timeout=30, **coord_kw)
    fk = None
    try:
        start = asyncio.ensure_future(coord.start())
        while coord._server is None:
            await asyncio.sleep(0.01)
        fk = asyncio.ensure_future(fake(coord.host, coord.port))
        if not setup_ok:
            with pytest.raises(RuntimeError, match=expect):
                await start
            return
        await start
        x = np.zeros(split.model.input_shape, np.float32)
        with pytest.raises(RuntimeError, match=expect):
            await coord.infer(x)
    finally:
        if fk is not None:
            fk.cancel()
            await asyncio.gather(fk, return_exceptions=True)
        await coord.close()
        await asyncio.sleep(0.05)
        leaked = {t for t in asyncio.all_tasks()
                  if not t.done()} - before - {asyncio.current_task()}
        assert not leaked, f"orphaned tasks: {leaked}"


class TestFaultInjection:
    def test_truncated_frame_during_setup(self, fault_env):
        split, qm = fault_env

        async def fake(host, port):
            r, w = await _fake_hello(host, port)
            w.write(b"\x40\x00\x00\x00partial")   # claims 64B, sends 7
            await w.drain()
            w.close()

        run(_drive(split, qm, fake, r"worker 0.*truncated frame",
                   setup_ok=False))

    def test_worker_dies_mid_upload(self, fault_env):
        split, qm = fault_env

        async def fake(host, port):
            r, w = await _fake_hello(host, port)
            await protocol.write_frame(w, "ready",
                                       {"worker": 0, "setup_s": 0.0})
            await protocol.read_frame(r)          # infer_input
            wire = protocol.encode_frame(
                "result", {"seq": 0, "gi": 0, "worker": 0},
                {"y": np.zeros(64, np.int8)})
            w.write(wire[:len(wire) // 2])        # half the frame, then die
            await w.drain()
            w.close()

        run(_drive(split, qm, fake, r"worker 0.*truncated frame",
                   setup_ok=True, request_timeout=20))

    def test_slow_worker_hits_recv_timeout(self, fault_env):
        split, qm = fault_env

        async def fake(host, port):
            r, w = await _fake_hello(host, port)
            await protocol.write_frame(w, "ready",
                                       {"worker": 0, "setup_s": 0.0})
            while True:                           # heartbeat but never answer
                await asyncio.sleep(0.1)
                await protocol.write_frame(w, "heartbeat", {"worker": 0})

        run(_drive(split, qm, fake, r"worker 0 timed out on segment 0",
                   setup_ok=True, request_timeout=0.5, max_retries=1))

    def test_garbage_frame_fails_setup(self, fault_env):
        split, qm = fault_env

        async def fake(host, port):
            r, w = await _fake_hello(host, port)
            w.write(b"\x08\x00\x00\x00NOTJSON!")
            await w.drain()
            await asyncio.sleep(10)

        run(_drive(split, qm, fake, r"worker 0", setup_ok=False))

    def test_unidentified_peer_rejected(self, fault_env):
        split, qm = fault_env

        async def fake(host, port):
            r, w = await asyncio.open_connection(host, port)
            await protocol.write_frame(w, "hello", {"worker": 99})
            await asyncio.sleep(10)

        run(_drive(split, qm, fake, r"unidentified peer|setup failed",
                   setup_ok=False))
