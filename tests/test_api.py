"""Coordinator facade tests: Cluster validation/round-trip, Planner search
properties (RAM-cap safety, best-feasible preference, InfeasibleError with
the binding constraint), and Plan serialization round-trip.

Planner calls on the conftest small_cnn are cheap (every candidate is costed
analytically — no jit); the MobileNetV2-smoke acceptance test pins the
planner against the hand-picked compare_modes baseline.
"""
import json

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from conftest import small_cnn
from repro.api import (Cluster, ClusterError, InfeasibleError, Objective,
                       Plan, PlanCandidate, Planner)
from repro.core import (WorkerParams, compare_modes, measured_kc,
                        peak_ram_per_worker, ratings_for, simulated_k1)
from repro.models import mobilenet_v2_smoke


# ---------------------------------------------------------------------------
# Cluster
# ---------------------------------------------------------------------------

class TestCluster:
    def test_validates_workers(self):
        with pytest.raises(ClusterError):
            Cluster(())
        with pytest.raises(ClusterError):
            Cluster((WorkerParams(f_mhz=0),))
        with pytest.raises(ClusterError):
            Cluster((WorkerParams(b_kb_s=-1),))
        with pytest.raises(ClusterError):
            Cluster((WorkerParams(d_s_per_kb=-0.1),))
        with pytest.raises(ClusterError):
            Cluster((WorkerParams(ram_bytes=0),))

    def test_container_protocol(self):
        c = Cluster.homogeneous(3, f_mhz=450)
        assert len(c) == c.n_workers == 3
        assert all(w.f_mhz == 450 for w in c)
        assert c[1].f_mhz == 450
        assert c.max_f_mhz == 450

    def test_accepts_list_and_freezes_to_tuple(self):
        c = Cluster([WorkerParams(), WorkerParams(f_mhz=150)])
        assert isinstance(c.workers, tuple) and len(c) == 2

    def test_heterogeneous_demo_cycles(self):
        c = Cluster.heterogeneous_demo(10)
        assert len(c) == 10
        assert c[8].f_mhz == c[0].f_mhz  # cycled

    def test_subset(self):
        c = Cluster.heterogeneous_demo(8)
        s = c.subset([0, 3, 5])
        assert len(s) == 3
        assert s[1] == c[3]
        with pytest.raises(ClusterError):
            c.subset([11])

    def test_json_round_trip(self, tmp_path):
        c = Cluster.heterogeneous_demo(4)
        # via string
        assert Cluster.from_json(c.to_json()) == c
        # via file
        p = tmp_path / "cluster.json"
        c.to_json(p)
        assert Cluster.from_json(p) == c

    def test_from_json_rejects_garbage(self):
        with pytest.raises(ClusterError):
            Cluster.from_json('{"workers": [{"nope": 1}]}')
        with pytest.raises(ClusterError):
            Cluster.from_json('{"not json')


# ---------------------------------------------------------------------------
# Objective
# ---------------------------------------------------------------------------

class TestObjective:
    def test_validation(self):
        with pytest.raises(ValueError):
            Objective(minimize="speed")
        with pytest.raises(ValueError):
            Objective(modes=())
        with pytest.raises(ValueError):
            Objective(modes=("banded",))
        with pytest.raises(ValueError):
            Objective(max_workers=0)
        with pytest.raises(ValueError):
            Objective(ram_cap_bytes=-5)

    def test_round_trip(self):
        o = Objective(minimize="peak_ram", ram_cap_bytes=4096,
                      max_workers=3, modes=("neuron", "spatial"))
        assert Objective.from_dict(o.to_dict()) == o


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cnn():
    return small_cnn()


@pytest.fixture(scope="module")
def planner(cnn):
    return Planner(cnn, Cluster.heterogeneous_demo(4))


class TestPlanner:
    def test_plan_is_feasible_and_scored(self, planner):
        plan = planner.plan(Objective(ram_cap_bytes=512 * 1024))
        assert plan.mode in ("neuron", "kernel", "spatial")
        assert plan.max_peak_ram <= 512 * 1024
        assert plan.latency_s > 0 and np.isfinite(plan.score)
        assert len(plan.ratings) == plan.n_workers == len(plan.worker_indices)
        # the stored peak matches a recomputation over the stored split
        assert np.array_equal(plan.peak_ram, peak_ram_per_worker(plan.split))

    def test_prefers_best_feasible_candidate(self, planner):
        obj = Objective(ram_cap_bytes=512 * 1024)
        plan = planner.plan(obj)
        feasible = [c for c in planner.candidates(obj) if c.feasible]
        assert feasible
        assert plan.score == min(c.score for c in feasible)

    def test_candidate_table_covers_search_space(self, planner):
        cands = planner.candidates(Objective())
        # 4 sizes x (neuron + kernel + spatial/block + spatial/layer)
        #         x (serial + pipelined), infeasible points collapsed to one
        # transport-independent entry each
        feasible = [c for c in cands if c.feasible]
        infeasible = [c for c in cands if not c.feasible]
        assert len(feasible) + 2 * len(infeasible) == 4 * 4 * 2
        assert all(c.transport == "*" for c in infeasible)
        assert all(isinstance(c, PlanCandidate) for c in cands)

    def test_max_workers_caps_subsets(self, planner):
        obj = Objective(max_workers=2)
        assert all(len(c.worker_indices) <= 2
                   for c in planner.candidates(obj))
        assert planner.plan(obj).n_workers <= 2

    def test_modes_restrict_search(self, planner):
        plan = planner.plan(Objective(modes=("kernel",)))
        assert plan.mode == "kernel"

    def test_minimize_peak_ram(self, planner):
        obj = Objective(minimize="peak_ram")
        plan = planner.plan(obj)
        feasible = [c for c in planner.candidates(obj) if c.feasible]
        assert plan.max_peak_ram == min(c.max_peak_ram for c in feasible)

    def test_minimize_comm_bytes(self, planner):
        obj = Objective(minimize="comm_bytes")
        plan = planner.plan(obj)
        feasible = [c for c in planner.candidates(obj) if c.feasible]
        assert plan.comm_bytes == min(c.comm_bytes for c in feasible)

    def test_infeasible_ram_cap_raises_with_binding_constraint(self, planner):
        with pytest.raises(InfeasibleError) as ei:
            planner.plan(Objective(ram_cap_bytes=64))
        assert ei.value.binding_constraint == "ram_cap"
        assert ei.value.details["ram_cap_bytes"] == 64
        assert "ram_cap" in str(ei.value)

    def test_infeasible_flash_cap_raises(self, cnn):
        # tiny flash on every worker: weights cannot fit anywhere
        cluster = Cluster.homogeneous(3, flash_bytes=8)
        with pytest.raises(InfeasibleError) as ei:
            Planner(cnn, cluster).plan(Objective())
        assert ei.value.binding_constraint == "flash_cap"

    def test_report_mentions_selection(self, planner):
        plan = planner.plan(Objective(ram_cap_bytes=512 * 1024))
        rep = plan.report()
        assert "<- selected" in rep and plan.mode in rep
        assert f"{plan.n_workers}/" in rep


class TestPlannerAcceptance:
    """ISSUE acceptance: over MobileNetV2-smoke with the 8-worker
    heterogeneous cluster, the planner must be at least as good (simulated
    latency) as the best hand-picked compare_modes row, and every plan must
    pass the RAM-cap feasibility check."""

    @pytest.fixture(scope="class")
    def smoke_plan(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(8)
        plan = Planner(model, cluster).plan(
            Objective(minimize="latency", ram_cap_bytes=512 * 1024))
        return model, cluster, plan

    def test_at_least_as_good_as_compare_modes(self, smoke_plan):
        model, cluster, plan = smoke_plan
        k1 = simulated_k1(model, cluster.max_f_mhz)
        kc = measured_kc(model, len(cluster))
        ratings = ratings_for(list(cluster.workers), k1, kc)
        best_row = min(
            r.total_time_s
            for r in compare_modes(model, list(cluster.workers),
                                   ratings).values())
        assert plan.latency_s <= best_row + 1e-12

    def test_plan_respects_ram_cap(self, smoke_plan):
        _, _, plan = smoke_plan
        assert peak_ram_per_worker(plan.split).max() <= 512 * 1024


# ---------------------------------------------------------------------------
# Plan serialization
# ---------------------------------------------------------------------------

class TestPlanSerialization:
    def test_json_round_trip(self, cnn, planner, tmp_path):
        plan = planner.plan(Objective(ram_cap_bytes=512 * 1024))
        text = plan.to_json(tmp_path / "plan.json")
        loaded = Plan.from_json(tmp_path / "plan.json", cnn)
        assert json.loads(text) == json.loads(loaded.to_json())
        assert loaded.mode == plan.mode and loaded.fusion == plan.fusion
        assert loaded.worker_indices == plan.worker_indices
        assert np.allclose(loaded.ratings, plan.ratings)
        assert loaded.latency_s == plan.latency_s
        assert loaded.objective == plan.objective
        assert np.array_equal(loaded.peak_ram, plan.peak_ram)
        assert len(loaded.candidates) == len(plan.candidates)
        # the re-derived split plan is usable: same per-worker peak
        assert np.array_equal(peak_ram_per_worker(loaded.split),
                              peak_ram_per_worker(plan.split))

    def test_rejects_wrong_model(self, planner):
        plan = planner.plan(Objective(ram_cap_bytes=512 * 1024))
        other = small_cnn(seed=1)  # same shape but different weights is OK...
        data = json.loads(plan.to_json())
        data["model"]["n_layers"] += 1  # ...a structural mismatch is not
        with pytest.raises(ValueError, match="mismatch"):
            Plan.from_dict(data, other)

    def test_rejects_non_plan_payload(self, cnn):
        with pytest.raises(ValueError, match="not a serialized"):
            Plan.from_dict({"kind": "something-else"}, cnn)

    def test_json_is_strict_with_infeasible_candidates(self, cnn, planner):
        """Infeasible candidates carry NaN sentinels internally; the JSON
        payload must map them to null (strict RFC 8259 — no `NaN` tokens)."""
        # a cap tight enough that some (small-subset) candidates are
        # infeasible but at least one fits (small_cnn peaks are ~1-2 KB)
        obj = Objective(ram_cap_bytes=1500)
        plan = planner.plan(obj)
        assert any(not c.feasible for c in plan.candidates)
        text = plan.to_json()
        assert "NaN" not in text
        json.loads(text)  # strict-parses
        loaded = Plan.from_json(text, cnn)
        reloaded_infeasible = [c for c in loaded.candidates if not c.feasible]
        assert reloaded_infeasible
        assert all(np.isnan(c.score) for c in reloaded_infeasible)


# ---------------------------------------------------------------------------
# fusion granularity (build_split_plan -> core split_model(fused=...))
# ---------------------------------------------------------------------------

class TestFusionGranularity:
    def test_layer_fusion_builds_singleton_blocks(self, cnn):
        from repro.api import build_split_plan
        ratings = np.asarray([2.0, 1.0, 1.5])
        blocked = build_split_plan(cnn, ratings, "spatial", "block")
        layered = build_split_plan(cnn, ratings, "spatial", "layer")
        assert all(len(b) == 1 for b in layered.block_groups)
        assert any(len(b) > 1 for b in blocked.block_groups)
        with pytest.raises(ValueError, match="fusion"):
            build_split_plan(cnn, ratings, "spatial", "banded")

    def test_layer_fusion_plan_executes_bitexact(self, cnn, rng):
        """An unfused spatial plan must execute like any other: compiled ==
        eager bit-for-bit in int8, float matches the monolithic reference."""
        from repro.api import Session, build_split_plan
        from repro.core import (CompiledSplitExecutor, SplitExecutor,
                                reference_forward)
        split = build_split_plan(cnn, np.asarray([2.0, 1.0, 1.5]),
                                 "spatial", "layer")
        x = rng.standard_normal(cnn.input_shape).astype(np.float32)
        session = Session(split, precision="int8", seed=0, max_batch=1)
        out = session.run(x)
        eager = SplitExecutor(split, session.qmodel).run(x, mode="int8")
        compiled = CompiledSplitExecutor(split, session.qmodel).run(
            x, mode="int8")
        assert np.array_equal(out, eager)
        assert np.array_equal(out, compiled)
        ref = reference_forward(cnn, x)
        flt = Session(split, precision="float", max_batch=1).run(x)
        assert np.max(np.abs(flt - ref)) < 1e-4


# ---------------------------------------------------------------------------
# hypothesis properties (skip cleanly when hypothesis is unavailable)
# ---------------------------------------------------------------------------

@given(cap_kb=st.integers(min_value=1, max_value=64),
       n_workers=st.integers(min_value=1, max_value=5))
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_plan_never_exceeds_ram_cap(cap_kb, n_workers):
    """Planner.plan either respects the RAM cap on every worker or raises
    InfeasibleError — never a silent over-budget plan."""
    model = small_cnn()
    planner = Planner(model, Cluster.heterogeneous_demo(n_workers))
    cap = cap_kb * 1024
    try:
        plan = planner.plan(Objective(ram_cap_bytes=cap))
    except InfeasibleError as e:
        assert e.binding_constraint in ("ram_cap", "flash_cap")
        return
    peak = peak_ram_per_worker(plan.split)
    assert peak.max() <= cap
    assert plan.max_peak_ram <= cap


@given(minimize=st.sampled_from(["latency", "comm_bytes", "peak_ram"]),
       n_workers=st.integers(min_value=1, max_value=4))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_plan_picks_min_score_feasible(minimize, n_workers):
    """When several candidates fit, the planner returns the lowest-scoring
    feasible one (e.g. prefers a lower-latency mode that also fits)."""
    model = small_cnn()
    planner = Planner(model, Cluster.heterogeneous_demo(n_workers))
    obj = Objective(minimize=minimize, ram_cap_bytes=512 * 1024)
    plan = planner.plan(obj)
    feasible = [c for c in planner.candidates(obj) if c.feasible]
    assert feasible and plan.score == min(c.score for c in feasible)
