"""Unit + property tests for the fine-grained splitting (Alg. 1/2)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.reinterpret import LayerSpec, conv_out_hw
from repro.core.splitting import (partition_bounds, split_conv_layer,
                                  split_linear_layer, split_model)
from conftest import small_cnn


def _conv_layer(c_in=4, c_out=6, hw=8, k=3, stride=1):
    rng = np.random.default_rng(0)
    oh, ow = conv_out_hw((hw, hw), (k, k), (stride, stride), (1, 1))
    w = rng.standard_normal((c_out, c_in, k, k)).astype(np.float32)
    return LayerSpec("conv", "conv", (c_in, hw, hw), (c_out, oh, ow), w,
                     np.zeros(c_out, np.float32), stride=(stride, stride),
                     padding=(1, 1))


class TestPartitionBounds:
    def test_exact_partition(self):
        b = partition_bounds(100, np.array([1.0, 1.0, 1.0, 1.0]))
        assert b[0] == 0 and b[-1] == 100
        assert np.all(np.diff(b) >= 0)

    @given(total=st.integers(0, 10_000),
           ratings=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=16))
    @settings(max_examples=200, deadline=None)
    def test_partition_properties(self, total, ratings):
        r = np.asarray(ratings)
        if r.sum() <= 0:
            r = r + 1.0
        b = partition_bounds(total, r)
        # exact cover, monotone, proportional within 1 position per worker
        assert b[0] == 0 and b[-1] == total
        assert np.all(np.diff(b) >= 0)
        shares = np.diff(b)
        exact = r / r.sum() * total
        assert np.all(np.abs(shares - exact) <= len(r))

    def test_proportionality(self):
        b = partition_bounds(1000, np.array([3.0, 1.0]))
        assert abs((b[1] - b[0]) - 750) <= 1

    def test_zero_rating_worker_gets_nothing(self):
        b = partition_bounds(100, np.array([1.0, 0.0, 1.0]))
        assert b[2] - b[1] == 0


class TestConvSplit(object):
    def test_every_position_assigned_once(self):
        layer = _conv_layer()
        sp = split_conv_layer(layer, np.array([1.0, 2.0, 1.0]))
        covered = []
        for sh in sp.shards:
            covered.extend(range(sh.start, sh.stop))
        assert covered == list(range(layer.n_out))

    def test_kernel_assignment_matches_positions(self):
        """Alg. 1: a worker holds kernel c iff it owns a position of
        channel c; usage counts sum to the positions owned."""
        layer = _conv_layer(c_out=5, hw=6)
        sp = split_conv_layer(layer, np.array([1.0, 1.0, 3.0]))
        hw = layer.out_shape[1] * layer.out_shape[2]
        for sh in sp.shards:
            chans = {j // hw for j in range(sh.start, sh.stop)}
            assert set(sh.kernel_usage) == chans
            assert sum(sh.kernel_usage.values()) == sh.n_positions

    def test_weight_fragment_bytes(self):
        layer = _conv_layer(c_in=4, c_out=6, k=3)
        sp = split_conv_layer(layer, np.array([1.0]))
        # single worker holds all kernels: 6*(4*3*3) weights + 6 biases
        assert sp.shards[0].weight_bytes == 6 * 36 + 6

    @given(c=st.integers(1, 8), hw=st.integers(2, 8),
           n=st.integers(1, 6), seed=st.integers(0, 100))
    @settings(max_examples=50, deadline=None)
    def test_random_split_covers(self, c, hw, n, seed):
        rng = np.random.default_rng(seed)
        layer = _conv_layer(c_out=c, hw=hw)
        ratings = rng.uniform(0.1, 5.0, n)
        sp = split_conv_layer(layer, ratings)
        total = sum(sh.n_positions for sh in sp.shards)
        assert total == layer.n_out
        # contiguous ascending
        pos = 0
        for sh in sp.shards:
            assert sh.start == pos
            pos = sh.stop


class TestLinearSplit:
    def test_column_split(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((16, 10)).astype(np.float32)
        layer = LayerSpec("fc", "linear", (16, 1, 1), (10, 1, 1), w,
                          np.zeros(10, np.float32))
        sp = split_linear_layer(layer, np.array([1.0, 1.0]))
        assert sp.shards[0].n_positions + sp.shards[1].n_positions == 10
        # each column counted once
        cols = set()
        for sh in sp.shards:
            cols |= set(sh.kernel_usage)
        assert cols == set(range(10))

    def test_fragment_bytes(self):
        w = np.zeros((16, 10), np.float32)
        layer = LayerSpec("fc", "linear", (16, 1, 1), (10, 1, 1), w,
                          np.zeros(10, np.float32))
        sp = split_linear_layer(layer, np.array([1.0]))
        assert sp.shards[0].weight_bytes == 10 * 16 + 10


def test_split_model_worker_totals():
    from repro.core.reinterpret import layer_macs
    m = small_cnn()
    plan = split_model(m, [2.0, 1.0, 1.0])
    total_macs = sum(plan.worker_macs(w) for w in range(3))
    # avgpool stays coordinator-side (zero worker shards) by design
    expected = sum(layer_macs(lyr) for lyr in m.layers if lyr.kind != "avgpool")
    assert abs(total_macs - expected) <= len(m.layers) * 3
    # higher-rated worker gets more work
    assert plan.worker_macs(0) > plan.worker_macs(1) * 1.3
