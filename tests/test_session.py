"""Session (micro-batched serving) tests: bucket-padded ``submit_many`` must
be bit-identical to ``run_batch`` in int8, the submit/flush queue must
fulfill tickets in order, compiled buckets must be reusable across shapes
(the UnexpectedTracerError regression), and stats must account every
request/pad."""
import numpy as np
import pytest

from conftest import small_cnn
from repro.api import Cluster, Objective, Planner, Session
from repro.core import (CompiledSplitExecutor, SplitExecutor,
                        calibrate_scales, quantize_model, reference_forward,
                        split_model)


@pytest.fixture(scope="module")
def model():
    return small_cnn()


@pytest.fixture(scope="module")
def qmodel(model):
    rng = np.random.default_rng(0)
    calib = [rng.standard_normal(model.input_shape).astype(np.float32)
             for _ in range(3)]
    scales = calibrate_scales(
        model, calib,
        lambda m, x: reference_forward(m, x, collect_activations=True)[1])
    return quantize_model(model, scales)


@pytest.fixture(scope="module")
def plan(model):
    return Planner(model, Cluster.heterogeneous_demo(3)).plan(
        Objective(ram_cap_bytes=512 * 1024))


@pytest.fixture(scope="module")
def xs(model):
    rng = np.random.default_rng(1)
    return np.stack([rng.standard_normal(model.input_shape).astype(np.float32)
                     for _ in range(7)])


class TestSessionServing:
    def test_submit_many_matches_run_batch_bitexact_int8(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        out = session.submit_many(xs)          # 7 requests -> buckets 4 + 4(pad 1)
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs, mode="int8")
        assert out.dtype == ref.dtype == np.int8
        assert np.array_equal(out, ref)

    def test_run_matches_eager_oracle_int8(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=2)
        eager = SplitExecutor(plan.split, qmodel)
        assert np.array_equal(session.run(xs[0]),
                              eager.run(xs[0], mode="int8"))

    def test_float_precision_close_to_reference(self, plan, model, xs):
        session = Session(plan, precision="float", max_batch=4)
        out = session.submit_many(xs[:3])
        for i in range(3):
            ref = reference_forward(model, xs[i])
            assert np.max(np.abs(out[i] - ref)) < 1e-4

    def test_bucket_reuse_across_shapes(self, plan, qmodel, xs):
        """Regression: the compiled engine must survive serving at several
        batch shapes (constants created inside one trace used to leak into
        the next as tracers)."""
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4,
                          buckets=(1, 2, 4))
        a = session.submit_many(xs[:1])     # bucket 1
        b = session.submit_many(xs[:3])     # bucket 4 (pad 1)
        c = session.submit_many(xs[:2])     # bucket 2
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:3], mode="int8")
        assert np.array_equal(a[0], ref[0])
        assert np.array_equal(b, ref)
        assert np.array_equal(c, ref[:2])

    def test_submit_flush_tickets(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        tickets = [session.submit(x) for x in xs[:3]]
        assert session.n_pending == 3
        assert not tickets[0].done()
        served = session.flush()
        assert served == 3 and session.n_pending == 0
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:3], mode="int8")
        for t, r in zip(tickets, ref):
            assert t.done() and np.array_equal(t.result(), r)

    def test_ticket_result_flushes_on_demand(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        t = session.submit(xs[0])
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:1], mode="int8")[0]
        assert np.array_equal(t.result(), ref)   # implicit flush
        assert session.n_pending == 0

    def test_stats_account_requests_and_padding(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4,
                          buckets=(1, 2, 4))
        session.submit_many(xs)                  # 7 -> dispatches of 4 and 4(pad 1)
        s = session.stats()
        assert s.requests == 7
        assert s.batches == 2
        assert s.padded == 1
        assert s.wall_s > 0 and s.throughput_rps > 0
        assert sum(s.per_bucket.values()) == s.batches
        # deployment context flows from the plan into the stats
        assert s.transport == plan.transport
        assert s.predicted_overlap_saved_s == plan.overlap_saved_s

    def test_bare_splitplan_session_defaults_to_serial(self, model, qmodel):
        session = Session(split_model(model, np.ones(2)), precision="int8",
                          qmodel=qmodel)
        s = session.stats()
        assert s.transport == "serial"
        assert s.predicted_overlap_saved_s == 0.0

    def test_auto_calibration_path(self, plan, xs):
        """int8 without an explicit qmodel: Session calibrates itself and
        still serves deterministically."""
        s1 = Session(plan, precision="int8", seed=7)
        s2 = Session(plan, precision="int8", seed=7)
        assert np.array_equal(s1.run(xs[0]), s2.run(xs[0]))


class TestSessionValidation:
    def test_rejects_bad_precision(self, plan):
        with pytest.raises(ValueError, match="precision"):
            Session(plan, precision="fp16")

    def test_rejects_bad_shapes(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel)
        with pytest.raises(ValueError, match="shape"):
            session.run(xs[0][:, :4, :])
        with pytest.raises(ValueError, match="shape"):
            session.submit_many(xs[:, :, :4, :])

    def test_rejects_bad_plan_type(self):
        with pytest.raises(TypeError):
            Session(object(), precision="float")

    def test_accepts_bare_split_plan(self, model, qmodel, xs):
        """Benchmarks/tests can wrap a core SplitPlan directly."""
        split = split_model(model, np.asarray([2.0, 1.0]))
        session = Session(split, precision="int8", qmodel=qmodel, max_batch=2)
        ref = CompiledSplitExecutor(split, qmodel).run_batch(xs[:2],
                                                             mode="int8")
        assert np.array_equal(session.submit_many(xs[:2]), ref)

    def test_empty_batch_keeps_output_shape_and_dtype(self, plan, qmodel,
                                                      model, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=2)
        empty = session.submit_many(xs[:0])
        assert empty.shape == (0, *model.out_shape)
        assert empty.dtype == np.int8
        # concatenates cleanly with real outputs
        real = session.submit_many(xs[:1])
        assert np.concatenate([empty, real]).shape == (1, *model.out_shape)
        sf = Session(plan, precision="float", max_batch=2)
        assert sf.submit_many(xs[:0]).dtype == np.float32

    def test_warmup_compiles_buckets(self, plan, qmodel):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=2,
                          buckets=(1, 2))
        session.warmup()
        assert session.stats().requests == 0  # warmup is not traffic


class TestTicketHardening:
    def test_result_with_timeout_fulfills(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        t = session.submit(xs[0])
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:1], mode="int8")[0]
        assert np.array_equal(t.result(timeout=60.0), ref)
        assert t.exception() is None
        assert t.completed_at > 0          # fulfillment stamp for latency

    def test_detached_ticket_timeout_raises(self):
        from repro.api import Ticket
        t = Ticket()                        # no session to flush
        with pytest.raises(TimeoutError, match="unfulfilled"):
            t.result(timeout=0.02)
        assert not t.done()
        assert np.isnan(t.completed_at)     # still pending: no stamp

    def test_poisoned_dispatch_rejects_all_pending_tickets(
            self, plan, qmodel, xs, monkeypatch):
        """Regression: a raising dispatch mid-batch must reject every ticket
        of that flush with the exception — callers blocked on ``result()``
        get the error instead of hanging forever."""
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        tickets = [session.submit(x) for x in xs[:3]]
        boom = RuntimeError("poisoned input blew up the batch")
        monkeypatch.setattr(session.engine, "run_batch_async",
                            lambda *a, **k: (_ for _ in ()).throw(boom))
        with pytest.raises(RuntimeError, match="poisoned"):
            session.flush()
        for t in tickets:
            assert t.done()
            assert t.exception() is boom
            with pytest.raises(RuntimeError, match="poisoned"):
                t.result(timeout=1.0)
        # the queue was consumed, not wedged: serving resumes after the fix
        monkeypatch.undo()
        assert session.n_pending == 0
        good = session.submit(xs[0])
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:1], mode="int8")[0]
        assert np.array_equal(good.result(timeout=60.0), ref)

    def test_rolling_percentile_stats_fields(self, plan, qmodel, xs):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4,
                          buckets=(1, 2, 4))
        s0 = session.stats()
        assert np.isnan(s0.latency_p50_s) and np.isnan(s0.latency_p99_s)
        assert s0.per_bucket_p50_s == {}
        session.submit_many(xs)             # 7 -> buckets 4 + 4(pad 1)
        s = session.stats()
        assert s.latency_p50_s > 0
        assert s.latency_p99_s >= s.latency_p50_s
        assert set(s.per_bucket_p50_s) == set(s.per_bucket) == {4}
        assert s.per_bucket_p99_s[4] >= s.per_bucket_p50_s[4] > 0
        # the same rolling window answers the admission-control query
        assert session.dispatch_latency_s(bucket=4) == s.per_bucket_p50_s[4]
        assert np.isnan(session.dispatch_latency_s(bucket=2))


class TestBucketPaddingEdgeCases:
    def test_flush_of_more_than_max_bucket_chunks(self, plan, qmodel, xs):
        """A backlog larger than the biggest bucket flushes in max_batch
        chunks — every ticket fulfilled, order preserved."""
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=2,
                          buckets=(1, 2))
        tickets = [session.submit(x) for x in xs[:5]]   # 5 > max bucket 2
        assert session.flush() == 5
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:5], mode="int8")
        for t, r in zip(tickets, ref):
            assert np.array_equal(t.result(), r)
        s = session.stats()
        assert s.batches == 3                     # 2 + 2 + 1(pad to bucket 1)
        assert s.per_bucket == {2: 2, 1: 1}

    def test_empty_flush_is_a_noop(self, plan, qmodel):
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=2)
        assert session.flush() == 0
        assert session.stats().batches == 0

    def test_submit_during_dispatch_lands_in_next_flush(self, plan, qmodel,
                                                        xs, monkeypatch):
        """Interleaved submit/flush: a request submitted while a dispatch is
        executing is untouched by that flush and served by the next one."""
        session = Session(plan, precision="int8", qmodel=qmodel, max_batch=4)
        first = [session.submit(x) for x in xs[:2]]
        real = session.engine.run_batch_async
        late: list = []

        def submit_mid_dispatch(batch, mode):
            if not late:                      # only on the first dispatch
                late.append(session.submit(xs[2]))
            return real(batch, mode=mode)

        monkeypatch.setattr(session.engine, "run_batch_async",
                            submit_mid_dispatch)
        assert session.flush() == 2           # the late submit is NOT in it
        assert all(t.done() for t in first)
        assert not late[0].done()
        assert session.n_pending == 1
        assert session.flush() == 1           # ...but the next flush has it
        ref = CompiledSplitExecutor(plan.split, qmodel).run_batch(
            xs[:3], mode="int8")
        for t, r in zip(first + late, ref):
            assert np.array_equal(t.result(), r)
