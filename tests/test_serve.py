"""Multi-tenant serving subsystem tests: the continuous-batching server must
be bit-exact vs ``Session.run`` per request regardless of batch composition,
admission control must shed with typed ``Overloaded`` (never by collapsing
queues), failures must stay isolated to the batch that raised, and the QoS
monitor / load generator must report what actually happened."""
import collections
import math
import threading
import time

import numpy as np
import pytest

from conftest import small_cnn
from repro.api import Session
from repro.core import split_model
from repro.serve import (SLO, AdmissionController, EdfBatcher, Overloaded,
                         QosMonitor, Server, run_open_loop,
                         saturation_throughput)
from repro.serve.scheduler import make_request

# threaded server + wall-clock SLO assertions: keep the module on one xdist
# worker (serial group) so parallel cells don't skew its timing
pytestmark = pytest.mark.xdist_group("runtime")


@pytest.fixture(scope="module")
def model():
    return small_cnn()


@pytest.fixture(scope="module")
def split(model):
    return split_model(model, np.asarray([2.0, 1.0]))


@pytest.fixture(scope="module")
def xs(model):
    rng = np.random.default_rng(3)
    return np.stack([rng.standard_normal(model.input_shape).astype(np.float32)
                     for _ in range(12)])


def _server(split, n_tenants=1, slo=None, **kw):
    srv = Server(**kw)
    for i in range(n_tenants):
        srv.add_tenant(f"t{i}", split, precision="int8", seed=0,
                       max_batch=4, buckets=(1, 2, 4), slo=slo)
    return srv


def _prefill(srv, tenant, xs):
    """Queue requests with the scheduler not yet running (white-box: the
    admitted-but-unscheduled state), returning their tickets."""
    srv._running = True
    tickets = [srv.submit(tenant, x) for x in xs]
    srv._running = False
    return tickets


class TestServerBitexact:
    def test_single_tenant_matches_session_run(self, split, xs):
        ref = Session(split, precision="int8", seed=0, max_batch=4)
        srv = _server(split)
        with srv:
            for x in xs[:5]:
                assert np.array_equal(srv.run("t0", x, timeout=60.0),
                                      ref.run(x))

    def test_batched_requests_match_per_request_session(self, split, xs):
        """Whatever micro-batch a request rides in, its output is the
        bucket-padded vmapped plan's — identical to a lone Session.run."""
        ref = Session(split, precision="int8", seed=0, max_batch=4)
        srv = _server(split)
        tickets = _prefill(srv, "t0", xs)      # forces multi-request batches
        with srv:
            outs = [t.result(timeout=60.0) for t in tickets]
        for x, y in zip(xs, outs):
            assert np.array_equal(y, ref.run(x))

    def test_two_tenants_isolated_and_bitexact(self, model, split, xs):
        other = split_model(model, np.ones(3), mode="kernel")
        ref_a = Session(split, precision="int8", seed=0, max_batch=4)
        ref_b = Session(other, precision="int8", seed=0, max_batch=4)
        srv = Server()
        srv.add_tenant("a", split, precision="int8", seed=0, max_batch=4)
        srv.add_tenant("b", other, precision="int8", seed=0, max_batch=4)
        with srv:
            ta = [srv.submit("a", x) for x in xs[:4]]
            tb = [srv.submit("b", x) for x in xs[:4]]
            for x, t in zip(xs, ta):
                assert np.array_equal(t.result(timeout=60.0), ref_a.run(x))
            for x, t in zip(xs, tb):
                assert np.array_equal(t.result(timeout=60.0), ref_b.run(x))


class TestContinuousBatching:
    def test_queued_requests_form_micro_batches(self, split, xs):
        """A backlog drains in bucket-sized dispatches, not one-by-one."""
        srv = _server(split)
        tickets = _prefill(srv, "t0", xs)      # 12 queued, max_batch 4
        with srv:
            for t in tickets:
                t.result(timeout=60.0)
        st = srv.session("t0").stats()
        assert st.requests == len(xs)
        assert st.batches <= math.ceil(len(xs) / 4) + 1
        assert st.batches < len(xs)

    def test_partial_batch_only_when_device_idle(self, split, xs):
        """The bucket-filling rule: while a dispatch is in flight, only a
        full max_batch queue may form the next batch."""
        srv = _server(split)
        sess = srv.session("t0")
        reqs = [make_request(x, "t0", 0.0, SLO()) for x in xs[:2]]
        srv._tenants["t0"].queue.extend(reqs)
        # full_only (something in flight): 2 < max_batch -> no batch
        assert srv._form_batch(full_only=True) is None
        assert len(srv._tenants["t0"].queue) == 2
        # idle device: the partial pair dispatches immediately
        tenant, taken = srv._form_batch(full_only=False)
        assert tenant.session is sess and len(taken) == 2

    def test_responses_fifo_per_tenant(self, split, xs):
        srv = _server(split)
        tickets = _prefill(srv, "t0", xs)
        with srv:
            for t in tickets:
                t.result(timeout=60.0)
        stamps = [t.completed_at for t in tickets]
        assert stamps == sorted(stamps)


class TestAdmissionControl:
    def test_queue_cap_sheds_typed(self, split, xs):
        srv = _server(split, slo=SLO(p99_target_s=None, queue_cap=2))
        srv._running = True
        srv.submit("t0", xs[0])
        srv.submit("t0", xs[1])
        with pytest.raises(Overloaded) as ei:
            srv.submit("t0", xs[2])
        assert ei.value.reason == "queue_cap"
        assert ei.value.tenant == "t0"
        assert ei.value.queue_depth == 2
        # shed, not collapsed: the queued requests are still queued
        assert srv.queue_depth("t0") == 2
        assert srv.stats("t0").rejected == 1

    def test_slo_sheds_on_predicted_delay(self, split, xs):
        srv = _server(split, slo=SLO(p99_target_s=0.05, queue_cap=None))
        sess = srv.session("t0")
        # seed the rolling service-time estimate: 10 s per max_batch bucket
        sess._record_dispatch(4, 4, 10.0)
        srv._running = True
        for i in range(4):        # queue_depth 0..3 -> 0 full batches ahead
            srv.submit("t0", xs[i])
        with pytest.raises(Overloaded) as ei:
            srv.submit("t0", xs[4])   # 4 queued -> 1 batch ahead -> 10 s
        assert ei.value.reason == "slo"
        assert ei.value.predicted_delay_s == pytest.approx(10.0)
        assert ei.value.p99_target_s == pytest.approx(0.05)

    def test_cold_tenant_admits_until_cap(self, split, xs):
        """Before any dispatch is measured the SLO gate cannot predict, so
        only the model-free queue cap holds."""
        srv = _server(split, slo=SLO(p99_target_s=1e-9, queue_cap=3))
        srv._running = True
        for i in range(3):
            srv.submit("t0", xs[i])
        with pytest.raises(Overloaded) as ei:
            srv.submit("t0", xs[3])
        assert ei.value.reason == "queue_cap"

    def test_predicted_delay_math(self):
        class FakeMonitor:
            def service_time_s(self, tenant, bucket=None):
                return 0.5

        ctl = AdmissionController(FakeMonitor())
        assert ctl.predicted_delay_s(
            "t", queue_depth=0, inflight_batches=0, max_batch=8) == 0.0
        assert ctl.predicted_delay_s(
            "t", queue_depth=7, inflight_batches=0, max_batch=8) == 0.0
        assert ctl.predicted_delay_s(
            "t", queue_depth=8, inflight_batches=0, max_batch=8) == 0.5
        assert ctl.predicted_delay_s(
            "t", queue_depth=20, inflight_batches=2, max_batch=8) \
            == pytest.approx((2 + 2) * 0.5)

    def test_service_estimate_cached_within_ttl(self):
        calls = []

        class CountingMonitor:
            def service_time_s(self, tenant, bucket=None):
                calls.append(tenant)
                return 0.25

        now = [0.0]
        ctl = AdmissionController(CountingMonitor(), cache_ttl_s=1.0,
                                  clock=lambda: now[0])
        for _ in range(5):
            ctl.predicted_delay_s("t", queue_depth=16, inflight_batches=0,
                                  max_batch=8)
        assert len(calls) == 1          # cached within the TTL
        now[0] = 2.0
        ctl.predicted_delay_s("t", queue_depth=16, inflight_batches=0,
                              max_batch=8)
        assert len(calls) == 2          # refreshed after expiry

    def test_slo_validation(self):
        with pytest.raises(ValueError):
            SLO(p99_target_s=0.0)
        with pytest.raises(ValueError):
            SLO(queue_cap=0)
        SLO(p99_target_s=None, queue_cap=None)   # both gates off is valid


class TestFailureIsolation:
    def test_poisoned_batch_rejects_only_its_tickets(self, split, xs):
        srv = Server()
        srv.add_tenant("good", split, precision="int8", seed=0, max_batch=4)
        srv.add_tenant("bad", split, precision="int8", seed=0, max_batch=4)
        boom = RuntimeError("poisoned dispatch")

        def raising_dispatch(batch):
            raise boom

        srv.session("bad").dispatch_async = raising_dispatch
        ref = Session(split, precision="int8", seed=0, max_batch=4)
        with srv:
            tb = [srv.submit("bad", x) for x in xs[:3]]
            tg = [srv.submit("good", x) for x in xs[:3]]
            for t in tb:
                with pytest.raises(RuntimeError, match="poisoned"):
                    t.result(timeout=60.0)
                assert t.exception() is boom
            # the good tenant was never disturbed
            for x, t in zip(xs, tg):
                assert np.array_equal(t.result(timeout=60.0), ref.run(x))
            assert srv.running
        assert srv.stats("bad").failed == 3
        assert srv.stats("good").failed == 0


class TestLifecycle:
    def test_stop_drain_serves_everything_admitted(self, split, xs):
        srv = _server(split)
        tickets = _prefill(srv, "t0", xs[:6])
        srv.start()
        srv.stop(drain=True)
        for t in tickets:
            assert t.done()
            assert t.result(timeout=0.1) is not None

    def test_stop_without_drain_rejects_queued(self, split, xs):
        srv = _server(split)
        tickets = _prefill(srv, "t0", xs[:6])
        srv.start()
        srv.stop(drain=False)
        shed = sum(1 for t in tickets if t.exception() is not None)
        served = sum(1 for t in tickets if t.exception() is None)
        assert shed + served == 6
        assert shed > 0 or served == 6  # a fast scheduler may win the race
        for t in tickets:
            if t.exception() is not None:
                assert isinstance(t.exception(), Overloaded)
                assert t.exception().reason == "shutdown"

    def test_submit_when_not_running_raises(self, split, xs):
        srv = _server(split)
        with pytest.raises(RuntimeError, match="not running"):
            srv.submit("t0", xs[0])

    def test_tenancy_is_static_and_named(self, split):
        srv = _server(split)
        with pytest.raises(ValueError, match="duplicate"):
            srv.add_tenant("t0", split)
        with pytest.raises(KeyError, match="unknown tenant"):
            srv.session("nope")
        with srv:
            with pytest.raises(RuntimeError, match="tenancy is static"):
                srv.add_tenant("late", split)

    def test_start_with_no_tenants_raises(self):
        with pytest.raises(RuntimeError, match="no tenants"):
            Server().start()

    def test_input_validated_before_admission(self, split, xs):
        srv = _server(split, slo=SLO(p99_target_s=None, queue_cap=1))
        with srv:
            with pytest.raises(ValueError, match="shape"):
                srv.submit("t0", xs[0][:, :2, :])
        # the malformed request was never counted against the tenant
        assert srv.stats("t0").submitted == 0


class TestQosMonitor:
    def test_percentiles_and_counters(self):
        now = [0.0]
        mon = QosMonitor(window=64, clock=lambda: now[0])
        lat = [0.01 * (i + 1) for i in range(10)]
        for _ in lat:
            mon.on_submit("t")
            mon.on_admit("t")
        mon.on_complete_batch("t", lat[:6])
        now[0] = 1.0
        for v in lat[6:]:
            mon.on_complete("t", v)
        q = mon.snapshot("t", queue_depth=2, inflight=1)
        assert q.submitted == q.accepted == q.completed == 10
        assert q.latency_p50_s == pytest.approx(np.percentile(lat, 50))
        assert q.latency_p99_s == pytest.approx(np.percentile(lat, 99))
        assert q.queue_depth == 2 and q.inflight == 1
        # 10 completions spanning 1 s of fake clock -> 9 intervals / 1 s
        assert q.throughput_rps == pytest.approx(9.0)
        assert "t" in mon.tenants()
        assert "p50" in q.describe()

    def test_service_time_delegates_to_session(self, split):
        mon = QosMonitor()
        assert math.isnan(mon.service_time_s("t"))
        sess = Session(split, precision="int8", seed=0, max_batch=4)
        mon.register_session("t", sess)
        assert math.isnan(mon.service_time_s("t"))          # cold
        sess._record_dispatch(4, 4, 0.125)
        assert mon.service_time_s("t", bucket=4) == pytest.approx(0.125)
        # falls back to the all-bucket window for unmeasured buckets
        assert mon.service_time_s("t", bucket=2) == pytest.approx(0.125)

    def test_rejection_rate(self):
        mon = QosMonitor()
        for _ in range(3):
            mon.on_submit("t")
        mon.on_admit("t")
        mon.on_reject("t")
        mon.on_reject("t")
        q = mon.snapshot("t")
        assert q.rejection_rate == pytest.approx(2 / 3)


class TestEdfBatcher:
    def test_earliest_deadline_tenant_wins(self):
        b = EdfBatcher()
        qa = collections.deque([make_request(None, "a", 5.0, SLO(1.0))])
        qb = collections.deque([make_request(None, "b", 1.0, SLO(1.0))])
        assert b.select({"a": qa, "b": qb}) == "b"   # older arrival first
        tight = collections.deque(
            [make_request(None, "c", 5.5, SLO(0.01))])
        assert b.select({"a": qa, "c": tight}) == "c"  # tighter SLO wins
        assert b.select({"a": collections.deque()}) is None

    def test_take_preserves_fifo(self):
        b = EdfBatcher()
        q = collections.deque(
            make_request(i, "a", float(i), SLO(1.0)) for i in range(6))
        taken = b.take(q, 4)
        assert [r.x for r in taken] == [0, 1, 2, 3]
        assert len(q) == 2 and q[0].x == 4

    def test_no_slo_target_means_infinite_deadline(self):
        r = make_request(None, "a", 2.0, SLO(p99_target_s=None))
        assert math.isinf(r.deadline)


class TestLoadgen:
    def test_open_loop_reports(self, split, xs):
        srv = _server(split)
        with srv:
            reports = run_open_loop(srv, {"t0": 50.0}, lambda: xs[0],
                                    duration_s=0.4, seed=0,
                                    result_timeout_s=60.0)
        rep = reports["t0"]
        assert rep.submitted > 0
        assert rep.accepted + rep.rejected == rep.submitted
        assert rep.completed == rep.accepted and rep.failed == 0
        assert rep.p50_s > 0 and rep.p99_s >= rep.p50_s
        assert rep.throughput_rps > 0
        assert "t0" in rep.describe()

    def test_open_loop_requires_running_server(self, split):
        srv = _server(split)
        with pytest.raises(RuntimeError, match="started"):
            run_open_loop(srv, {"t0": 10.0}, lambda: None, duration_s=0.1)

    def test_saturation_throughput_positive(self, split, xs):
        srv = _server(split)
        with srv:
            rate = saturation_throughput(srv, "t0", lambda: xs[0],
                                         n_requests=16, repeats=1)
        assert rate > 0

    def test_overload_sheds_and_bounds_accepted_tail(self, split, xs):
        """End-to-end admission story: a tight SLO under a hopeless offered
        rate sheds most load while every accepted request is still served."""
        srv = _server(split, slo=SLO(p99_target_s=0.02, queue_cap=4))
        with srv:
            reports = run_open_loop(srv, {"t0": 2000.0}, lambda: xs[0],
                                    duration_s=0.5, seed=0,
                                    result_timeout_s=60.0)
        rep = reports["t0"]
        assert rep.rejected > 0
        assert rep.completed == rep.accepted     # shed != dropped-after-admit
        assert rep.failed == 0


class TestSharedCache:
    def test_tenants_share_executable_cache(self, split):
        before = Server.cache_stats()["hits"]
        srv = Server()
        srv.add_tenant("a", split, precision="int8", seed=0, max_batch=4,
                       buckets=(1, 4))
        srv.add_tenant("b", split, precision="int8", seed=0, max_batch=4,
                       buckets=(1, 4))
        assert Server.cache_stats()["hits"] > before


class TestConcurrentClients:
    def test_many_threads_submit_concurrently(self, split, xs):
        srv = _server(split)
        ref = Session(split, precision="int8", seed=0, max_batch=4)
        expected = [ref.run(x) for x in xs[:4]]
        errors = []

        def client(i):
            try:
                for _ in range(3):
                    y = srv.run("t0", xs[i % 4], timeout=60.0)
                    assert np.array_equal(y, expected[i % 4])
            except Exception as e:  # noqa: BLE001 — re-raised on the driver
                errors.append(e)

        with srv:
            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert not errors
        st = srv.stats("t0")
        assert st.completed == 24
        assert st.latency_p50_s > 0

    def test_run_convenience_roundtrip(self, split, xs):
        srv = _server(split)
        ref = Session(split, precision="int8", seed=0, max_batch=4)
        with srv:
            assert np.array_equal(srv.run("t0", xs[0], timeout=60.0),
                                  ref.run(xs[0]))


class TestTicketTimeout:
    def test_detached_ticket_times_out(self):
        from repro.api import Ticket
        t = Ticket()
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            t.result(timeout=0.05)
        assert time.perf_counter() - t0 < 5.0
        assert not t.done()
