"""ElasticCluster policy tests under an injected fake clock.

The clock callable makes the heartbeat-timeout logic testable without
sleeping (ISSUE 7 bugfix): time is advanced explicitly, including the
previously-broken ``now=0.0`` case that the old ``now or time.monotonic()``
expression silently replaced with wall-clock time.
"""
import numpy as np
import pytest

from conftest import small_cnn
from repro.core.allocation import WorkerParams
from repro.runtime.elastic import ElasticCluster


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def cluster(n=3, timeout=5.0, clock=None, **kw):
    clock = clock or FakeClock()
    c = ElasticCluster(small_cnn(), [WorkerParams() for _ in range(n)],
                       k1=1.0, kc=1.0, heartbeat_timeout=timeout,
                       clock=clock, **kw)
    return c, clock


class TestClockInjection:
    def test_initial_heartbeats_use_injected_clock(self):
        c, clk = cluster(clock=FakeClock(42.0))
        assert all(h.last_heartbeat == 42.0 for h in c.health)

    def test_heartbeat_at_time_zero_is_respected(self):
        # regression: `now or clock()` treated now=0.0 as unset
        c, clk = cluster(clock=FakeClock(100.0))
        c.heartbeat(1, now=0.0)
        assert c.health[1].last_heartbeat == 0.0

    def test_heartbeat_default_reads_clock(self):
        c, clk = cluster()
        clk.t = 7.5
        c.heartbeat(0)
        assert c.health[0].last_heartbeat == 7.5


class TestDropPath:
    def test_silent_worker_dropped_and_replanned(self):
        c, clk = cluster(n=3, timeout=5.0)
        old_plan = c.plan
        clk.t = 4.0
        c.heartbeat(0)
        c.heartbeat(2)
        clk.t = 6.0                     # worker 1 silent since t=0
        assert c.check() is True
        assert c.alive_indices == [0, 2]
        assert c.plan is not old_plan
        assert c.plan.n_workers == 2

    def test_fresh_heartbeats_keep_everyone(self):
        c, clk = cluster(n=3, timeout=5.0)
        clk.t = 4.9
        for w in range(3):
            c.heartbeat(w)
        clk.t = 5.5
        assert c.check() is False
        assert c.alive_indices == [0, 1, 2]

    def test_check_accepts_explicit_now(self):
        c, clk = cluster(n=2, timeout=5.0)
        assert c.check(now=4.0) is False
        c.heartbeat(0, now=99.0)
        assert c.check(now=100.0) is True
        assert c.alive_indices == [0]
        assert c.plan.n_workers == 1

    def test_all_dead_raises(self):
        c, clk = cluster(n=2, timeout=5.0)
        clk.t = 50.0
        with pytest.raises(RuntimeError, match="no surviving workers"):
            c.check()


class TestDemotionPath:
    def test_straggler_demoted(self):
        c, clk = cluster(n=3, timeout=1e9, straggler_factor=1.5)
        f0 = c.health[2].params.f_mhz
        for _ in range(4):
            c.report_step_time(0, 1.0)
            c.report_step_time(1, 1.0)
            c.report_step_time(2, 10.0)  # 10x the median
        assert c.check() is True
        assert c.health[2].params.f_mhz < f0 / 2
        assert c.health[2].ema_step_time is None   # reset after demotion
        # demoted worker gets a smaller share in the new plan
        shares = [c.plan.worker_weight_bytes(w) for w in range(3)]
        assert shares[2] < shares[0]

    def test_balanced_workers_not_demoted(self):
        c, clk = cluster(n=3, timeout=1e9, straggler_factor=1.5)
        for w in range(3):
            c.report_step_time(w, 1.0)
        assert c.check() is False
        assert all(h.params.f_mhz == WorkerParams().f_mhz
                   for h in c.health)

    def test_mark_failed_triggers_replan_on_check(self):
        c, clk = cluster(n=3)
        c.mark_failed(1)
        assert c.check(now=0.1) is True
        assert c.alive_indices == [0, 2]
