"""ElasticCluster policy tests under an injected fake clock.

The clock callable makes the heartbeat-timeout logic testable without
sleeping (ISSUE 7 bugfix): time is advanced explicitly, including the
previously-broken ``now=0.0`` case that the old ``now or time.monotonic()``
expression silently replaced with wall-clock time.

ISSUE 9 additions: the cluster re-plans with the full ``Planner`` (worker
identity preserved through ``plan_worker_ids``, every search axis live
instead of the old compacted neuron-only ``split_model`` path), raises
typed ``ClusterCollapsed``, floors straggler demotion at a fraction of the
original rating, and supports ``rejoin``.
"""
import pytest

from conftest import small_cnn
from repro.api.plan import Plan
from repro.core.allocation import WorkerParams
from repro.runtime.elastic import ClusterCollapsed, ElasticCluster


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def cluster(n=3, timeout=5.0, clock=None, **kw):
    clock = clock or FakeClock()
    c = ElasticCluster(small_cnn(), [WorkerParams() for _ in range(n)],
                       heartbeat_timeout=timeout, clock=clock, **kw)
    return c, clock


class TestClockInjection:
    def test_initial_heartbeats_use_injected_clock(self):
        c, clk = cluster(clock=FakeClock(42.0))
        assert all(h.last_heartbeat == 42.0 for h in c.health)

    def test_heartbeat_at_time_zero_is_respected(self):
        # regression: `now or clock()` treated now=0.0 as unset
        c, clk = cluster(clock=FakeClock(100.0))
        c.heartbeat(1, now=0.0)
        assert c.health[1].last_heartbeat == 0.0

    def test_heartbeat_default_reads_clock(self):
        c, clk = cluster()
        clk.t = 7.5
        c.heartbeat(0)
        assert c.health[0].last_heartbeat == 7.5


class TestDropPath:
    def test_silent_worker_dropped_and_replanned(self):
        c, clk = cluster(n=3, timeout=5.0)
        old_plan = c.plan
        clk.t = 4.0
        c.heartbeat(0)
        c.heartbeat(2)
        clk.t = 6.0                     # worker 1 silent since t=0
        assert c.check() is True
        assert c.alive_indices == [0, 2]
        assert c.plan is not old_plan
        assert 1 not in c.plan_worker_ids
        assert set(c.plan_worker_ids) <= {0, 2}

    def test_fresh_heartbeats_keep_everyone(self):
        c, clk = cluster(n=3, timeout=5.0)
        clk.t = 4.9
        for w in range(3):
            c.heartbeat(w)
        clk.t = 5.5
        assert c.check() is False
        assert c.alive_indices == [0, 1, 2]

    def test_check_accepts_explicit_now(self):
        c, clk = cluster(n=2, timeout=5.0)
        assert c.check(now=4.0) is False
        c.heartbeat(0, now=99.0)
        assert c.check(now=100.0) is True
        assert c.alive_indices == [0]
        assert c.plan_worker_ids == (0,)

    def test_all_dead_raises_typed(self):
        c, clk = cluster(n=2, timeout=5.0)
        clk.t = 50.0
        with pytest.raises(ClusterCollapsed, match="no surviving workers"):
            c.check()

    def test_cluster_collapsed_is_runtime_error(self):
        # callers catching the pre-ISSUE-9 bare RuntimeError keep working
        assert issubclass(ClusterCollapsed, RuntimeError)


class TestPlannerBacked:
    """Regression: the old `_replan` used raw neuron-only `split_model`
    over a *compacted* alive-only index space — worker identity was lost
    and the mode/fusion/subset/transport axes were ignored."""

    def test_plan_is_full_api_plan(self):
        c, clk = cluster(n=3)
        assert isinstance(c.plan, Plan)
        # every planner axis is present on the decision, not hardwired
        assert c.plan.mode in ("neuron", "kernel", "spatial", "mixed")
        assert c.plan.transport in ("serial", "pipelined")

    def test_worker_identity_preserved(self):
        c, clk = cluster(n=4)
        # identity mapping to original ids, aligned with plan slots
        assert len(c.plan_worker_ids) == c.plan.n_workers
        assert set(c.plan_worker_ids) <= {0, 1, 2, 3}
        c.mark_failed(0)                # kill the *first* id: any compacted
        assert c.check(now=0.0)         # index space would shift survivors
        assert 0 not in c.plan_worker_ids
        assert set(c.plan_worker_ids) <= {1, 2, 3}
        # plan slots still resolve to the surviving physical workers
        for slot, pid in enumerate(c.plan_worker_ids):
            assert c.health[pid].alive
            assert c.plan.split.worker_weight_bytes(slot) >= 0

    def test_flash_caps_respected_after_churn(self):
        m = small_cnn()
        workers = [WorkerParams(flash_bytes=64 << 10),
                   WorkerParams(flash_bytes=8 << 10),    # tiny flash
                   WorkerParams(flash_bytes=64 << 10)]
        c = ElasticCluster(m, workers, heartbeat_timeout=5.0,
                           clock=FakeClock())
        c.mark_failed(0)
        c.check(now=0.0)
        for slot, pid in enumerate(c.plan_worker_ids):
            assert (c.plan.split.worker_weight_bytes(slot)
                    <= workers[pid].flash_bytes)


class TestDemotionPath:
    def test_straggler_demoted(self):
        c, clk = cluster(n=3, timeout=1e9, straggler_factor=1.5)
        f0 = c.health[2].params.f_mhz
        for _ in range(4):
            c.report_step_time(0, 1.0)
            c.report_step_time(1, 1.0)
            c.report_step_time(2, 10.0)  # 10x the median
        assert c.check() is True
        assert c.health[2].params.f_mhz < f0 / 2
        assert c.health[2].ema_step_time is None   # reset after demotion
        # demoted worker gets a smaller share in the new plan (or none)
        shares = {pid: c.plan.split.worker_weight_bytes(slot)
                  for slot, pid in enumerate(c.plan_worker_ids)}
        assert shares.get(2, 0) < shares[0]

    def test_demotion_floor(self):
        # regression: repeated demotions compounded f_mhz toward zero
        c, clk = cluster(n=3, timeout=1e9, straggler_factor=1.5,
                         demotion_floor=0.25)
        f0 = c.health[2].params.f_mhz
        for _ in range(6):              # repeated straggle/demote rounds
            for _ in range(4):
                c.report_step_time(0, 1.0)
                c.report_step_time(1, 1.0)
                c.report_step_time(2, 100.0)
            c.check()
        assert c.health[2].params.f_mhz >= 0.25 * f0
        assert c.health[2].params.f_mhz == pytest.approx(0.25 * f0)

    def test_demotion_floor_validated(self):
        with pytest.raises(ValueError, match="demotion_floor"):
            cluster(n=2, demotion_floor=0.0)
        with pytest.raises(ValueError, match="demotion_floor"):
            cluster(n=2, demotion_floor=1.5)

    def test_balanced_workers_not_demoted(self):
        c, clk = cluster(n=3, timeout=1e9, straggler_factor=1.5)
        for w in range(3):
            c.report_step_time(w, 1.0)
        assert c.check() is False
        assert all(h.params.f_mhz == WorkerParams().f_mhz
                   for h in c.health)

    def test_mark_failed_triggers_replan_on_check(self):
        c, clk = cluster(n=3)
        c.mark_failed(1)
        assert c.check(now=0.1) is True
        assert c.alive_indices == [0, 2]


class TestRejoin:
    def test_rejoin_restores_original_rating(self):
        c, clk = cluster(n=3, timeout=1e9)
        f0 = c.health[2].params.f_mhz
        for _ in range(4):
            c.report_step_time(0, 1.0)
            c.report_step_time(1, 1.0)
            c.report_step_time(2, 10.0)
        c.check()
        assert c.health[2].params.f_mhz < f0
        c.rejoin(2)                      # fresh process: clean slate
        assert c.health[2].params.f_mhz == f0
        assert c.health[2].ema_step_time is None

    def test_rejoin_after_death_refolds_into_plan(self):
        c, clk = cluster(n=3)
        c.mark_failed(1)
        assert c.check(now=0.0)
        assert 1 not in c.plan_worker_ids
        c.rejoin(1, now=0.0)
        assert c.check(now=0.0)
        assert 1 in c.alive_indices
        assert 1 in c.plan_worker_ids

    def test_rejoin_with_new_measured_params(self):
        c, clk = cluster(n=2)
        c.mark_failed(1)
        c.check(now=0.0)
        slow = WorkerParams(f_mhz=100.0)
        c.rejoin(1, params=slow, now=0.0)
        assert c.health[1].params.f_mhz == 100.0
