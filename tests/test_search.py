"""The shared cost-model/search layer (``repro.core.search``) and the
planner refactor on top of it.

Covers the :class:`CostCache` memo (LRU, counters, parameter-keyed reuse
across planners and survivor-subset replans), the beam search over worker
subsets (score never worse than the prefix ladder — property-tested on
random heterogeneous clusters; ``beam_width=None`` byte-identical to the
committed ladder plans), the search-budget cap, the transport-aware +
subset-aware mixing DP extensions, the mixed-axis ``InfeasibleError``
binding-block details, and the search-stats plumbing through ``Plan``,
``SessionStats`` and the elastic replan path.
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import small_cnn
from repro.api import Cluster, InfeasibleError, Objective, Plan, Planner
from repro.api.planner import SEARCH_MODES
from repro.core import CostCache, SearchStats, SimConfig, WorkerParams, simulate
from repro.core.mixed import MixedInfeasible, search_mixed_assignment
from repro.core.search import (config_fingerprint, prefix_subset_grid,
                               subset_fingerprint, worker_fingerprint)
from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke

BENCH = json.loads(
    (pathlib.Path(__file__).parent.parent / "BENCH_executor.json")
    .read_text())
RAM_CAP = 512 * 1024


def _objective(**kw):
    return Objective(minimize="latency", ram_cap_bytes=RAM_CAP, **kw)


# ---------------------------------------------------------------------------
# CostCache / SearchStats / fingerprints
# ---------------------------------------------------------------------------

class TestCostCache:
    def test_hit_miss_counters(self):
        c = CostCache()
        assert c.get("k") is None
        c.put("k", 1)
        assert c.get("k") == 1
        assert (c.hits, c.misses) == (1, 1)

    def test_get_or_builds_once(self):
        c = CostCache()
        calls = []
        assert c.get_or("k", lambda: calls.append(1) or 42) == 42
        assert c.get_or("k", lambda: calls.append(1) or 42) == 42
        assert len(calls) == 1

    def test_lru_eviction(self):
        c = CostCache(max_entries=2)
        c.put("a", 1), c.put("b", 2)
        c.get("a")                      # refresh "a": "b" is now LRU
        c.put("c", 3)
        assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
        assert len(c) == 2

    def test_clear_keeps_counters(self):
        c = CostCache()
        c.put("a", 1), c.get("a")
        c.clear()
        assert len(c) == 0 and c.hits == 1

    def test_validates_capacity(self):
        with pytest.raises(ValueError):
            CostCache(max_entries=0)

    def test_fingerprints_are_parameter_keyed(self):
        a, b = WorkerParams(), WorkerParams()
        assert a is not b
        assert worker_fingerprint(a) == worker_fingerprint(b)
        assert subset_fingerprint([a]) == subset_fingerprint([b])
        # transport must NOT split cache keys — one evaluation serves both
        cfg = SimConfig()
        assert (config_fingerprint(cfg) ==
                config_fingerprint(dataclasses.replace(
                    cfg, transport="pipelined")))


class TestSearchStats:
    def test_hit_rate(self):
        s = SearchStats(candidates_evaluated=4, cache_hits=1, cache_misses=3)
        assert s.cache_hit_rate == 0.25
        assert SearchStats().cache_hit_rate == 0.0

    def test_to_dict_round(self):
        d = SearchStats(candidates_evaluated=3, cache_hits=1, cache_misses=2,
                        search_wall_s=0.1234567).to_dict()
        assert d["cache_hit_rate"] == round(1 / 3, 6)
        assert d["search_wall_s"] == 0.123457


class TestPrefixSubsetGrid:
    def test_disabled(self):
        assert prefix_subset_grid(8, None) == (None,)
        assert prefix_subset_grid(1, 3) == (None,)

    def test_geometric_sizes(self):
        assert prefix_subset_grid(8, 3) == (None, 1, 2, 4)
        assert prefix_subset_grid(3, 5) == (None, 1, 2)


# ---------------------------------------------------------------------------
# Objective knobs
# ---------------------------------------------------------------------------

class TestObjectiveKnobs:
    def test_validation(self):
        for kw in (dict(beam_width=0), dict(search_budget=0),
                   dict(mixed_subsets=-1)):
            with pytest.raises(ValueError):
                Objective(**kw)

    def test_round_trip(self):
        obj = Objective(beam_width=3, search_budget=50, mixed_subsets=2)
        again = Objective.from_dict(obj.to_dict())
        assert again == obj

    def test_from_dict_tolerates_missing_knobs(self):
        obj = Objective.from_dict({"minimize": "latency"})
        assert obj.beam_width is None and obj.search_budget is None
        assert obj.mixed_subsets is None


# ---------------------------------------------------------------------------
# ladder exactness: beam_width=None reproduces the committed plans
# ---------------------------------------------------------------------------

class TestLadderExactness:
    """``beam_width=None`` + uniform modes must be byte-identical to the
    committed plan-search outcomes (BENCH planner section)."""

    def _check(self, model, config, k):
        want = BENCH["planner"][f"{config}@{k}"]
        planner = Planner(model, Cluster.heterogeneous_demo(k))
        if not want["feasible"]:
            with pytest.raises(InfeasibleError) as ei:
                planner.plan(_objective())
            assert ei.value.binding_constraint == want["binding"]
            return
        plan = planner.plan(_objective())
        got = dict(plan_latency_s=round(plan.latency_s, 9),
                   max_peak_ram=int(plan.max_peak_ram),
                   mode=plan.mode, fusion=plan.fusion,
                   transport=plan.transport,
                   overlap_saved_s=round(plan.overlap_saved_s, 9),
                   n_workers=plan.n_workers)
        assert got == {k_: want[k_] for k_ in got}

    @pytest.mark.parametrize("k", [1, 3, 8])
    def test_smoke_configs(self, k):
        self._check(mobilenet_v2_smoke(), "smoke", k)

    @pytest.mark.parametrize("k", [1, 3])
    def test_mnv2_112_configs(self, k):
        self._check(mobilenet_v2_paper(), "mnv2_112", k)


# ---------------------------------------------------------------------------
# beam search
# ---------------------------------------------------------------------------

class TestBeamSearch:
    def test_full_width_beats_ladder_on_demo(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(8)
        ladder = Planner(model, cluster).plan(_objective())
        beam = Planner(model, cluster).plan(_objective(beam_width=4))
        assert beam.score <= ladder.score
        # the demo cluster is heterogeneous enough that the beam finds a
        # strictly better non-prefix subset — keep this strict so the beam
        # phase cannot silently degenerate into the ladder
        assert beam.score < ladder.score
        assert beam.search_stats["subsets_explored"] > 8

    def test_budget_caps_beam_misses_not_ladder(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(8)
        planner = Planner(model, cluster)
        ladder_misses = 32          # 8 prefixes x 4 (mode, fusion) points
        planner.plan(_objective(beam_width=4, search_budget=8))
        stats = planner.last_stats
        assert stats.cache_misses <= ladder_misses + 8
        # the ladder itself always completes (8 subsets), budget or not
        assert stats.subsets_explored >= 8

    def test_warm_cache_widens_budgeted_beam(self):
        """Budget counts cache *misses*: a warm cache lets the same budget
        explore at least as many subsets as a cold one."""
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(8)
        cold = Planner(model, cluster)
        cold.plan(_objective(beam_width=4, search_budget=16))
        warm = Planner(model, cluster, cache=cold.cache)
        warm.plan(_objective(beam_width=4, search_budget=16))
        assert (warm.last_stats.subsets_explored
                >= cold.last_stats.subsets_explored)
        assert warm.last_stats.cache_hits > 0


@st.composite
def random_clusters(draw):
    n = draw(st.integers(2, 6))
    workers = tuple(
        WorkerParams(f_mhz=draw(st.floats(50.0, 400.0)),
                     d_s_per_kb=draw(st.floats(0.0, 0.02)),
                     b_kb_s=draw(st.floats(10.0, 200.0)))
        for _ in range(n))
    return Cluster(workers, name=f"rand[{n}]")


@given(random_clusters())
@settings(max_examples=15, deadline=None)
def test_property_beam_never_worse_than_ladder(cluster):
    """Beam at full width on random heterogeneous clusters: the beam
    evaluates every ladder prefix too, so its plan score is <= the
    ladder's for the same objective (HYPOTHESIS_PROFILE=ci in CI)."""
    model = small_cnn()
    obj = Objective(minimize="latency")
    cache = CostCache()     # shared: the property is about scores, and the
    ladder = Planner(model, cluster, cache=cache).plan(obj)
    beam = Planner(model, cluster, cache=cache).plan(
        dataclasses.replace(obj, beam_width=cluster.n_workers))
    assert beam.score <= ladder.score + 1e-15


# ---------------------------------------------------------------------------
# memoized replans
# ---------------------------------------------------------------------------

class TestMemoizedReplans:
    def test_same_topology_is_all_hits(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(4)
        first = Planner(model, cluster)
        plan_a = first.plan(_objective())
        again = Planner(model, cluster, cache=first.cache)
        plan_b = again.plan(_objective())
        assert again.last_stats.cache_hit_rate == 1.0
        assert again.last_stats.cache_misses == 0
        assert plan_b.latency_s == plan_a.latency_s
        assert again.last_stats.search_wall_s < first.last_stats.search_wall_s

    def test_survivor_subset_replan_hits(self):
        """Losing one worker re-derives only what the old search did not
        already cost: keys fingerprint worker parameters, not indices."""
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(8)
        cold = Planner(model, cluster)
        cold.plan(_objective())
        survivors = Cluster(cluster.workers[:-1], name="survivors")
        warm = Planner(model, survivors, cache=cold.cache)
        warm.plan(_objective())
        assert warm.last_stats.cache_hits > 0
        assert (warm.last_stats.cache_misses
                < warm.last_stats.candidates_evaluated)

    def test_cache_is_objective_agnostic_for_uniform_modes(self):
        """A comm_bytes search reuses a latency search's evaluations —
        scoring is recomputed from the cached per-transport metrics."""
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(3)
        a = Planner(model, cluster)
        a.plan(_objective())
        b = Planner(model, cluster, cache=a.cache)
        b.plan(Objective(minimize="comm_bytes", ram_cap_bytes=RAM_CAP))
        assert b.last_stats.cache_hit_rate == 1.0

    def test_elastic_cluster_replans_warm(self):
        """The ElasticCluster owns one cache across replans: a kill/rejoin
        cycle re-plans with hit rate > 0 and a lower search wall than its
        own cold initial search."""
        from repro.runtime.elastic import ElasticCluster
        model = mobilenet_v2_smoke()
        ec = ElasticCluster(
            model, [WorkerParams() for _ in range(4)],
            objective=Objective(modes=("spatial",)),
            heartbeat_timeout=1e9, clock=lambda: 0.0)
        cold = dict(ec.last_search_stats)
        assert cold["cache_hit_rate"] == 0.0
        ec.mark_failed(0)
        assert ec.check() is True
        warm = ec.last_search_stats
        assert warm["cache_hit_rate"] > 0.0
        assert warm["search_wall_s"] < cold["search_wall_s"]
        ec.rejoin(0)
        assert ec.check() is True
        # rejoin restores the original topology: every candidate cached
        assert ec.last_search_stats["cache_hit_rate"] == 1.0


# ---------------------------------------------------------------------------
# transport-aware + subset-aware mixing DP
# ---------------------------------------------------------------------------

class TestMixingDP:
    def _setup(self, n=6):
        model = small_cnn()
        workers = [WorkerParams(f_mhz=100.0 * (1 + w % 3),
                                d_s_per_kb=0.004 * (w % 4),
                                b_kb_s=40.0 + 30.0 * (w % 2))
                   for w in range(n)]
        ratings = np.linspace(1.0, 2.0, n)
        return model, workers, ratings

    def test_transport_dp_never_worse_on_pipelined(self):
        """Simulated pipelined latency of the transport-aware DP's plan is
        <= the serial-surrogate DP's (the planner re-ranks both)."""
        from repro.api.plan import build_split_plan
        model, workers, ratings = self._setup()
        cfg = SimConfig()
        pcfg = dataclasses.replace(cfg, transport="pipelined")

        def pipe_latency(search):
            split = build_split_plan(model, ratings, "mixed",
                                     assignment=search.assignment,
                                     block_workers=search.block_workers)
            return simulate(model, workers, ratings, pcfg,
                            plan=split).total_time

        s_serial = search_mixed_assignment(model, workers, ratings, cfg)
        s_pipe = search_mixed_assignment(model, workers, ratings, cfg,
                                         transport="pipelined")
        assert (min(pipe_latency(s_serial), pipe_latency(s_pipe))
                <= pipe_latency(s_serial))

    def test_transport_validated(self):
        model, workers, ratings = self._setup(2)
        with pytest.raises(ValueError, match="transport"):
            search_mixed_assignment(model, workers, ratings,
                                    transport="warp")

    def test_subset_dp_never_worse_serial(self):
        """Per-block subsets strictly widen the DP state space, so the
        serial-exact optimum can only improve."""
        model, workers, ratings = self._setup()
        full = search_mixed_assignment(model, workers, ratings)
        sub = search_mixed_assignment(model, workers, ratings,
                                      subset_choices=(None, 1, 2, 4))
        assert sub.predicted_latency_s <= full.predicted_latency_s + 1e-15

    def test_subset_dp_splits_validate(self):
        """A subset-DP assignment builds a split whose peak matches the
        full-width worker layout (empty shards for excluded workers)."""
        from repro.api.plan import build_split_plan
        from repro.core import peak_ram_per_worker
        model, workers, ratings = self._setup()
        res = search_mixed_assignment(model, workers, ratings,
                                      subset_choices=(None, 1, 2))
        split = build_split_plan(model, ratings, "mixed",
                                 assignment=res.assignment,
                                 block_workers=res.block_workers)
        assert split.n_workers == len(workers)
        assert peak_ram_per_worker(split).shape == (len(workers),)

    def test_planner_mixed_subsets_knob(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(4)
        base = Planner(model, cluster).plan(
            _objective(modes=SEARCH_MODES))
        sub = Planner(model, cluster).plan(
            _objective(modes=SEARCH_MODES, mixed_subsets=2))
        assert sub.score <= base.score + 1e-15
        if sub.mode == "mixed" and sub.block_workers is not None:
            assert len(sub.block_workers) == len(sub.assignment)

    def test_plan_json_round_trips_block_workers(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(4)
        plan = Planner(model, cluster).plan(
            _objective(modes=("mixed",), mixed_subsets=2))
        again = Plan.from_json(plan.to_json(), model)
        assert again.block_workers == plan.block_workers
        assert again.search_stats == plan.search_stats
        assert again.objective.mixed_subsets == 2


# ---------------------------------------------------------------------------
# mixed-axis infeasibility reporting
# ---------------------------------------------------------------------------

class TestMixedInfeasible:
    def _tiny_caps_error(self):
        model, workers = small_cnn(), [WorkerParams(), WorkerParams()]
        with pytest.raises(MixedInfeasible) as ei:
            search_mixed_assignment(
                model, workers, np.ones(2),
                ram_caps=np.array([64.0, 64.0]))
        return ei.value

    def test_exception_carries_binding_block(self):
        e = self._tiny_caps_error()
        assert e.block >= 0 and e.peak_bytes > e.cap_bytes
        assert e.best_assignment is not None
        assert len(e.block_indices) >= 1

    def test_planner_details_carry_dp_report(self):
        """InfeasibleError for the mixed axis reports the DP's best
        cap-ignoring assignment and the binding block, not uniform-mode
        proxies."""
        model = mobilenet_v2_smoke()
        cluster = Cluster(
            (WorkerParams(ram_bytes=2048), WorkerParams(ram_bytes=2048)))
        planner = Planner(model, cluster)
        with pytest.raises(InfeasibleError) as ei:
            planner.plan(Objective(modes=("mixed",), ram_cap_bytes=2048))
        err = ei.value
        assert err.binding_constraint == "ram_cap"
        mixed = err.details["mixed"]
        assert mixed["best_infeasible_assignment"] is not None
        assert mixed["peak_bytes"] > mixed["cap_bytes"]
        assert mixed["block"] >= 0 and mixed["block_layers"]


# ---------------------------------------------------------------------------
# telemetry plumbing
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_plan_report_has_search_line(self):
        model = mobilenet_v2_smoke()
        plan = Planner(model, Cluster.heterogeneous_demo(3)).plan(_objective())
        assert plan.search_stats["candidates_evaluated"] > 0
        assert "search:" in plan.report()
        assert "cache hit rate" in plan.report()

    def test_session_stats_carry_search_fields(self):
        model = mobilenet_v2_smoke()
        plan = Planner(model, Cluster.heterogeneous_demo(3)).plan(_objective())
        stats = plan.compile(precision="float").stats()
        assert (stats.search_candidates_evaluated
                == plan.search_stats["candidates_evaluated"])
        assert stats.search_wall_s == plan.search_stats["search_wall_s"]

    def test_bare_splitplan_session_defaults(self):
        from repro.api.session import Session
        from repro.core import split_model
        model = mobilenet_v2_smoke()
        stats = Session(split_model(model, [1.0]),
                        precision="float").stats()
        assert stats.search_candidates_evaluated == 0
        assert np.isnan(stats.search_wall_s)
