"""ISSUE 9 tentpole tests: plan diffing, delta shipping, warm recompiles,
and the serve-through-churn transition protocol.

Layers under test:

* ``shards`` fingerprints — deterministic content identity per array and
  per segment spec, the substrate both the diff layer and the worker-side
  warm caches key on;
* ``diff_plans``/``PlanDiff`` — exact unchanged/moved/resized/new
  classification, reshipped-bytes < full-setup-bytes minimality;
* ``build_segment_fns`` warm cache — unchanged geometry never re-traces;
* ``Session.replan`` — swapping plans reuses the cross-instance executable
  cache and stays bit-exact;
* ``ElasticCoordinator`` — end-to-end: worker killed mid-stream, cluster
  re-plans, output bit-exact vs a single-process Session on the surviving
  topology, only the delta re-shipped, warm-cache hit-rate 1.0, typed
  ``Overloaded(reason="rebalancing")`` at the queue cap;
* hypothesis churn property (``HYPOTHESIS_PROFILE=ci``) — random
  kill/degrade sequences over random heterogeneous clusters keep the plan
  feasible and the diff minimal.
"""
import asyncio
import collections

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import small_cnn
from repro.api.planner import Objective
from repro.api.session import Session
from repro.core.allocation import WorkerParams
from repro.core.executor import CompiledSplitExecutor
from repro.core.splitting import split_model
from repro.runtime.elastic import ElasticCluster
from repro.runtime.replan import ElasticCoordinator, diff_plans
from repro.runtime.shards import (build_segment_fns, build_worker_setup,
                                  delta_setup, setup_array_bytes)
from repro.serve.admission import Overloaded

pytestmark = pytest.mark.xdist_group("runtime")


@pytest.fixture(scope="module")
def model():
    return small_cnn()


@pytest.fixture(scope="module")
def qmodel(model):
    # one shared quantization: bit-exactness comparisons are meaningful
    return Session(split_model(model, np.ones(2)), seed=0).qmodel


class TestFingerprints:
    def test_deterministic(self, model, qmodel):
        s = split_model(model, np.ones(3), mode="spatial", fused=True)
        m1, a1 = build_worker_setup(s, qmodel, "int8", 0)
        m2, a2 = build_worker_setup(s, qmodel, "int8", 0)
        fps1 = [sp.get("fingerprint") for sp in m1["segments"]]
        fps2 = [sp.get("fingerprint") for sp in m2["segments"]]
        assert fps1 == fps2
        assert any(fp is not None for fp in fps1)

    def test_geometry_change_changes_fingerprint(self, model, qmodel):
        s_a = split_model(model, np.array([1.0, 1.0]))
        s_b = split_model(model, np.array([3.0, 1.0]))   # shifted columns
        m_a, _ = build_worker_setup(s_a, qmodel, "int8", 0)
        m_b, _ = build_worker_setup(s_b, qmodel, "int8", 0)
        fps_a = {sp["gi"]: sp["fingerprint"] for sp in m_a["segments"]
                 if "fingerprint" in sp}
        fps_b = {sp["gi"]: sp["fingerprint"] for sp in m_b["segments"]
                 if "fingerprint" in sp}
        assert any(fps_a[gi] != fps_b.get(gi) for gi in fps_a)

    def test_delta_setup_empty_when_all_held(self, model, qmodel):
        s = split_model(model, np.ones(2))
        meta, arrays = build_worker_setup(s, qmodel, "int8", 0)
        held = {fp for sp in meta["segments"]
                for fp in sp.get("array_fps", {}).values()}
        assert delta_setup(meta, arrays, held) == {}
        assert len(delta_setup(meta, arrays, set())) == len(arrays)
        assert setup_array_bytes(arrays) > 0


class TestPlanDiff:
    def test_identity_diff_all_unchanged(self, model, qmodel):
        s = split_model(model, np.ones(3), mode="spatial", fused=True)
        d = diff_plans(s, s, qmodel, "int8")
        assert d.moved == d.resized == d.new == d.removed == 0
        assert d.unchanged > 0
        assert d.reshipped_bytes == 0
        for e in d.entries:
            assert e.status == "unchanged" and e.reship_bytes == 0

    def test_shrink_reships_less_than_full(self, model, qmodel):
        s3 = split_model(model, np.ones(3), mode="spatial", fused=True)
        s2 = split_model(model, np.ones(2), mode="spatial", fused=True)
        d = diff_plans(s3, s2, qmodel, "int8")
        assert d.reshipped_bytes < d.full_setup_bytes
        # spatial survivors replicate full layer weights: band resize
        # re-ships specs, not weights, so only geometry-changed shards
        # re-materialize
        for e in d.entries:
            if e.status == "unchanged":
                assert e.reship_bytes == 0

    def test_unmapped_workers_ship_everything(self, model, qmodel):
        s = split_model(model, np.ones(2))
        d = diff_plans(s, s, qmodel, "int8", worker_map={})
        assert d.reshipped_bytes == d.full_setup_bytes

    def test_summary_mentions_counts(self, model, qmodel):
        s = split_model(model, np.ones(2))
        text = diff_plans(s, s, qmodel, "int8").summary()
        assert "unchanged" in text and "reship" in text


class TestWarmSegmentCache:
    def test_unchanged_geometry_never_retraces(self, model, qmodel):
        s = split_model(model, np.ones(2), mode="spatial", fused=True)
        meta, arrays = build_worker_setup(s, qmodel, "int8", 0)
        cache: collections.OrderedDict = collections.OrderedDict()
        stats: dict = {}
        segs1 = build_segment_fns(meta, arrays, cache=cache, stats=stats)
        assert stats["cache_hits"] == 0
        assert stats["cache_misses"] == len(segs1)
        segs2 = build_segment_fns(meta, arrays, cache=cache, stats=stats)
        assert stats["cache_misses"] == 0
        assert stats["cache_hits"] == len(segs2)
        # reused entries carry the (possibly remapped) group index
        for gi, seg in segs2.items():
            assert seg.gi == gi
            assert seg.fn is segs1[gi].fn      # the jitted fn itself

    def test_no_cache_kwarg_stays_compatible(self, model, qmodel):
        s = split_model(model, np.ones(2))
        meta, arrays = build_worker_setup(s, qmodel, "int8", 0)
        segs = build_segment_fns(meta, arrays)
        assert len(segs) > 0


class TestSessionReplan:
    def test_replan_bitexact_and_warm(self, model, qmodel):
        s2 = split_model(model, np.ones(2), mode="spatial", fused=True)
        s3 = split_model(model, np.ones(3), mode="spatial", fused=True)
        rng = np.random.default_rng(1)
        x = rng.standard_normal(model.input_shape).astype(np.float32)
        sess = Session(s2, qmodel=qmodel)
        y2 = sess.run(x)
        sess.replan(s3)
        assert sess.split is s3
        y3 = sess.run(x)
        assert np.array_equal(y2, y3)   # same qmodel: split is invisible
        # replanning back onto seen geometry hits the cross-instance
        # executable cache — no re-trace
        before = CompiledSplitExecutor.cache_stats()
        sess.replan(s2)
        y2b = sess.run(x)
        after = CompiledSplitExecutor.cache_stats()
        assert np.array_equal(y2, y2b)
        assert after["hits"] > before["hits"]
        assert after["misses"] == before["misses"]

    def test_replan_rejects_other_model(self, model):
        from repro.core.reinterpret import trace_sequential
        sess = Session(split_model(model, np.ones(2)), seed=0)
        other = trace_sequential(
            [dict(kind="conv", out_channels=4, kernel=(3, 3), stride=(1, 1),
                  padding=(1, 1)),
             dict(kind="avgpool"),
             dict(kind="linear", features=10)],
            (3, 24, 24), rng=np.random.default_rng(3))
        with pytest.raises(ValueError, match="different model"):
            sess.replan(split_model(other, np.ones(2)))

    def test_server_replan_tenant_live(self, model, qmodel):
        from repro.serve import Server
        s2 = split_model(model, np.ones(2), mode="spatial", fused=True)
        s3 = split_model(model, np.ones(3), mode="spatial", fused=True)
        rng = np.random.default_rng(4)
        xs = [rng.standard_normal(model.input_shape).astype(np.float32)
              for _ in range(4)]
        ref = Session(s2, qmodel=qmodel)
        srv = Server()
        srv.add_tenant("t", Session(s2, qmodel=qmodel))
        with srv:
            for x in xs[:2]:
                assert np.array_equal(srv.submit("t", x).result(timeout=60.0),
                                      ref.run(x))
            # live topology swap: queued + later requests serve under the
            # new plan, output stays bit-exact (same qmodel)
            srv.replan_tenant("t", s3)
            assert srv.session("t").split is s3
            for x in xs[2:]:
                assert np.array_equal(srv.submit("t", x).result(timeout=60.0),
                                      ref.run(x))

    def test_server_replan_unknown_tenant(self, model):
        from repro.serve import Server
        srv = Server()
        srv.add_tenant("t", split_model(model, np.ones(2)), seed=0)
        with pytest.raises(KeyError, match="unknown tenant"):
            srv.replan_tenant("nope", split_model(model, np.ones(3)))


class TestSessionDistributedElastic:
    def test_facade_builds_elastic_coordinator(self, model, qmodel):
        sess = Session(split_model(model, np.ones(2)), qmodel=qmodel)
        ec = sess.distributed(elastic=True,
                              workers=[WorkerParams() for _ in range(2)],
                              objective=Objective(modes=("spatial",)),
                              spawn="inprocess")
        assert isinstance(ec, ElasticCoordinator)
        # shares the session's quantization: churn cannot shift the scales
        assert ec.qmodel is sess.qmodel
        assert ec.plan.mode == "spatial"

    def test_facade_requires_workers(self, model, qmodel):
        sess = Session(split_model(model, np.ones(2)), qmodel=qmodel)
        with pytest.raises(ValueError, match="workers"):
            sess.distributed(elastic=True)


class TestElasticCoordinatorTyped:
    def test_queue_cap_sheds_typed(self, model, qmodel):
        cluster = ElasticCluster(model, [WorkerParams() for _ in range(2)],
                                 objective=Objective(modes=("spatial",)),
                                 heartbeat_timeout=1e9, clock=lambda: 0.0)
        ec = ElasticCoordinator(cluster, qmodel, spawn="inprocess",
                                queue_cap=0)
        with pytest.raises(Overloaded) as ei:
            asyncio.run(ec.infer(np.zeros(model.input_shape, np.float32)))
        assert ei.value.reason == "rebalancing"
        assert ei.value.queue_depth == 0


class TestChurnEndToEnd:
    def test_kill_then_rejoin_bitexact(self, model, qmodel):
        """Mid-stream worker kill: recovery is bit-exact vs the
        single-process Session on the surviving topology, only moved
        shards re-ship, and every unchanged geometry hits the warm
        compiled cache (rate 1.0, non-vacuous on rejoin)."""
        workers = [WorkerParams() for _ in range(3)]
        cluster = ElasticCluster(model, workers,
                                 objective=Objective(modes=("spatial",)),
                                 heartbeat_timeout=1e9)
        rng = np.random.default_rng(2)
        x = rng.standard_normal(model.input_shape).astype(np.float32)

        async def run():
            out = {}
            async with ElasticCoordinator(cluster, qmodel,
                                          spawn="inprocess") as ec:
                out["y0"] = await ec.infer(x)
                out["split0"] = ec.split
                victim = ec.physical_ids[0]
                await ec.inject_failure(0)
                out["y1"] = await ec.infer(x)     # replan + retry inside
                out["split1"] = ec.split
                out["kill_report"] = ec.reports[-1]
                out["victim_gone"] = victim not in cluster.plan_worker_ids
                out["rejoin_report"] = await ec.rejoin(victim)
                out["y2"] = await ec.infer(x)
                out["split2"] = ec.split
            return out

        out = asyncio.run(run())
        for tag in ("0", "1", "2"):
            oracle = Session(out[f"split{tag}"], qmodel=qmodel)
            assert np.array_equal(out[f"y{tag}"], oracle.run(x)), \
                f"phase {tag} not bit-exact vs single-process Session"
        assert out["victim_gone"]
        kill, rejoin = out["kill_report"], out["rejoin_report"]
        for rep in (kill, rejoin):
            assert rep["reshipped_bytes"] < rep["full_setup_bytes"]
            assert rep["hit_rate"] == 1.0
            assert rep["cache_hits"] == rep["expected_cache_hits"]
        # rejoin returns survivors to their original geometry: the warm
        # cache must hit non-vacuously
        assert rejoin["cache_hits"] > 0
        assert rejoin["spawned"], "rejoined worker needs a fresh process"


# -- hypothesis churn property ---------------------------------------------

_MODEL = None


def _shared_model():
    global _MODEL
    if _MODEL is None:
        _MODEL = small_cnn()
    return _MODEL


@st.composite
def churn_scenarios(draw):
    n = draw(st.integers(min_value=2, max_value=5))
    f = [draw(st.sampled_from([150.0, 300.0, 600.0])) for _ in range(n)]
    flash = [draw(st.sampled_from([16 << 10, 64 << 10, 1 << 20]))
             for _ in range(n)]
    events = draw(st.lists(
        st.tuples(st.sampled_from(["kill", "degrade"]),
                  st.integers(min_value=0, max_value=n - 1)),
        min_size=1, max_size=3))
    return n, f, flash, events


@given(churn_scenarios())
@settings(max_examples=20, deadline=None)
def test_churn_property_feasible_and_minimal(scenario):
    """Random kill/degrade over random heterogeneous clusters: the
    post-churn plan respects every survivor's RAM/flash caps, worker
    identity maps into the alive set, and the plan diff re-materializes
    only geometry-changed shards (unchanged => zero reship bytes)."""
    n, f, flash, events = scenario
    m = _shared_model()
    workers = [WorkerParams(f_mhz=fi, flash_bytes=fl)
               for fi, fl in zip(f, flash)]
    try:
        c = ElasticCluster(m, workers, heartbeat_timeout=1e9,
                           clock=lambda: 0.0)
    except RuntimeError:
        return                          # cluster infeasible from the start
    old_split = c.plan.split
    old_ids = c.plan_worker_ids
    alive = set(range(n))
    for kind, w in events:
        if kind == "kill" and len(alive) > 1 and w in alive:
            c.mark_failed(w)
            alive.discard(w)
        elif kind == "degrade" and w in alive:
            for ww in sorted(alive):
                c.report_step_time(ww, 10.0 if ww == w else 1.0)
    try:
        c.check(now=0.0)
    except RuntimeError:
        return                          # survivors can't fit the model
    # feasibility: every serving worker within its own caps
    for slot, pid in enumerate(c.plan_worker_ids):
        assert pid in alive
        assert (c.plan.split.worker_weight_bytes(slot)
                <= workers[pid].flash_bytes)
        assert c.plan.peak_ram[slot] <= workers[pid].ram_bytes
    # diff minimality: unchanged shards ship zero bytes
    by_pid = {pid: slot for slot, pid in enumerate(old_ids)}
    wmap = {slot: by_pid[pid]
            for slot, pid in enumerate(c.plan_worker_ids)
            if pid in by_pid}
    d = diff_plans(old_split, c.plan.split, qmodel=None,
                   precision="float", worker_map=wmap)
    for e in d.entries:
        if e.status == "unchanged":
            assert e.reship_bytes == 0
    assert d.reshipped_bytes <= d.full_setup_bytes
