"""Fused batched-band spatial execution (the compiled engine's hot path).

The compiled executor runs every band of a fused spatial block as ONE
batched kernel/conv invocation over a (bands, C, rows, W) stack — the band
index lives on the Pallas grid (dwconv) or is folded into the GEMM M axis
(conv), and the block-boundary halo gather happens once per block, not per
band per layer.  These tests hold three lines:

* **parity** — int8 bit-for-bit vs the eager per-band oracle across band
  counts, halo widths (kernel 3/5), stride-2 seams, and mixed
  spatial->kernel plan boundaries (the eager executor was left untouched
  exactly so it can play oracle here);
* **trace shape** — the lowered HLO contains one convolution per block
  stage, independent of the band count (the regression that motivated the
  rewrite: O(bands x layers) convs in the traced graph);
* **executable identity** — the cross-instance compiled-fn cache hits on an
  equal plan fingerprint and misses when geometry or weights change.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import (CompiledSplitExecutor, SplitExecutor,
                        calibrate_scales, quantize_model, reference_forward,
                        split_model, trace_sequential)
from repro.core.splitting import split_model_mixed
from repro.models import mobilenet_v2_smoke

# band counts the ISSUE names: single band (degenerate), even, power-of-two,
# and a 7-way split whose uneven heights force zero-filled stack rows
BAND_RATINGS = ([1.0], [1, 1], [1, 1, 1, 1], list(np.ones(7)))


def _acts_fn(model, x):
    return reference_forward(model, x, collect_activations=True)[1]


def _quantized(model, rng, shape, n_calib=2):
    calib = [rng.standard_normal(shape).astype(np.float32)
             for _ in range(n_calib)]
    scales = calibrate_scales(model, calib, _acts_fn)
    return quantize_model(model, scales)


def _conv_net(kernel, stride, padding, hw, cin=3, cout=5, depthwise=False,
              seed=0):
    spec = [dict(kind="dwconv" if depthwise else "conv",
                 kernel=(kernel, kernel), stride=(stride, stride),
                 padding=(padding, padding), activation="relu6",
                 **({} if depthwise else {"out_channels": cout})),
            dict(kind="conv", out_channels=4, kernel=(1, 1), stride=(1, 1),
                 padding=(0, 0))]
    return trace_sequential(spec, (cin, hw, hw),
                            rng=np.random.default_rng(seed))


def _block_net(stride=1, hw=12, seed=0):
    """expand -> dwconv -> project inverted-residual stack: the fused-block
    shape whose interior stages re-gather band-locally."""
    rng = np.random.default_rng(seed)
    spec = [
        dict(kind="conv", out_channels=4, kernel=(3, 3), stride=(1, 1),
             padding=(1, 1), activation="relu6", save_as="blk"),
        dict(kind="conv", out_channels=12, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0), activation="relu6"),
        dict(kind="dwconv", kernel=(3, 3), stride=(stride, stride),
             padding=(1, 1), activation="relu6"),
        dict(kind="conv", out_channels=4, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0),
             residual_from="blk" if stride == 1 else None),
    ]
    return trace_sequential(spec, (3, hw, hw), rng=rng)


class TestBandCountParity:
    @pytest.mark.parametrize("ratings", BAND_RATINGS,
                             ids=lambda r: f"bands{len(r)}")
    def test_smoke_int8_bit_exact(self, rng, ratings):
        """Batched-band compiled output == eager per-band oracle, bit for
        bit, at every band count (smoke MNv2 includes stride-2 seams and
        residual blocks)."""
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        qm = _quantized(m, rng, (3, 32, 32))
        plan = split_model(m, ratings, mode="spatial")
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, eager)

    @pytest.mark.parametrize("ratings", ([1, 1], [3, 1, 2, 0.5]),
                             ids=("even2", "hetero4"))
    def test_smoke_float_parity(self, rng, ratings):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        plan = split_model(m, ratings, mode="spatial")
        ref = reference_forward(m, x)
        out = CompiledSplitExecutor(plan).run(x)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_run_batch_rides_the_banded_kernels(self, rng):
        """vmap over the banded plan function: batch output rows equal the
        per-sample compiled outputs exactly."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        plan = split_model(m, [2, 1, 1], mode="spatial")
        ex = CompiledSplitExecutor(plan, qm)
        xs = np.stack([rng.standard_normal((3, 32, 32)).astype(np.float32)
                       for _ in range(3)])
        batched = ex.run_batch(xs, mode="int8")
        for i in range(xs.shape[0]):
            np.testing.assert_array_equal(batched[i],
                                          ex.run(xs[i], mode="int8"))


class TestSeamsAndHalos:
    @pytest.mark.parametrize("kernel,stride,padding", [
        (3, 2, 1),   # stride-2 seam: band boundaries land between strides
        (5, 1, 2),   # kernel-5: two-row halos on both sides of every seam
        (5, 2, 2),   # both at once
        (3, 1, 0),   # VALID conv: no padding rows, pure interior halos
    ])
    @pytest.mark.parametrize("ratings", ([1, 1, 1, 1], [2, 1, 3]),
                             ids=("even4", "hetero3"))
    def test_int8_bit_exact(self, rng, kernel, stride, padding, ratings):
        m = _conv_net(kernel, stride, padding, hw=13)
        qm = _quantized(m, rng, m.input_shape)
        x = rng.standard_normal(m.input_shape).astype(np.float32)
        plan = split_model(m, ratings, mode="spatial")
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, eager)

    @pytest.mark.parametrize("stride", (1, 2))
    def test_fused_block_dwconv_seam(self, rng, stride):
        """The expand->dw->project chain (interior band-local re-gather,
        Pallas dwconv grid when enabled) stays bit-exact across a stride
        seam."""
        m = _block_net(stride=stride)
        qm = _quantized(m, rng, (3, 12, 12))
        x = rng.standard_normal((3, 12, 12)).astype(np.float32)
        plan = split_model(m, [1, 2, 1], mode="spatial")
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, eager)

    def test_interpret_mode_pallas_bit_exact(self, rng):
        """Force the Pallas kernels (interpret on CPU) through the banded
        path — dwconv3x3_bands and the im2col_bands+qgemm fold must agree
        with the eager oracle bit-for-bit too."""
        m = _block_net()
        qm = _quantized(m, rng, (3, 12, 12))
        x = rng.standard_normal((3, 12, 12)).astype(np.float32)
        plan = split_model(m, [1, 1, 1], mode="spatial")
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        compiled = CompiledSplitExecutor(plan, qm, use_pallas=True,
                                         interpret=True).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, eager)


class TestMixedBoundary:
    def test_spatial_to_kernel_seam_int8(self, rng):
        """A heterogeneous plan whose spatial block feeds a kernel-mode
        block: the banded row aggregation must hand the flat stage exactly
        the rows the eager oracle produces."""
        from repro.core import group_blocks
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        assignment = ["spatial"] * (n_b // 2) + ["kernel"] * (n_b - n_b // 2)
        qm = _quantized(m, rng, (3, 32, 32))
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        plan = split_model_mixed(m, [2, 1, 1, 1], assignment)
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
        np.testing.assert_array_equal(compiled, eager)


@st.composite
def band_cases(draw):
    kernel = draw(st.sampled_from([3, 5]))
    stride = draw(st.integers(1, 2))
    padding = draw(st.integers(0, kernel // 2))
    hw = draw(st.integers(8, 14))
    n_workers = draw(st.sampled_from([2, 4, 7]))
    ratings = draw(st.lists(st.integers(0, 3), min_size=n_workers,
                            max_size=n_workers).filter(lambda r: sum(r) > 0))
    return kernel, stride, padding, hw, ratings


@given(band_cases())
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_banded_int8_exact(case):
    """Compiled batched-band int8 == eager oracle across random halo widths,
    strides, and zero-rated (empty-band) worker mixes."""
    kernel, stride, padding, hw, ratings = case
    m = _conv_net(kernel, stride, padding, hw)
    rng = np.random.default_rng(3)
    x = rng.standard_normal(m.input_shape).astype(np.float32)
    qm = _quantized(m, rng, m.input_shape)
    plan = split_model(m, ratings, mode="spatial")
    eager = SplitExecutor(plan, qm).run(x, mode="int8")
    compiled = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
    np.testing.assert_array_equal(compiled, eager)


def _hlo_conv_count(plan, qm) -> int:
    ex = CompiledSplitExecutor(plan, qm, use_pallas=False)
    fn = ex._cached_fn("int8", batched=False)
    hlo = fn.lower(
        jnp.zeros(plan.model.input_shape, jnp.float32)).as_text()
    # works on both textual HLO ("... convolution(") and StableHLO MLIR
    # ("stablehlo.convolution(")
    return hlo.count("convolution(")


class TestTraceShape:
    def test_one_conv_per_stage_not_per_band(self, rng):
        """The traced graph must contain one convolution per conv/dwconv
        stage regardless of the band count — the whole point of batching the
        bands.  (jnp fallback path: the Pallas calls would not lower to HLO
        convolutions.)"""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        # int8 dwconv stages lower to shifted-product adds (no HLO
        # convolution — see _dwconv_bands_int32), so the count is one per
        # full-conv stage
        n_convs = sum(1 for lyr in m.layers if lyr.kind == "conv")
        counts = {}
        for ratings in ([1, 1], list(np.ones(7))):
            plan = split_model(m, ratings, mode="spatial")
            counts[len(ratings)] = _hlo_conv_count(plan, qm)
        assert counts[2] == counts[7], (
            f"conv count grew with band count: {counts}")
        assert counts[7] == n_convs, (
            f"expected one fused conv per stage ({n_convs}), "
            f"got {counts[7]}")


class TestExecutableCache:
    def test_equal_plans_share_the_executable(self, rng):
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        plan_a = split_model(m, [2, 1, 1], mode="spatial")
        plan_b = split_model(m, [2, 1, 1], mode="spatial")
        CompiledSplitExecutor.cache_clear()
        ex_a = CompiledSplitExecutor(plan_a, qm)
        ex_b = CompiledSplitExecutor(plan_b, qm)
        assert ex_a.fingerprint == ex_b.fingerprint
        fn_a = ex_a._cached_fn("int8", batched=False)
        fn_b = ex_b._cached_fn("int8", batched=False)
        assert fn_a is fn_b
        stats = CompiledSplitExecutor.cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1

    def test_geometry_change_misses(self, rng):
        """Different ratings -> different band geometry -> different
        fingerprint: a stale executable can never be reused."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        ex_a = CompiledSplitExecutor(split_model(m, [2, 1, 1],
                                                 mode="spatial"), qm)
        ex_b = CompiledSplitExecutor(split_model(m, [1, 1],
                                                 mode="spatial"), qm)
        assert ex_a.fingerprint != ex_b.fingerprint

    def test_weight_change_misses(self, rng):
        """Same geometry, different weights: the fingerprint digests the
        weight bytes, so retrained models never alias."""
        m1 = _conv_net(3, 1, 1, hw=10, seed=0)
        m2 = _conv_net(3, 1, 1, hw=10, seed=1)
        qm1 = _quantized(m1, rng, m1.input_shape)
        qm2 = _quantized(m2, rng, m2.input_shape)
        ex1 = CompiledSplitExecutor(split_model(m1, [1, 1], mode="spatial"),
                                    qm1)
        ex2 = CompiledSplitExecutor(split_model(m2, [1, 1], mode="spatial"),
                                    qm2)
        assert ex1.fingerprint != ex2.fingerprint

    def test_mode_flag_keys_are_distinct(self, rng):
        """float vs int8 and single vs batched all get their own
        executables under one fingerprint."""
        m = _conv_net(3, 1, 1, hw=10)
        qm = _quantized(m, rng, m.input_shape)
        plan = split_model(m, [1, 1], mode="spatial")
        ex = CompiledSplitExecutor(plan, qm)
        fns = {ex._cached_fn("float", False), ex._cached_fn("int8", False),
               ex._cached_fn("int8", True)}
        assert len(fns) == 3

    def test_session_replan_skips_retrace(self, rng):
        """The serving facade's warmup after a re-plan with unchanged
        geometry is a cache hit (the ISSUE's compile-cost satellite)."""
        m = mobilenet_v2_smoke()
        qm = _quantized(m, rng, (3, 32, 32))
        plan = split_model(m, [2, 1, 1], mode="spatial")
        CompiledSplitExecutor.cache_clear()
        CompiledSplitExecutor(plan, qm).warmup((3, 32, 32), mode="int8")
        before = CompiledSplitExecutor.cache_stats()
        CompiledSplitExecutor(split_model(m, [2, 1, 1], mode="spatial"),
                              qm).warmup((3, 32, 32), mode="int8")
        after = CompiledSplitExecutor.cache_stats()
        assert after["hits"] == before["hits"] + 1
        assert after["misses"] == before["misses"]
