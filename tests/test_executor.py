"""Split execution (Alg. 4) must match monolithic inference numerically —
the core correctness claim of the system."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.executor import SplitExecutor, reference_forward
from repro.core.quantize import calibrate_scales, quantize_model
from repro.core.reinterpret import trace_sequential
from repro.core.splitting import split_model
from repro.models import mobilenet_v2_smoke
from conftest import small_cnn


def _acts_fn(model, x):
    return reference_forward(model, x, collect_activations=True)[1]


class TestFloatEquality:
    def test_small_cnn_various_workers(self, rng):
        m = small_cnn()
        x = rng.standard_normal((3, 12, 12)).astype(np.float32)
        ref = reference_forward(m, x)
        for ratings in ([1.0], [1, 1], [3, 1, 2, 0.5], np.ones(8)):
            out = SplitExecutor(split_model(m, ratings)).run(x)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_mobilenet_smoke(self, rng):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        ref = reference_forward(m, x)
        out = SplitExecutor(split_model(m, [2, 1, 1])).run(x)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @given(c1=st.integers(1, 6), c2=st.integers(1, 6), hw=st.integers(4, 10),
           stride=st.integers(1, 2), n=st.integers(1, 6),
           seed=st.integers(0, 100))
    @settings(max_examples=40, deadline=None)
    def test_random_cnn_property(self, c1, c2, hw, stride, n, seed):
        rng = np.random.default_rng(seed)
        spec = [
            dict(kind="conv", out_channels=c1, kernel=(3, 3),
                 stride=(stride, stride), padding=(1, 1), activation="relu6"),
            dict(kind="dwconv", kernel=(3, 3), stride=(1, 1), padding=(1, 1),
                 activation="relu"),
            dict(kind="conv", out_channels=c2, kernel=(1, 1), padding=(0, 0)),
            dict(kind="avgpool"),
            dict(kind="linear", features=5),
        ]
        m = trace_sequential(spec, (2, hw, hw), rng=rng)
        x = rng.standard_normal((2, hw, hw)).astype(np.float32)
        ref = reference_forward(m, x)
        ratings = rng.uniform(0.2, 3.0, n)
        out = SplitExecutor(split_model(m, ratings)).run(x)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


class TestInt8Path:
    def test_int8_matches_float_closely(self, rng):
        m = small_cnn()
        calib = [rng.standard_normal((3, 12, 12)).astype(np.float32)
                 for _ in range(4)]
        scales = calibrate_scales(m, calib, _acts_fn)
        qm = quantize_model(m, scales)
        plan = split_model(m, [1, 2, 1])
        ex = SplitExecutor(plan, qm)
        x = calib[0]
        ref = reference_forward(m, x)
        q_out = ex.run(x, mode="int8").astype(np.float32) * scales[-1]
        corr = np.corrcoef(ref.ravel(), q_out.ravel())[0, 1]
        assert corr > 0.99

    def test_int8_split_equals_int8_single(self, rng):
        """Splitting must not change the quantized result (bit-exact int8)."""
        m = small_cnn()
        calib = [rng.standard_normal((3, 12, 12)).astype(np.float32)
                 for _ in range(2)]
        scales = calibrate_scales(m, calib, _acts_fn)
        qm = quantize_model(m, scales)
        x = calib[0]
        single = SplitExecutor(split_model(m, [1.0]), qm).run(x, mode="int8")
        multi = SplitExecutor(split_model(m, [1, 1, 1, 1]), qm).run(x, mode="int8")
        # int32 accumulation is exact; requant rounding can differ by <=1 ulp
        assert np.max(np.abs(single.astype(np.int32) -
                             multi.astype(np.int32))) <= 1


def test_zero_rating_worker():
    m = small_cnn()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 12, 12)).astype(np.float32)
    ref = reference_forward(m, x)
    out = SplitExecutor(split_model(m, [1.0, 0.0, 1.0])).run(x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
