"""Async transport simulator tests: serial bit-compatibility, the pipelined
event model (timeline consistency, overlap savings, single-link degeneracy,
zero-bandwidth validation), the planner's transport axis, the explicit
infeasible entries in compare_modes, and heterogeneous (mixed-assignment)
plans under both transports."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from conftest import small_cnn
from repro.api import Cluster, Objective, Plan, Planner
from repro.core import (SimConfig, WorkerParams, compare_modes, simulate,
                        split_model, split_model_mixed)
from repro.core.fusion import group_blocks
from repro.core.simulator import _boundary_deps, _segments
from repro.models import mobilenet_v2_smoke


def _demo_workers(n=8):
    return list(Cluster.heterogeneous_demo(n).workers)


# ---------------------------------------------------------------------------
# SimConfig / validation
# ---------------------------------------------------------------------------

class TestConfig:
    def test_default_transport_is_serial(self):
        assert SimConfig().transport == "serial"

    def test_unknown_transport_rejected(self):
        with pytest.raises(ValueError, match="unknown transport"):
            SimConfig(transport="warp")

    def test_zero_bandwidth_link_raises(self):
        m = small_cnn()
        for bad in (0.0, -1.0):
            with pytest.raises(ValueError, match="zero-bandwidth"):
                simulate(m, [WorkerParams(), WorkerParams(b_kb_s=bad)])


# ---------------------------------------------------------------------------
# serial transport: bit-compatible with the pre-transport model
# ---------------------------------------------------------------------------

# pinned from the model this PR inherited: SimConfig() defaults over
# mobilenet_v2_smoke on Cluster.heterogeneous_demo(8), uniform ratings.
# (total_s, comp_s, comm_s, total_bytes, max_peak_ram, max_weight_bytes)
_PINNED_SERIAL = {
    "neuron": (0.2539466285252525, 0.04144629785858587,
               0.21250033066666665, 215296, 4128, 4701),
    "kernel": (0.2539466285252525, 0.04144629785858587,
               0.21250033066666665, 215296, 4128, 4701),
    "spatial": (0.12684274093104964, 0.06834187119191919,
                0.05850086973913045, 49184, 16672, 19674),
}


class TestSerialBitCompat:
    def test_compare_modes_reproduces_pinned_numbers(self):
        reports = compare_modes(mobilenet_v2_smoke(), _demo_workers())
        assert set(reports) == set(_PINNED_SERIAL)
        for mode, (total, comp, comm, nbytes, peak, weights) in \
                _PINNED_SERIAL.items():
            rep = reports[mode]
            assert rep.feasible and rep.transport == "serial"
            assert rep.total_time_s == pytest.approx(total, rel=1e-12)
            assert rep.comp_time_s == pytest.approx(comp, rel=1e-12)
            assert rep.comm_time_s == pytest.approx(comm, rel=1e-12)
            assert rep.total_bytes == nbytes
            assert rep.max_peak_ram == peak
            assert rep.max_weight_bytes == weights
            assert rep.overlap_saved_s == 0.0

    def test_serial_result_has_no_timeline(self):
        res = simulate(small_cnn(), [WorkerParams()] * 3)
        assert res.transport == "serial" and res.timeline is None
        assert res.overlap_saved_s == 0.0
        assert res.total_time == res.serial_total_time


# ---------------------------------------------------------------------------
# pipelined transport
# ---------------------------------------------------------------------------

class TestPipelined:
    def setup_method(self):
        self.m = mobilenet_v2_smoke()
        self.cfg = SimConfig(transport="pipelined")

    def test_single_worker_equals_serial(self):
        """One link: nothing to overlap with — the transports coincide."""
        for p in (WorkerParams(), WorkerParams(f_mhz=150, d_s_per_kb=0.01)):
            serial = simulate(self.m, [p])
            piped = simulate(self.m, [p], cfg=self.cfg)
            assert piped.total_time == serial.total_time
            assert piped.overlap_saved_s == 0.0
            assert piped.timeline is not None
            assert piped.timeline.makespan_s == serial.total_time

    def test_strictly_faster_on_heterogeneous_demo(self):
        """Acceptance: pipelining strictly lowers the 8-MCU demo makespan."""
        ws = _demo_workers()
        for mode in ("neuron", "kernel", "spatial"):
            plan = split_model(self.m, np.ones(8), mode=mode)
            serial = simulate(self.m, ws, plan=plan)
            piped = simulate(self.m, ws, cfg=self.cfg, plan=plan)
            assert piped.total_time < serial.total_time
            assert piped.overlap_saved_s == pytest.approx(
                serial.total_time - piped.total_time, rel=1e-12)

    def test_timeline_consistency(self):
        ws = _demo_workers(4)
        res = simulate(self.m, ws, cfg=self.cfg)
        tl = res.timeline
        assert tl.n_workers == 4
        assert tl.makespan_s == pytest.approx(
            max(e.end_s for e in tl.events), rel=1e-12)
        per_kind: dict[tuple[int, str], list] = {}
        for e in res.timeline.events:
            assert e.kind in ("download", "compute", "upload")
            assert 0.0 <= e.start_s <= e.end_s <= tl.makespan_s + 1e-12
            assert (e.nbytes > 0) == (e.kind != "compute")
            per_kind.setdefault((e.worker, e.kind), []).append(e)
        # each link direction and the core are FIFO resources: same-kind
        # events on one worker never overlap
        for evs in per_kind.values():
            evs.sort(key=lambda e: e.start_s)
            for a, b in zip(evs, evs[1:]):
                assert a.end_s <= b.start_s + 1e-12

    def test_timeline_stats(self):
        res = simulate(self.m, _demo_workers(4), cfg=self.cfg)
        tl = res.timeline
        assert tl.compute_busy_s.shape == (4,)
        assert np.all(tl.idle_s >= 0)
        assert np.all(tl.link_utilization >= 0)
        assert np.all(tl.link_utilization <= 1.0 + 1e-12)
        # comp/comm decomposition: busiest core + exposed (non-overlapped)
        # communication adds up to the makespan
        assert res.comp_time == pytest.approx(tl.compute_busy_s.max())
        assert res.comm_time >= 0
        assert res.comp_time + res.comm_time == pytest.approx(res.total_time)

    def test_downloads_overlap_across_workers(self):
        """The point of per-link queues: transfers to different workers run
        concurrently instead of serializing through the coordinator."""
        res = simulate(self.m, _demo_workers(4), cfg=self.cfg)
        downloads = [e for e in res.timeline.events if e.kind == "download"
                     and e.segment == 0]
        assert len(downloads) > 1
        starts = {e.start_s for e in downloads}
        assert len(starts) == 1  # all first downloads start at t=0, in parallel

    def test_compare_modes_carries_transport_stats(self):
        reports = compare_modes(self.m, _demo_workers(), cfg=self.cfg)
        for rep in reports.values():
            assert rep.transport == "pipelined"
            assert rep.overlap_saved_s > 0
            assert 0 < rep.mean_link_utilization <= 1
            assert rep.max_idle_s >= 0


# ---------------------------------------------------------------------------
# compare_modes: explicit infeasible entries
# ---------------------------------------------------------------------------

class TestCompareModesInfeasible:
    def test_unbuildable_split_yields_infeasible_entry(self):
        m = mobilenet_v2_smoke()
        reports = compare_modes(m, _demo_workers(2), ratings=np.zeros(2))
        assert set(reports) == {"neuron", "kernel", "spatial"}
        for rep in reports.values():
            assert not rep.feasible
            assert "rating" in rep.reason
            assert np.isnan(rep.total_time_s)

    def test_surviving_modes_not_dropped_by_a_failing_one(self, monkeypatch):
        import repro.core.simulator as sim
        m = mobilenet_v2_smoke()
        real = sim.split_model

        def flaky(model, ratings, mode="neuron", **kw):
            if mode == "spatial":
                raise ValueError("synthetic spatial failure")
            return real(model, ratings, mode=mode, **kw)

        monkeypatch.setattr(sim, "split_model", flaky)
        reports = sim.compare_modes(m, _demo_workers(2))
        assert reports["neuron"].feasible and reports["kernel"].feasible
        assert not reports["spatial"].feasible
        assert "synthetic spatial failure" in reports["spatial"].reason


# ---------------------------------------------------------------------------
# planner: transport as the fourth search axis
# ---------------------------------------------------------------------------

class TestPlannerTransportAxis:
    def test_objective_validates_transports(self):
        with pytest.raises(ValueError, match="unknown transport"):
            Objective(transports=("warp",))
        with pytest.raises(ValueError, match="at least one transport"):
            Objective(transports=())
        o = Objective(transports=["pipelined"])
        assert o.transports == ("pipelined",)

    def test_objective_round_trip_and_legacy_default(self):
        o = Objective(minimize="latency", transports=("pipelined", "serial"))
        assert Objective.from_dict(o.to_dict()) == o
        legacy = {k: v for k, v in o.to_dict().items() if k != "transports"}
        assert Objective.from_dict(legacy).transports == ("serial",)

    def test_planner_selects_pipelined_for_latency(self):
        """Acceptance: minimizing latency over the 8-MCU demo picks the
        async transport, and its candidate table shows both policies."""
        plan = Planner(mobilenet_v2_smoke(), Cluster.heterogeneous_demo(8)) \
            .plan(Objective(minimize="latency", ram_cap_bytes=512 * 1024))
        assert plan.transport == "pipelined"
        assert plan.overlap_saved_s > 0
        transports = {c.transport for c in plan.candidates if c.feasible}
        assert transports == {"serial", "pipelined"}
        # the pipelined twin of every feasible candidate is never slower
        by_key = {(c.mode, c.fusion, c.worker_indices, c.transport): c
                  for c in plan.candidates if c.feasible}
        for (mode, fusion, idx, t), c in by_key.items():
            if t == "serial":
                twin = by_key[(mode, fusion, idx, "pipelined")]
                assert twin.latency_s <= c.latency_s + 1e-12
        assert "transport=pipelined" in plan.report()

    def test_serial_only_objective_matches_legacy_search(self):
        model = mobilenet_v2_smoke()
        cluster = Cluster.heterogeneous_demo(3)
        plan = Planner(model, cluster).plan(
            Objective(minimize="latency", ram_cap_bytes=512 * 1024,
                      transports=("serial",)))
        assert plan.transport == "serial"
        assert plan.overlap_saved_s == 0.0
        assert all(c.transport in ("serial", "*") for c in plan.candidates)

    def test_transport_tiebreak_prefers_serial(self):
        """When transport cannot change the score (minimize=peak_ram), the
        objective's order breaks the tie — serial first by default."""
        plan = Planner(mobilenet_v2_smoke(), Cluster.heterogeneous_demo(2)) \
            .plan(Objective(minimize="peak_ram"))
        assert plan.transport == "serial"

    def test_plan_json_round_trip_carries_transport(self):
        model = mobilenet_v2_smoke()
        plan = Planner(model, Cluster.heterogeneous_demo(3)).plan(
            Objective(minimize="latency", ram_cap_bytes=512 * 1024))
        loaded = Plan.from_json(plan.to_json(), model)
        assert loaded.transport == plan.transport
        assert loaded.overlap_saved_s == pytest.approx(plan.overlap_saved_s)
        assert loaded.objective.transports == plan.objective.transports
        cands = {(c.mode, c.transport) for c in loaded.candidates}
        assert cands == {(c.mode, c.transport) for c in plan.candidates}


# ---------------------------------------------------------------------------
# mixed (heterogeneous-assignment) plans under both transports
# ---------------------------------------------------------------------------

class TestMixedTransport:
    def test_segments_follow_block_structure(self):
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        assignment = ["spatial" if i % 2 == 0 else "kernel"
                      for i in range(n_b)]
        plan = split_model_mixed(m, np.ones(4), assignment)
        segs = _segments(plan)
        # spatial-assigned conv blocks fuse into one transfer segment;
        # flat-assigned blocks contribute one segment per layer
        assert [tuple(g) for g in plan.block_groups] == segs
        assert [i for s in segs for i in s] == list(range(len(m.layers)))

    def test_seam_boundary_deps_barrier_vs_row_overlap(self):
        """A spatial->flat (or flat->spatial) seam degrades to the
        per-boundary barrier; a spatial->spatial seam keeps the exact
        row-overlap dependency set."""
        m = mobilenet_v2_smoke()
        n_b = len(group_blocks(m))
        assignment = ["spatial", "kernel"] + ["spatial"] * (n_b - 2)
        plan = split_model_mixed(m, np.ones(4), assignment)
        segs = _segments(plan)
        # seg 0 (spatial block) -> seg 1 (first kernel layer): mixed seam
        first_flat = segs[1][0]
        up = np.ones(4, dtype=np.int64)
        deps = _boundary_deps(plan.splits[segs[0][-1]],
                              plan.splits[first_flat], up)
        assert deps == [[0, 1, 2, 3]] * 4
        # find a spatial->spatial seam and check it is not a full barrier
        spatial_seams = [
            (a[-1], b[0]) for a, b in zip(segs, segs[1:])
            if plan.splits[a[-1]].mode == "spatial"
            and plan.splits[b[0]].mode == "spatial"]
        assert spatial_seams
        prev_li, li = spatial_seams[0]
        deps = _boundary_deps(plan.splits[prev_li], plan.splits[li], up)
        assert any(d != [0, 1, 2, 3] for d in deps)

    def test_mixed_pipelined_not_slower_on_demo(self):
        m = mobilenet_v2_smoke()
        ws = _demo_workers()
        n_b = len(group_blocks(m))
        assignment = ["spatial"] * (n_b // 2) + \
            ["neuron"] * (n_b - n_b // 2)
        plan = split_model_mixed(m, np.ones(8), assignment)
        serial = simulate(m, ws, plan=plan)
        piped = simulate(m, ws, cfg=SimConfig(transport="pipelined"),
                         plan=plan)
        assert piped.total_time < serial.total_time
        assert piped.timeline is not None
        assert piped.overlap_saved_s == pytest.approx(
            serial.serial_total_time - piped.total_time, rel=1e-12)


# ---------------------------------------------------------------------------
# hypothesis sweep: savings are monotone non-negative on heterogeneous
# clusters (the pipelined schedule only relaxes serialization constraints)
# ---------------------------------------------------------------------------

@st.composite
def het_clusters(draw):
    n = draw(st.integers(2, 6))
    workers = [WorkerParams(
        f_mhz=draw(st.floats(50.0, 1000.0)),
        d_s_per_kb=draw(st.floats(0.0, 0.05)),
        b_kb_s=draw(st.floats(100.0, 200000.0))) for _ in range(n)]
    ratings = np.array([draw(st.floats(0.01, 5.0)) for _ in range(n)])
    mode = draw(st.sampled_from(["neuron", "kernel", "spatial"]))
    overlap = draw(st.booleans())
    return workers, ratings, mode, overlap


@given(het_clusters())
@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_overlap_savings_nonnegative(case):
    workers, ratings, mode, overlap = case
    m = small_cnn()
    plan = split_model(m, ratings, mode=mode)
    res = simulate(m, workers, ratings,
                   SimConfig(transport="pipelined", overlap=overlap),
                   plan=plan)
    assert res.overlap_saved_s >= -1e-9
    assert res.total_time > 0
    assert res.total_time <= res.serial_total_time + 1e-9


@st.composite
def het_mixed_cases(draw):
    n = draw(st.integers(2, 5))
    workers = [WorkerParams(
        f_mhz=draw(st.floats(50.0, 1000.0)),
        d_s_per_kb=draw(st.floats(0.0, 0.05)),
        b_kb_s=draw(st.floats(100.0, 200000.0))) for _ in range(n)]
    ratings = np.array([draw(st.floats(0.01, 5.0)) for _ in range(n)])
    n_blocks = len(group_blocks(small_cnn()))
    assignment = [draw(st.sampled_from(["neuron", "kernel", "spatial"]))
                  for _ in range(n_blocks)]
    overlap = draw(st.booleans())
    return workers, ratings, assignment, overlap


@given(het_mixed_cases())
@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_mixed_pipelined_never_exceeds_serial(case):
    """Heterogeneous per-block assignments: the pipelined makespan may never
    exceed the serial total, across every seam combination."""
    workers, ratings, assignment, overlap = case
    m = small_cnn()
    plan = split_model_mixed(m, ratings, assignment)
    res = simulate(m, workers, ratings,
                   SimConfig(transport="pipelined", overlap=overlap),
                   plan=plan)
    assert res.overlap_saved_s >= -1e-9
    assert res.total_time <= res.serial_total_time + 1e-9
    serial = simulate(m, workers, ratings, SimConfig(overlap=overlap),
                      plan=plan)
    assert res.serial_total_time == pytest.approx(serial.total_time,
                                                  rel=1e-12)
