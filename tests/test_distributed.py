"""Multi-device tests: run in subprocesses with forced host devices so the
rest of the suite keeps seeing 1 device."""
import os
import subprocess
import sys
import textwrap

import pytest

# each test forks a fresh interpreter with 8 forced host devices: keep the
# module on one xdist worker (serial group) to bound peak process count
pytestmark = pytest.mark.xdist_group("runtime")

_ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src"}


def _run(code: str, timeout=500):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=_ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,2,2) mesh and on 1 device must produce
    the same loss trajectory — sharding must not change the math."""
    _run("""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainOptions, init_train_state, make_train_step
    from repro.data.pipeline import SyntheticLM

    cfg = get_config("qwen3-14b-smoke")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    data = SyntheticLM(cfg.vocab_size, seed=0)

    def losses(mesh):
        step, rules = make_train_step(cfg, opt_cfg, mesh,
                                      TrainOptions(donate=False))
        params, opt = init_train_state(cfg, jax.random.PRNGKey(0),
                                       mesh=mesh, rules=rules)
        out = []
        for i in range(3):
            b = data.batch(i, 8, 32)
            params, opt, m = step(params, opt, b)
            out.append(float(m["loss"]))
        return out

    l1 = losses(None)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    with mesh:
        l8 = losses(mesh)
    np.testing.assert_allclose(l1, l8, rtol=2e-2)
    print("OK", l1, l8)
    """)


def test_dryrun_reduced_cells_compile_multipod():
    """lower+compile a reduced arch on a (2,2,2) multi-pod mesh for all
    three step kinds (train/prefill/decode)."""
    _run("""
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, ShapeConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.dryrun import lower_cell

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    for arch in ["qwen3-14b", "deepseek-moe-16b", "recurrentgemma-9b",
                 "whisper-base", "xlstm-1.3b"]:
        cfg = get_config(arch + "-smoke")
        for shape in [ShapeConfig("t", 32, 8, "train"),
                      ShapeConfig("p", 32, 4, "prefill"),
                      ShapeConfig("d", 32, 8, "decode")]:
            lowered, _ = lower_cell(cfg, shape, mesh)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
            print(arch, shape.mode, "compiled OK")
    """)


def test_compressed_psum_shard_map():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel.collectives import make_compressed_grad_sync

    mesh = make_mesh((8,), ("data",))
    sync = make_compressed_grad_sync(mesh, "data", bits=8)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((8, 16)).astype(np.float32))}
    with mesh:
        out = sync(g)
    # psum over a replicated input = 8x; int8 quant error <= 8 * scale/2
    bound = 8 * float(jnp.max(jnp.abs(g["w"]))) / 127 / 2 * 1.05
    err = float(jnp.max(jnp.abs(out["w"] - g["w"] * 8)))
    assert err <= bound, (err, bound)
    print("compressed psum OK", err, "<=", bound)
    """)


def test_checkpoint_reshard_restore():
    """Save on a (4,2) mesh, restore onto (2,4) — elastic restart path."""
    _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
    from repro.launch.mesh import make_mesh

    m1 = make_mesh((4, 2), ("data", "model"))
    m2 = make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(m1, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": xs})
        out = restore_checkpoint(d, 1, {"x": x},
                                 shardings={"x": NamedSharding(m2, P("data", "model"))})
        np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
        assert out["x"].sharding.mesh.shape["model"] == 4
    print("reshard restore OK")
    """)


def test_elastic_mesh_rescale_end_to_end():
    """Train 2 steps on 8 devices, 'lose' 4, restore the checkpoint onto a
    4-device mesh and keep training — loss stays finite and decreasing-ish."""
    _run("""
    import tempfile, jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import (TrainOptions, abstract_train_state,
                                     init_train_state, make_train_step)
    from repro.parallel.sharding import param_shardings
    from repro.models import lm
    from repro.data.pipeline import SyntheticLM

    cfg = get_config("qwen3-14b-smoke")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    data = SyntheticLM(cfg.vocab_size, seed=0)
    mesh8 = make_mesh((4, 2), ("data", "model"))
    step8, rules8 = make_train_step(cfg, opt_cfg, mesh8, TrainOptions(donate=False))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0), mesh8, rules8)
    with mesh8:
        for i in range(2):
            params, opt, m = step8(params, opt, data.batch(i, 8, 32))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 2, {"params": params, "opt": opt})
        # half the pod dies: rebuild on (2,2)
        mesh4 = make_mesh((2, 2), ("data", "model"))
        step4, rules4 = make_train_step(cfg, opt_cfg, mesh4, TrainOptions(donate=False))
        p_abs, o_abs = abstract_train_state(cfg, rules4)
        p_sh = jax.tree.map(lambda s: s.sharding, p_abs)
        o_sh = jax.tree.map(lambda s: s.sharding, o_abs)
        restored = restore_checkpoint(d, 2, {"params": params, "opt": opt},
                                      shardings={"params": p_sh, "opt": o_sh})
    with mesh4:
        params4, opt4 = restored["params"], restored["opt"]
        for i in range(2, 4):
            params4, opt4, m = step4(params4, opt4, data.batch(i, 8, 32))
            assert np.isfinite(float(m["loss"]))
    print("elastic rescale OK, final loss", float(m["loss"]))
    """)
