"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device;
only launch/dryrun.py (and the subprocess tests) force 512/8 host devices.

If ``hypothesis`` is not installed (it is an optional dev dependency — see
requirements.txt) we install a minimal stand-in module so that test modules
using ``@given``/``@settings`` still *collect*; every property test then
skips with a clear reason instead of erroring the whole module at import.

When hypothesis *is* available, two profiles are registered and selected
via ``HYPOTHESIS_PROFILE`` (the CI test job exports ``ci``):

* ``ci`` — derandomized (fixed seed: a matrix cell cannot flake on a fresh
  random draw), ``deadline=None`` (shared runners stall unpredictably), and
  a bumped ``max_examples`` so the extra determinism is spent on coverage;
* ``dev`` (default) — hypothesis defaults minus the deadline.
"""
import os
import sys
import types

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", derandomize=True, deadline=None,
                                   max_examples=200, print_blob=True)
    _hyp_settings.register_profile("dev", deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    def _given(*_args, **_kwargs):
        def deco(_fn):
            # A signature-free wrapper: pytest sees no fixture params, so the
            # test runs (and immediately skips) instead of failing to resolve
            # the strategy arguments.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed — property test skipped")
            skipper.__name__ = getattr(_fn, "__name__", "property_test")
            skipper.__doc__ = getattr(_fn, "__doc__", None)
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Placeholder for strategy objects (never drawn from)."""

        def __getattr__(self, name):
            return _AnyStrategy()

        def __call__(self, *a, **k):
            return _AnyStrategy()

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "text", "composite"):
        setattr(_st, _name, _AnyStrategy())

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.HealthCheck = _AnyStrategy()
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def small_cnn(seed=0):
    """A small conv+dw+linear net exercising every layer kind."""
    from repro.core.reinterpret import trace_sequential
    spec = [
        dict(kind="conv", out_channels=6, kernel=(3, 3), stride=(1, 1),
             padding=(1, 1), activation="relu6", save_as="blk"),
        dict(kind="dwconv", kernel=(3, 3), stride=(1, 1), padding=(1, 1),
             activation="relu6"),
        dict(kind="conv", out_channels=6, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0), residual_from="blk"),
        dict(kind="conv", out_channels=8, kernel=(3, 3), stride=(2, 2),
             padding=(1, 1), activation="relu"),
        dict(kind="avgpool"),
        dict(kind="linear", features=10),
    ]
    return trace_sequential(spec, (3, 12, 12),
                            rng=np.random.default_rng(seed))
