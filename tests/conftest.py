"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device;
only launch/dryrun.py (and the subprocess tests) force 512/8 host devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def small_cnn(seed=0):
    """A small conv+dw+linear net exercising every layer kind."""
    from repro.core.reinterpret import trace_sequential
    spec = [
        dict(kind="conv", out_channels=6, kernel=(3, 3), stride=(1, 1),
             padding=(1, 1), activation="relu6", save_as="blk"),
        dict(kind="dwconv", kernel=(3, 3), stride=(1, 1), padding=(1, 1),
             activation="relu6"),
        dict(kind="conv", out_channels=6, kernel=(1, 1), stride=(1, 1),
             padding=(0, 0), residual_from="blk"),
        dict(kind="conv", out_channels=8, kernel=(3, 3), stride=(2, 2),
             padding=(1, 1), activation="relu"),
        dict(kind="avgpool"),
        dict(kind="linear", features=10),
    ]
    return trace_sequential(spec, (3, 12, 12),
                            rng=np.random.default_rng(seed))
