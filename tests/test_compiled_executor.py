"""The compiled engine must match the eager oracle: float to tolerance,
int8 bit-for-bit (the epilogue contract in core.quantize), across worker
counts, heterogeneous ratings, batching, and the Pallas-kernel hot path."""
import numpy as np
import pytest

from repro.core import (CompiledSplitExecutor, SplitExecutor, calibrate_scales,
                        compile_shard_geometry, quantize_model,
                        reference_forward, split_model)
from repro.models import mobilenet_v2_smoke
from conftest import small_cnn

RATINGS = ([1.0], [1, 1, 1], np.ones(8), [3, 1, 2, 0.5])


def _acts_fn(model, x):
    return reference_forward(model, x, collect_activations=True)[1]


def _quantized(model, rng, shape, n_calib=3):
    calib = [rng.standard_normal(shape).astype(np.float32)
             for _ in range(n_calib)]
    scales = calibrate_scales(model, calib, _acts_fn)
    return quantize_model(model, scales), calib


class TestGeometry:
    def test_index_map_matches_worker_compute_decomposition(self):
        """The precomputed bbox map must be the contiguous run the executor
        slices, for every shard of every layer of the smoke model."""
        m = mobilenet_v2_smoke()
        for ratings in RATINGS:
            plan = split_model(m, ratings)
            for layer, split in zip(m.layers, plan.splits):
                geoms = compile_shard_geometry(layer, split)
                if layer.kind not in ("conv", "dwconv"):
                    assert all(g is None for g in geoms)
                    continue
                c_out, h_out, w_out = layer.out_shape
                hw = h_out * w_out
                for g, sh in zip(geoms, split.shards):
                    if sh.n_positions == 0:
                        assert g is None
                        continue
                    assert (g.start, g.stop) == (sh.start, sh.stop)
                    assert g.c_lo == sh.start // hw
                    assert g.c_hi == (sh.stop - 1) // hw
                    # index map is exactly the contiguous run at bbox_start
                    np.testing.assert_array_equal(
                        g.bbox_index,
                        np.arange(g.n_positions) + g.bbox_start)
                    # bbox holds the full shard
                    assert g.bbox_index[-1] < \
                        g.n_channels * g.n_rows * w_out


class TestFloatParity:
    def test_smoke_matches_eager_and_reference(self, rng):
        m = mobilenet_v2_smoke()
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        ref = reference_forward(m, x)
        for ratings in RATINGS:
            plan = split_model(m, ratings)
            eager = SplitExecutor(plan).run(x)
            out = CompiledSplitExecutor(plan).run(x)
            np.testing.assert_allclose(out, eager, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_small_cnn_zero_rating_worker(self, rng):
        m = small_cnn()
        x = rng.standard_normal((3, 12, 12)).astype(np.float32)
        plan = split_model(m, [1.0, 0.0, 1.0])
        out = CompiledSplitExecutor(plan).run(x)
        np.testing.assert_allclose(out, reference_forward(m, x),
                                   rtol=1e-5, atol=1e-5)


class TestInt8Parity:
    def test_smoke_bit_exact_vs_eager(self, rng):
        """int8 is integer accumulation + a multiply-only f32 epilogue, so
        compiled must equal eager *exactly* for any split."""
        m = mobilenet_v2_smoke()
        qm, calib = _quantized(m, rng, (3, 32, 32))
        x = calib[0]
        for ratings in RATINGS:
            plan = split_model(m, ratings)
            eager = SplitExecutor(plan, qm).run(x, mode="int8")
            out = CompiledSplitExecutor(plan, qm).run(x, mode="int8")
            np.testing.assert_array_equal(out, eager)

    def test_int8_requires_qmodel(self):
        m = small_cnn()
        ex = CompiledSplitExecutor(split_model(m, [1, 1]))
        with pytest.raises(ValueError):
            ex.run(np.zeros((3, 12, 12), np.float32), mode="int8")


class TestPallasPath:
    """use_pallas=True routes dwconv through the Pallas dwconv3x3 kernel and
    conv/linear through qgemm (interpret mode on CPU).  The int32-bias
    epilogue keeps even this path bit-exact against the eager oracle."""

    def test_small_cnn_bit_exact(self, rng):
        m = small_cnn()
        qm, calib = _quantized(m, rng, (3, 12, 12))
        x = calib[0]
        plan = split_model(m, [1, 2, 1])
        eager = SplitExecutor(plan, qm).run(x, mode="int8")
        out = CompiledSplitExecutor(plan, qm, use_pallas=True,
                                    interpret=True).run(x, mode="int8")
        np.testing.assert_array_equal(out, eager)

    def test_batch_matches_singles(self, rng):
        m = small_cnn()
        qm, _ = _quantized(m, rng, (3, 12, 12))
        plan = split_model(m, [1, 1])
        ex = CompiledSplitExecutor(plan, qm, use_pallas=True, interpret=True)
        xs = np.stack([rng.standard_normal((3, 12, 12)).astype(np.float32)
                       for _ in range(3)])
        batch = ex.run_batch(xs, mode="int8")
        singles = np.stack([ex.run(xs[i], mode="int8") for i in range(3)])
        np.testing.assert_array_equal(batch, singles)


class TestBatching:
    def test_run_batch_equals_independent_runs(self, rng):
        m = mobilenet_v2_smoke()
        qm, _ = _quantized(m, rng, (3, 32, 32))
        plan = split_model(m, [2, 1, 1])
        ex = CompiledSplitExecutor(plan, qm)
        xs = np.stack([rng.standard_normal((3, 32, 32)).astype(np.float32)
                       for _ in range(8)])
        bq = ex.run_batch(xs, mode="int8")
        sq = np.stack([ex.run(xs[i], mode="int8") for i in range(8)])
        np.testing.assert_array_equal(bq, sq)
        # and against the eager oracle
        eq = np.stack([SplitExecutor(plan, qm).run(xs[i], mode="int8")
                       for i in range(8)])
        np.testing.assert_array_equal(bq, eq)

    def test_run_batch_float(self, rng):
        m = mobilenet_v2_smoke()
        plan = split_model(m, [1, 1, 1])
        ex = CompiledSplitExecutor(plan)
        xs = np.stack([rng.standard_normal((3, 32, 32)).astype(np.float32)
                       for _ in range(4)])
        bf = ex.run_batch(xs)
        sf = np.stack([ex.run(xs[i]) for i in range(4)])
        np.testing.assert_allclose(bf, sf, rtol=1e-5, atol=1e-6)

    def test_replicated_input_rows_identical(self, rng):
        """run_batch(stack([x]*B)) must produce B identical rows equal to
        run(x) — the vmapped trace is sample-independent."""
        m = mobilenet_v2_smoke()
        qm, _ = _quantized(m, rng, (3, 32, 32))
        ex = CompiledSplitExecutor(split_model(m, [1, 1]), qm)
        x = rng.standard_normal((3, 32, 32)).astype(np.float32)
        out = ex.run_batch(np.stack([x] * 5), mode="int8")
        single = ex.run(x, mode="int8")
        for b in range(5):
            np.testing.assert_array_equal(out[b], single)

    def test_warmup(self, rng):
        m = small_cnn()
        ex = CompiledSplitExecutor(split_model(m, [1, 1]))
        ex.warmup()
        ex.warmup(batch=2)
