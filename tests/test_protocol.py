"""Frame-protocol unit tests: round-trip fidelity and corruption handling.

Stream-level cases run against a hand-fed ``asyncio.StreamReader`` — no
sockets needed — and every async body runs under an outer ``wait_for`` so a
protocol bug can never hang the suite.
"""
import asyncio

import numpy as np
import pytest

from repro.runtime.protocol import (MAX_FRAME_BYTES, ConnectionClosed,
                                    ProtocolError, decode_body, encode_frame,
                                    read_frame)

# event-loop + socket-pair tests: one xdist worker (serial group)
pytestmark = pytest.mark.xdist_group("runtime")

TIMEOUT = 30


def run(coro):
    return asyncio.run(asyncio.wait_for(coro, TIMEOUT))


def roundtrip(ftype, meta=None, arrays=None):
    body = encode_frame(ftype, meta, arrays)[4:]
    return decode_body(body)


class TestRoundTrip:
    def test_meta_and_type(self):
        t, meta, arrays = roundtrip("hello", {"worker": 3, "x": [1, 2]})
        assert t == "hello"
        assert meta == {"worker": 3, "x": [1, 2]}
        assert arrays == {}

    @pytest.mark.parametrize("dtype", [np.int8, np.int32, np.float32])
    def test_array_dtypes(self, dtype, rng):
        a = (rng.standard_normal((3, 4, 5)) * 50).astype(dtype)
        _, _, arrays = roundtrip("m", None, {"a": a})
        assert arrays["a"].dtype == a.dtype
        np.testing.assert_array_equal(arrays["a"], a)

    def test_multiple_arrays_keep_order_and_values(self, rng):
        arrs = {"w": rng.standard_normal((2, 3)).astype(np.float32),
                "b": np.arange(7, dtype=np.int32),
                "empty": np.zeros((4, 0, 3), np.int8)}
        _, _, out = roundtrip("setup", {"k": 1}, arrs)
        assert list(out) == ["w", "b", "empty"]
        for k in arrs:
            np.testing.assert_array_equal(out[k], arrs[k])
            assert out[k].shape == arrs[k].shape

    def test_noncontiguous_input(self, rng):
        a = rng.standard_normal((6, 6)).astype(np.float32)[::2, 1:]
        _, _, out = roundtrip("m", None, {"a": a})
        np.testing.assert_array_equal(out["a"], a)


class TestCorruption:
    def test_trailing_bytes_rejected(self):
        body = encode_frame("m", None, {"a": np.zeros(3, np.int8)})[4:]
        with pytest.raises(ProtocolError, match="trailing"):
            decode_body(body + b"xx")

    def test_header_overrun(self):
        with pytest.raises(ProtocolError, match="overruns"):
            decode_body(b"\xff\xff\x00\x00tiny")

    def test_array_payload_truncated(self):
        body = bytearray(encode_frame("m", None,
                                      {"a": np.zeros(8, np.int8)})[4:])
        with pytest.raises(ProtocolError, match="overruns the frame body"):
            decode_body(bytes(body[:-4]))

    def test_element_size_mismatch(self):
        # header claims f32 but ships 3 bytes
        import json
        import struct
        header = json.dumps({"type": "m", "meta": {},
                             "arrays": [["a", "<f4", [3], 3]]}).encode()
        body = struct.pack("<I", len(header)) + header + b"abc"
        with pytest.raises(ProtocolError, match="element"):
            decode_body(body)

    def test_undecodable_header(self):
        import struct
        body = struct.pack("<I", 7) + b"notjson"
        with pytest.raises(ProtocolError, match="undecodable"):
            decode_body(body)

    def test_oversize_frame_rejected_at_encode(self):
        class Huge:
            pass
        with pytest.raises(ProtocolError, match="exceeds"):
            # fake the size check without allocating a gigabyte
            big = np.lib.stride_tricks.as_strided(
                np.zeros(1, np.int8), shape=(MAX_FRAME_BYTES + 1,),
                strides=(0,))
            encode_frame("m", None, {"a": big})


class TestStream:
    @staticmethod
    def _reader(data: bytes, eof: bool = True) -> asyncio.StreamReader:
        r = asyncio.StreamReader()
        r.feed_data(data)
        if eof:
            r.feed_eof()
        return r

    def test_read_frame_roundtrip(self, rng):
        a = (rng.standard_normal(10) * 9).astype(np.int8)

        async def main():
            wire = encode_frame("result", {"seq": 1}, {"y": a})
            f = await read_frame(self._reader(wire))
            assert f.type == "result" and f.meta == {"seq": 1}
            np.testing.assert_array_equal(f.arrays["y"], a)
            assert f.nbytes == len(wire)
            assert f.recv_end >= f.recv_start > 0
        run(main())

    def test_eof_on_boundary_is_connection_closed(self):
        async def main():
            with pytest.raises(ConnectionClosed):
                await read_frame(self._reader(b""))
        run(main())

    def test_truncated_body_is_protocol_error(self):
        async def main():
            wire = encode_frame("m", {"k": 1}, {"a": np.zeros(64, np.int8)})
            with pytest.raises(ProtocolError, match="truncated frame"):
                await read_frame(self._reader(wire[:len(wire) // 2]))
        run(main())

    def test_truncated_length_prefix_is_protocol_error(self):
        async def main():
            with pytest.raises(ProtocolError, match="length-prefix"):
                await read_frame(self._reader(b"\x01\x02"))
        run(main())

    def test_corrupt_length_prefix_rejected_before_alloc(self):
        async def main():
            with pytest.raises(ProtocolError, match="corrupt length"):
                await read_frame(self._reader(b"\xff\xff\xff\xff" + b"x" * 8,
                                              eof=False))
        run(main())
