"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
ref.py pure-jnp oracles (interpret=True on CPU; TPU is the target)."""
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import flash_decode, flash_decode_ref
from repro.kernels.dwconv.ops import dwconv, dwconv_ref
from repro.kernels.qgemm.ops import (qconv2d, qconv2d_ref, qgemm_padded)
from repro.kernels.qgemm.ref import qgemm_ref


@pytest.fixture
def rng():
    return np.random.default_rng(42)


class TestQGEMM:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (64, 200, 72), (300, 128, 513)])
    @pytest.mark.parametrize("act,osc", [(None, None), ("relu", None),
                                         ("relu6", 0.05), (None, 0.02)])
    def test_sweep_vs_ref(self, rng, m, k, n, act, osc):
        x = rng.integers(-127, 128, (m, k)).astype(np.int8)
        w = rng.integers(-127, 128, (k, n)).astype(np.int8)
        s = rng.uniform(1e-3, 1e-2, n).astype(np.float32)
        b = rng.uniform(-1, 1, n).astype(np.float32)
        got = np.asarray(qgemm_padded(x, w, s, b, activation=act,
                                      out_scale=osc), np.float32)
        exp = np.asarray(qgemm_ref(x, w, s, b, activation=act,
                                   out_scale=osc), np.float32)
        if osc is not None:
            assert np.max(np.abs(got - exp)) <= 1     # requant ulp
        else:
            np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-3)

    def test_int32_accumulation_exact(self, rng):
        """No epilogue scaling: int32 accumulation must be bit-exact."""
        x = rng.integers(-127, 128, (128, 512)).astype(np.int8)
        w = rng.integers(-127, 128, (512, 128)).astype(np.int8)
        ones = np.ones(128, np.float32)
        zeros = np.zeros(128, np.float32)
        got = np.asarray(qgemm_padded(x, w, ones, zeros))
        exp = x.astype(np.int64) @ w.astype(np.int64)
        np.testing.assert_array_equal(got.astype(np.int64), exp)

    @pytest.mark.parametrize("stride", [(1, 1), (2, 2)])
    def test_qconv2d(self, rng, stride):
        x = rng.integers(-127, 128, (16, 14, 14)).astype(np.int8)
        w = rng.integers(-127, 128, (24, 16, 3, 3)).astype(np.int8)
        s = rng.uniform(1e-3, 1e-2, 24).astype(np.float32)
        b = rng.uniform(-1, 1, 24).astype(np.float32)
        got = qconv2d(x, w, s, b, stride=stride, padding=(1, 1),
                      activation="relu6", out_scale=0.05)
        exp = qconv2d_ref(x, w, s, b, stride=stride, padding=(1, 1),
                          activation="relu6", out_scale=0.05)
        assert np.max(np.abs(np.asarray(got, np.int32)
                             - np.asarray(exp, np.int32))) <= 1

    def test_qconv_matches_float_conv(self, rng):
        """End-to-end quantized conv tracks the float conv (corr > 0.99)."""
        import jax
        import jax.numpy as jnp
        xf = rng.standard_normal((8, 10, 10)).astype(np.float32)
        wf = (rng.standard_normal((12, 8, 3, 3)) * 0.1).astype(np.float32)
        sx = np.abs(xf).max() / 127
        x_q = np.clip(np.round(xf / sx), -127, 127).astype(np.int8)
        sw = np.abs(wf).max(axis=(1, 2, 3)) / 127
        w_q = np.clip(np.round(wf / sw[:, None, None, None]), -127, 127).astype(np.int8)
        got = np.asarray(qconv2d(x_q, w_q, (sx * sw).astype(np.float32),
                                 np.zeros(12, np.float32), padding=(1, 1)))
        ref = jax.lax.conv_general_dilated(
            jnp.asarray(xf)[None], jnp.asarray(wf), (1, 1),
            [(1, 1), (1, 1)], dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
        corr = np.corrcoef(got.ravel(), np.asarray(ref).ravel())[0, 1]
        assert corr > 0.99


class TestDWConv:
    @pytest.mark.parametrize("c,hw", [(8, 16), (19, 12), (32, 7)])
    @pytest.mark.parametrize("stride", [1, 2])
    def test_sweep_vs_ref(self, rng, c, hw, stride):
        x = rng.integers(-127, 128, (c, hw, hw)).astype(np.int8)
        w = rng.integers(-127, 128, (c, 3, 3)).astype(np.int8)
        s = rng.uniform(1e-3, 1e-2, c).astype(np.float32)
        b = rng.uniform(-1, 1, c).astype(np.float32)
        got = dwconv(x, w, s, b, stride=stride, activation="relu6",
                     out_scale=0.05)
        exp = dwconv_ref(x, w, s, b, stride=stride, activation="relu6",
                         out_scale=0.05)
        assert got.shape == exp.shape
        assert np.max(np.abs(np.asarray(got, np.int32)
                             - np.asarray(exp, np.int32))) <= 1

    def test_float_out(self, rng):
        x = rng.integers(-127, 128, (8, 10, 10)).astype(np.int8)
        w = rng.integers(-127, 128, (8, 3, 3)).astype(np.int8)
        s = np.ones(8, np.float32)
        b = np.zeros(8, np.float32)
        got = np.asarray(dwconv(x, w, s, b))
        exp = np.asarray(dwconv_ref(x, w, s, b))
        np.testing.assert_allclose(got, exp, rtol=1e-6, atol=1e-6)


class TestDecodeAttn:
    @pytest.mark.parametrize("b,k,g,hd,s,bs", [
        (2, 4, 5, 64, 1024, 256),
        (1, 8, 1, 128, 512, 512),
        (3, 2, 8, 32, 768, 128),
        (2, 1, 16, 64, 640, 128),
    ])
    def test_sweep_vs_ref(self, rng, b, k, g, hd, s, bs):
        q = rng.standard_normal((b, 1, k, g, hd)).astype(np.float32)
        ck = rng.standard_normal((b, s, k, hd)).astype(np.float32)
        cv = rng.standard_normal((b, s, k, hd)).astype(np.float32)
        lens = rng.integers(s // 2, s + 1, b).astype(np.int32)
        got = np.asarray(flash_decode(q, ck, cv, lens, block_s=bs))
        exp = np.asarray(flash_decode_ref(q, ck, cv, lens))
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=2e-5)

    def test_bf16_dtype(self, rng):
        import jax.numpy as jnp
        b, k, g, hd, s = 2, 2, 4, 64, 512
        q = jnp.asarray(rng.standard_normal((b, 1, k, g, hd)), jnp.bfloat16)
        ck = jnp.asarray(rng.standard_normal((b, s, k, hd)), jnp.bfloat16)
        cv = jnp.asarray(rng.standard_normal((b, s, k, hd)), jnp.bfloat16)
        lens = np.full(b, s, np.int32)
        got = np.asarray(flash_decode(q, ck, cv, lens, block_s=128),
                         np.float32)
        exp = np.asarray(flash_decode_ref(q, ck, cv, lens), np.float32)
        np.testing.assert_allclose(got, exp, rtol=3e-2, atol=3e-2)

    def test_length_masking(self, rng):
        """Slots beyond `lengths` must not influence the output."""
        b, k, g, hd, s = 1, 2, 2, 32, 256
        q = rng.standard_normal((b, 1, k, g, hd)).astype(np.float32)
        ck = rng.standard_normal((b, s, k, hd)).astype(np.float32)
        cv = rng.standard_normal((b, s, k, hd)).astype(np.float32)
        lens = np.array([100], np.int32)
        out1 = np.asarray(flash_decode(q, ck, cv, lens, block_s=64))
        ck2, cv2 = ck.copy(), cv.copy()
        ck2[:, 100:] = 99.0
        cv2[:, 100:] = -99.0
        out2 = np.asarray(flash_decode(q, ck2, cv2, lens, block_s=64))
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)
