"""End-to-end system tests: the paper's full pipeline (reinterpret -> split
-> quantize -> execute across simulated MCUs), training convergence, and
restart-from-checkpoint."""
import numpy as np

from repro.core import (SplitExecutor, WorkerParams,
                        calibrate_scales, measured_kc, peak_ram_per_worker,
                        quantize_model, ratings_for, reference_forward,
                        simulate, simulated_k1, single_device_peak,
                        split_model)
from repro.models import mobilenet_v2_smoke


def test_full_paper_pipeline(rng):
    """Offline preprocessing -> deployment -> split inference (Fig. 2), with
    heterogeneous workers and int8 quantization, validated numerically and
    against the memory budget."""
    model = mobilenet_v2_smoke()

    # offline: calibrate + quantize (§V.D)
    calib = [rng.standard_normal((3, 32, 32)).astype(np.float32)
             for _ in range(4)]
    scales = calibrate_scales(
        model, calib,
        lambda m, x: reference_forward(m, x, collect_activations=True)[1])
    qm = quantize_model(model, scales)

    # deployment: rating-based allocation over heterogeneous MCUs (§V)
    workers = [WorkerParams(f_mhz=600), WorkerParams(f_mhz=150),
               WorkerParams(f_mhz=450, d_s_per_kb=0.005)]
    k1 = simulated_k1(model, 600)
    kc = measured_kc(model, 3)
    ratings = ratings_for(workers, k1, kc)
    plan = split_model(model, ratings)

    # memory claim: split peak < single-device peak; every worker bounded
    single = single_device_peak(model)
    peaks = peak_ram_per_worker(plan)
    assert peaks.max() < single

    # numerics: split int8 == single int8 (1 requant ulp)
    x = calib[0]
    ex = SplitExecutor(plan, qm)
    out_split = ex.run(x, mode="int8")
    out_single = SplitExecutor(split_model(model, [1.0]), qm).run(x, mode="int8")
    assert np.max(np.abs(out_split.astype(np.int32)
                         - out_single.astype(np.int32))) <= 1

    # latency model runs end to end
    res = simulate(model, workers, ratings)
    assert res.total_time > 0 and res.comp_time > 0
    assert len(res.layer_total) == len(model.layers)


def test_training_loss_decreases(tmp_path):
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("qwen3-14b-smoke")
    _, _, losses = train_loop(cfg, steps=40, batch=16, seq=32, ckpt_dir=None,
                              lr=3e-3, log_every=100)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


def test_restart_from_checkpoint(tmp_path):
    """Kill-and-resume: a run interrupted at step 6 resumes at 6 and reaches
    the same final state as an uninterrupted run."""
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("qwen3-14b-smoke")
    d1 = str(tmp_path / "a")
    train_loop(cfg, steps=6, batch=4, seq=16, ckpt_dir=d1, ckpt_every=3,
               log_every=100, schedule_steps=10)
    # resume to 10
    _, _, resumed = train_loop(cfg, steps=10, batch=4, seq=16, ckpt_dir=d1,
                               ckpt_every=100, log_every=100)
    # uninterrupted baseline
    d2 = str(tmp_path / "b")
    _, _, full = train_loop(cfg, steps=10, batch=4, seq=16, ckpt_dir=d2,
                            ckpt_every=100, log_every=100)
    np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-4)


def test_grad_compression_still_converges():
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("qwen3-14b-smoke")
    _, _, losses = train_loop(cfg, steps=30, batch=16, seq=32, ckpt_dir=None,
                              lr=3e-3, compress_grads=True, log_every=100)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatched_equals_full_batch():
    """Gradient accumulation must match the single-batch gradient step."""
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import (TrainOptions, init_train_state,
                                     make_train_step)
    cfg = get_config("qwen3-14b-smoke")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=5)
    data = SyntheticLM(cfg.vocab_size, seed=0)
    batch = data.batch(0, 8, 32)

    def run(micro):
        step, _ = make_train_step(cfg, opt_cfg, None,
                                  TrainOptions(microbatches=micro,
                                               donate=False))
        params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
        params, _, m = step(params, opt, batch)
        return float(m["loss"]), params

    l1, p1 = run(1)
    l4, p4 = run(4)
    # microbatch losses average per-microbatch losses — equal for this data
    assert abs(l1 - l4) < 0.05
    leaves1, leaves4 = jax.tree.leaves(p1), jax.tree.leaves(p4)
    deltas = [float(np.max(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32))))
              for a, b in zip(leaves1, leaves4)]
    assert max(deltas) < 0.05
