"""AdamW with decoupled weight decay, global-norm clipping and a linear-warmup
cosine schedule — pure JAX, optimizer states sharded like their parameters
(ZeRO-style: the 'embed' FSDP axis shards moments across the data axis too).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


def fake_quant_grads(grads, bits: int = 8):
    """Lossy int-N gradient compression numerics (per-tensor symmetric scale).

    On hardware this pairs with a compressed cross-pod reducer (see
    parallel/collectives.py); here it reproduces the *numerics* so convergence
    under compression is testable anywhere."""
    qmax = 2.0 ** (bits - 1) - 1

    def q(g):
        gf = g.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / qmax
        return (jnp.round(gf / s).clip(-qmax, qmax) * s).astype(g.dtype)

    return jax.tree.map(q, grads)
