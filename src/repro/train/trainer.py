"""Distributed train step builder: FSDP (data axis) x TP (model axis) with the
paper's routing modes, microbatched gradient accumulation (compute/comm
overlap: each microbatch's backward all-reduces overlap the next microbatch's
compute under XLA's latency-hiding scheduler), optional int8 gradient
compression, and donation of params/opt state.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from ..parallel.sharding import MeshRules, make_rules, param_shardings, use_rules
from .optimizer import OptConfig, adamw_update, fake_quant_grads, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    routing: str = "direct"          # 'direct' | 'coordinator' (paper baseline)
    seq_parallel: bool = True
    microbatches: int = 1
    compress_grads: bool = False
    donate: bool = True


def batch_specs(cfg: ModelConfig, shape, rules: MeshRules) -> dict:
    """ShapeDtypeStructs + shardings for a global batch of the given shape."""
    gb, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        p = cfg.n_patches
        out["tokens"] = rules.sds((gb, s - p), jnp.int32, ("batch", None))
        out["patches"] = rules.sds((gb, p, d), dt, ("batch", None, None))
        out["loss_mask"] = rules.sds((gb, s - p), jnp.float32, ("batch", None))
    elif cfg.family == "audio":
        out["tokens"] = rules.sds((gb, s), jnp.int32, ("batch", None))
        out["frames"] = rules.sds((gb, cfg.n_audio_frames, d), dt,
                                  ("batch", None, None))
    else:
        out["tokens"] = rules.sds((gb, s), jnp.int32, ("batch", None))
    return out


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh,
                    options: TrainOptions = TrainOptions()):
    """Returns (jitted_step, rules).  step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    rules = make_rules(mesh, mode="train", routing=options.routing,
                       seq_parallel=options.seq_parallel)

    def loss_fn(params, batch):
        with use_rules(rules):
            return lm.lm_loss(params, batch, cfg)

    def compute_grads(params, batch):
        if options.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        k = options.microbatches

        def mb(batch_i):
            return jax.tree.map(lambda x: x.reshape(k, x.shape[0] // k,
                                                    *x.shape[1:]), batch_i)

        def step_fn(acc, micro):
            loss_i, g_i = jax.value_and_grad(loss_fn)(params, micro)
            return (acc[0] + loss_i,
                    jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc[1], g_i)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(step_fn, (jnp.zeros(()), zeros), mb(batch))
        inv = 1.0 / k
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if options.compress_grads:
            grads = fake_quant_grads(grads)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    donate = (0, 1) if options.donate else ()
    if mesh is None:   # single-device path (examples / smoke tests)
        return jax.jit(train_step, donate_argnums=donate), rules
    specs = lm.model_spec_tree(cfg)
    p_sh = param_shardings(specs, rules, shapes=lm.abstract_model(cfg))
    opt_sh = {"m": p_sh, "v": p_sh,
              "step": rules.sharding(())}
    step = jax.jit(train_step,
                   in_shardings=(p_sh, opt_sh, None),
                   out_shardings=(p_sh, opt_sh, None),
                   donate_argnums=donate)
    return step, rules


def abstract_train_state(cfg: ModelConfig, rules: MeshRules):
    """ShapeDtypeStructs (with shardings) for params + opt state — the
    allocation-free stand-ins the dry-run lowers against."""
    params = lm.abstract_model(cfg)
    specs = lm.model_spec_tree(cfg)
    p_sh = param_shardings(specs, rules, shapes=params)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params, p_sh)
    opt = {
        "m": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh),
                          params, p_sh),
        "v": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh),
                          params, p_sh),
        "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=rules.sharding(())),
    }
    return params, opt


def init_train_state(cfg: ModelConfig, key, mesh=None, rules=None):
    """Concrete init (used by the real training examples)."""
    params = lm.init_model(cfg, key)
    opt = init_opt_state(params)
    if rules is not None and rules.mesh is not None:
        p_sh = param_shardings(lm.model_spec_tree(cfg), rules, shapes=params)
        params = jax.device_put(params, p_sh)
        opt = {"m": jax.device_put(opt["m"], p_sh),
               "v": jax.device_put(opt["v"], p_sh),
               "step": opt["step"]}
    return params, opt
