from . import optimizer, serve, trainer
