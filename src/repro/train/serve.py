"""Serving steps: prefill (builds the KV / recurrent caches) and decode (one
token against the caches), with TP-sharded params and caches sharded
(batch -> data, kv sequence -> model) so 32k-context x 128-batch caches fit.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from ..models.lm import pattern_stacks
from ..parallel.sharding import MeshRules, make_rules, param_shardings, use_rules


def _attn_cache_specs():
    # the cache shards along the kv *sequence* (32k+ contexts dominate
    # memory); the kv-head dim is replicated here — per-step writes reshard
    # one token, which is negligible.
    return {"k": ("layers", "batch", "kv_seq", None, None),
            "v": ("layers", "batch", "kv_seq", None, None),
            "kv_pos": ("layers", "kv_seq")}


def block_cache_specs(kind: str, cfg: ModelConfig):
    if kind in ("attn", "moe"):
        return _attn_cache_specs()
    if kind == "xattn":
        return {"self": _attn_cache_specs(),
                "cross": {"k": ("layers", "batch", None, None, None),
                          "v": ("layers", "batch", None, None, None)}}
    if kind == "rec":
        return {"h": ("layers", "batch", "rnn"),
                "conv": ("layers", "batch", None, "rnn")}
    if kind == "mlstm":
        return {"C": ("layers", "batch", None, None, "ff"),
                "n": ("layers", "batch", None, None),
                "m": ("layers", "batch", None),
                "conv": ("layers", "batch", None, "ff")}
    if kind == "slstm":
        return {k: ("layers", "batch", None) for k in ("c", "n", "h", "m")}
    raise ValueError(kind)


def cache_spec_tree(cfg: ModelConfig):
    return {"pos": (),
            "stacks": [{f"{i}_{kind}": block_cache_specs(kind, cfg)
                        for i, kind in enumerate(pattern)}
                       for pattern, _ in pattern_stacks(cfg)]}


def cache_shardings(cfg: ModelConfig, rules: MeshRules, batch: int,
                    max_seq: int):
    """Divisibility-fitted shardings for the cache pytree."""
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_seq))
    return jax.tree.map(
        lambda names, s: rules.fit_sharding(tuple(names), tuple(s.shape)),
        cache_spec_tree(cfg), shapes, is_leaf=lambda v: isinstance(v, tuple))


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   rules: MeshRules):
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_seq))
    sh = cache_shardings(cfg, rules, batch, max_seq)
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        shapes, sh)


def abstract_serve_params(cfg: ModelConfig, rules: MeshRules):
    params = lm.abstract_model(cfg)
    p_sh = param_shardings(lm.model_spec_tree(cfg), rules, shapes=params)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params, p_sh), p_sh


def make_decode_step(cfg: ModelConfig, mesh, batch: int, max_seq: int,
                     routing: str = "direct"):
    """(params, cache, tokens(B,1)) -> (logits (B,V), cache); cache donated."""
    rules = make_rules(mesh, mode="serve", routing=routing)

    def decode_step(params, cache, tokens):
        with use_rules(rules):
            return lm.forward(params, {"tokens": tokens}, cfg, mode="decode",
                              cache=cache)

    _, p_sh = abstract_serve_params(cfg, rules)
    c_sh = cache_shardings(cfg, rules, batch, max_seq)
    tok_sh = rules.fit_sharding(("batch", None), (batch, 1))
    lg_sh = rules.fit_sharding(("batch", "vocab"), (batch, cfg.padded_vocab))
    step = jax.jit(decode_step,
                   in_shardings=(p_sh, c_sh, tok_sh),
                   out_shardings=(lg_sh, c_sh),
                   donate_argnums=(1,))
    return step, rules


def make_prefill_step(cfg: ModelConfig, mesh, batch: int, max_seq: int,
                      routing: str = "direct"):
    """(params, cache0, batch) -> (last-token logits, filled cache)."""
    rules = make_rules(mesh, mode="serve", routing=routing)

    def prefill_step(params, cache, batch_in):
        with use_rules(rules):
            return lm.forward(params, batch_in, cfg, mode="prefill",
                              cache=cache)

    _, p_sh = abstract_serve_params(cfg, rules)
    c_sh = cache_shardings(cfg, rules, batch, max_seq)
    lg_sh = rules.fit_sharding(("batch", "vocab"), (batch, cfg.padded_vocab))
    step = jax.jit(prefill_step,
                   in_shardings=(p_sh, c_sh, None),
                   out_shardings=(lg_sh, c_sh),
                   donate_argnums=(1,))
    return step, rules


def serve_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                      rules: MeshRules) -> dict:
    dt = jnp.dtype(cfg.dtype)
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        p = cfg.n_patches
        out["tokens"] = rules.sds((batch, seq - p), jnp.int32, ("batch", None))
        out["patches"] = rules.sds((batch, p, cfg.d_model), dt,
                                   ("batch", None, None))
    elif cfg.family == "audio":
        out["tokens"] = rules.sds((batch, seq), jnp.int32, ("batch", None))
        out["frames"] = rules.sds((batch, cfg.n_audio_frames, cfg.d_model), dt,
                                  ("batch", None, None))
    else:
        out["tokens"] = rules.sds((batch, seq), jnp.int32, ("batch", None))
    return out
