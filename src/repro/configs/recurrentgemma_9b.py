"""recurrentgemma-9b [arXiv:2402.19427 Griffin]: RG-LRU + local attention,
1 attention block per 2 recurrent blocks (pattern rec,rec,attn), MQA kv=1."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, act="swiglu",
    block_pattern=("rec", "rec", "attn"), d_rnn=4096, local_window=2048,
    conv_width=4,
)
