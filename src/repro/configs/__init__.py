"""Config registry: ``get_config(arch_id)`` for every assigned architecture.

Exact configs from the assignment sheet (public literature; see per-file
citations).  ``--arch <id>`` in the launchers resolves through here.
"""
from __future__ import annotations

from .base import (LM_SHAPES, ModelConfig, ShapeConfig, get_shape,
                   shape_applicable, smoke_variant)
from .whisper_base import CONFIG as whisper_base
from .qwen3_14b import CONFIG as qwen3_14b
from .deepseek_coder_33b import CONFIG as deepseek_coder_33b
from .qwen2_5_32b import CONFIG as qwen2_5_32b
from .internlm2_20b import CONFIG as internlm2_20b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .dbrx_132b import CONFIG as dbrx_132b
from .llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .xlstm_1_3b import CONFIG as xlstm_1_3b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        whisper_base, qwen3_14b, deepseek_coder_33b, qwen2_5_32b,
        internlm2_20b, deepseek_moe_16b, dbrx_132b, llava_next_mistral_7b,
        recurrentgemma_9b, xlstm_1_3b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke_variant(get_config(name[: -len("-smoke")]))
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


__all__ = ["ARCHS", "LM_SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "get_shape", "shape_applicable", "smoke_variant"]
