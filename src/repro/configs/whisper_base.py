"""whisper-base [arXiv:2212.04356]: encoder-decoder, conv frontend stubbed
(precomputed frame embeddings per the assignment)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, n_encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, norm="layernorm", act="gelu",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    n_audio_frames=1500,
)
