"""xlstm-1.3b [arXiv:2405.04517]: sLSTM + mLSTM blocks (1 sLSTM per 8),
matrix-memory mLSTM with proj factor 2; no separate FFN (d_ff=0)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=8, proj_factor=2.0,
    # §Perf cell C: chunk 2048 adopted (temp −54%, t_comp −40% vs the
    # chunk-256 baseline recorded in EXPERIMENTS.md)
    mlstm_chunk=2048,
)
