"""Config system: architecture + input-shape configs for every assigned
architecture (plus the paper's own MobileNetV2).

Every config is a frozen dataclass; ``repro.configs.get_config(name)``
resolves by id.  Shape configs define the 4 assigned input-shape cells;
``input_specs(cfg, shape)`` (launch/dryrun.py) turns them into
ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention details
    qk_norm: bool = False           # qwen3-style per-head RMS on q/k
    qkv_bias: bool = False          # qwen2.5-style bias on qkv projections
    rope_theta: float = 10000.0
    local_window: int = 0           # >0: sliding-window attention
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    n_shared_experts: int = 0
    moe_group_size: int = 2048      # GShard dispatch group
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"        # einsum (baseline) | gather (optimized)
    # encoder-decoder (audio family)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500      # stub frontend output length
    # VLM
    n_patches: int = 0              # stub anyres patch embeddings
    # hybrid (recurrentgemma): block pattern within a scanned group
    block_pattern: tuple[str, ...] = ("attn",)   # e.g. ("rec","rec","attn")
    d_rnn: int = 0
    conv_width: int = 4
    # ssm (xlstm)
    slstm_every: int = 0            # one sLSTM per this many blocks (0: none)
    proj_factor: float = 2.0        # mLSTM up-projection factor
    mlstm_chunk: int = 256          # chunkwise-parallel mLSTM chunk length
    # numerics / training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save MXU outputs, skip fwd recompute)
    attn_chunk: int = 1024          # q-chunk for streaming attention (0: full)
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the LM head / embedding shard
        over the model axis (Megatron-style vocab padding; padded logits are
        masked to -inf in the loss).  whisper's 51865 is the only assigned
        vocab that doesn't already divide 16."""
        return (self.vocab_size + 127) // 128 * 128

    @property
    def q_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "ssm":
            # mLSTM block: up-proj 2x, qkv over inner dim, gates, down-proj
            di = int(self.proj_factor * d)
            per_blk = d * di * 2 + 3 * di * di // max(1, 1) + di * d
            return emb + self.n_layers * per_blk
        ff_mult = 3 if self.act == "swiglu" else 2
        per_mlp = ff_mult * d * self.d_ff
        if self.family == "moe":
            per_mlp = ff_mult * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
        n = emb + self.n_layers * (per_attn + per_mlp)
        if self.family == "hybrid":
            rec_frac = sum(1 for b in self.block_pattern if b == "rec") / len(self.block_pattern)
            dr = self.d_rnn or d
            per_rec = 2 * d * dr + dr * d + 2 * dr  # in x2, out, gates(diag-ish)
            n = emb + int(self.n_layers * rec_frac) * (per_rec + per_mlp) + \
                int(self.n_layers * (1 - rec_frac)) * (per_attn + per_mlp)
        if self.family == "audio":
            n += self.n_encoder_layers * (per_attn + per_mlp)
            n += self.n_layers * per_attn  # decoder cross-attention
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        ff_mult = 3 if self.act == "swiglu" else 2
        hd = self.resolved_head_dim
        per_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        act_mlp = ff_mult * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (per_attn + act_mlp)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode


# The four assigned LM shapes (identical across the 10 archs).
LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for ssm/hybrid, skip for
    pure full-attention archs (documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "O(S^2) full attention at S=524288 is infeasible by design"
    return True, ""


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4 if cfg.slstm_every == 0 else 4),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_group_size=16,
        vocab_size=256,
        d_rnn=64 if cfg.d_rnn else 0,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        n_audio_frames=8 if cfg.family == "audio" else cfg.n_audio_frames,
        n_patches=4 if cfg.family == "vlm" else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        attn_chunk=0,
        dtype="float32",
    )
