"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]: mistral-7b
backbone; anyres vision tiling STUBBED as precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, rope_theta=1e6,
    n_patches=576,  # one anyres base tile of 24x24 patches (stub frontend)
)
