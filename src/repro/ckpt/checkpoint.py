"""Fault-tolerant checkpointing: atomic, sharded, async-capable, and
restorable onto a *different* mesh (elastic restart).

Layout: ``<dir>/step_<N>/`` with one ``shard_<p>.npz`` per host process plus
``manifest.json`` (tree structure, global shapes, dtypes, step).  Writes go
to ``step_<N>.tmp`` and are renamed only after every shard + manifest is
fsynced — a crashed writer never corrupts the latest checkpoint, and
``latest_step`` simply ignores ``.tmp`` leftovers.

On this single-process container each array saves in full; the addressable-
shard path is exercised by the multi-device subprocess tests.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _key_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _flatten(tree) -> dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_key_str(p): v for p, v in leaves}


def _unflatten_into(template, flat: dict[str, Any]):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    return jax.tree_util.tree_unflatten(
        treedef, [flat[_key_str(p)] for p, _ in leaves])


def save_checkpoint(ckpt_dir: str, step: int, tree, *, process_index: int = 0,
                    n_processes: int = 1, blocking: bool = True):
    """Atomically persist a pytree of jax/np arrays.  Returns a join()able
    handle when blocking=False (async save off the main thread)."""
    flat = _flatten(tree)

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {}
        meta = {"step": step, "n_processes": n_processes, "entries": {}}
        for key, val in flat.items():
            arr = np.asarray(jax.device_get(val))
            arrays[key] = arr
            meta["entries"][key] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
        shard_path = os.path.join(tmp, f"shard_{process_index}.npz")
        with open(shard_path, "wb") as f:
            np.savez(f, **{k.replace(_SEP, "|"): v for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        if process_index == 0:
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template,
                       shardings=None):
    """Restore into the structure of ``template``; if ``shardings`` (matching
    pytree of NamedSharding) is given, arrays are placed with those shardings
    — this is how a checkpoint written on one mesh restarts on another
    (elastic rescale)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        meta = json.load(f)
    flat: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(final)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(final, name)) as z:
                for k in z.files:
                    flat[k.replace("|", _SEP)] = z[k]
    missing = set(meta["entries"]) - set(flat)
    if missing:
        raise IOError(f"checkpoint step {step} incomplete: missing {sorted(missing)[:5]}")
    flat_t = _flatten(template)
    out_flat = {}
    for key, tmpl in flat_t.items():
        if key not in flat:
            raise KeyError(f"checkpoint missing entry {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"{key}: shape {arr.shape} != template {np.shape(tmpl)}")
        out_flat[key] = arr
    if shardings is not None:
        flat_s = _flatten(shardings)
        out_flat = {k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                    for k, v in out_flat.items()}
    return _unflatten_into(template, out_flat)
