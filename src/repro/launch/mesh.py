"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
host-platform devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").  Multi-pod: 2 pods of
    256 = 512 chips ("pod","data","model"); the pod axis carries only
    data-parallel gradient reduction (DCN-crossing collectives)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use (2,2)/(2,2,2) with forced host devices)."""
    return jax.make_mesh(shape, axes)


def data_axis_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
