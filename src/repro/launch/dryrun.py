import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) cell against the production meshes and record
memory/cost/collective analysis for EXPERIMENTS.md §Dry-run and §Roofline.

The two lines above MUST precede any jax import: jax locks the device count
on first init, and the dry-run needs 512 placeholder host devices.  Smoke
tests and benches never import this module, so they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
Options: --multi-pod (2x16x16 mesh), --routing {direct,coordinator},
         --seq-parallel, --print-hlo
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax.numpy as jnp

from ..configs import ARCHS, LM_SHAPES, get_config, get_shape, shape_applicable
from ..train import serve as serve_lib
from ..train import trainer as trainer_lib
from ..train.optimizer import OptConfig
from . import analysis
from .mesh import make_production_mesh


def input_specs(cfg, shape, rules):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if shape.mode == "train":
        return trainer_lib.batch_specs(cfg, shape, rules)
    return serve_lib.serve_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                       rules)


def lower_cell(cfg, shape, mesh, routing: str = "direct",
               seq_parallel: bool = True, microbatches: int = 1):
    """Build + lower the jitted step for one (arch x shape x mesh) cell.
    Returns (lowered, n_chips)."""
    n_chips = mesh.size
    if shape.mode == "train":
        opts = trainer_lib.TrainOptions(routing=routing,
                                        seq_parallel=seq_parallel,
                                        microbatches=microbatches)
        step, rules = trainer_lib.make_train_step(cfg, OptConfig(), mesh, opts)
        params, opt = trainer_lib.abstract_train_state(cfg, rules)
        batch = input_specs(cfg, shape, rules)
        with mesh:
            lowered = step.lower(params, opt, batch)
        return lowered, n_chips
    if shape.mode == "prefill":
        step, rules = serve_lib.make_prefill_step(
            cfg, mesh, shape.global_batch, shape.seq_len, routing=routing)
        params, _ = serve_lib.abstract_serve_params(cfg, rules)
        cache = serve_lib.abstract_cache(cfg, shape.global_batch,
                                         shape.seq_len, rules)
        batch = input_specs(cfg, shape, rules)
        with mesh:
            lowered = step.lower(params, cache, batch)
        return lowered, n_chips
    # decode: one new token against a seq_len cache
    step, rules = serve_lib.make_decode_step(
        cfg, mesh, shape.global_batch, shape.seq_len, routing=routing)
    params, _ = serve_lib.abstract_serve_params(cfg, rules)
    cache = serve_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                     rules)
    toks = rules.sds((shape.global_batch, 1), jnp.int32, ("batch", None))
    with mesh:
        lowered = step.lower(params, cache, toks)
    return lowered, n_chips


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             routing: str = "direct", seq_parallel: bool = True,
             print_hlo: bool = False, moe_impl: str | None = None,
             overrides: dict | None = None, microbatches: int = 1) -> dict:
    cfg = get_config(arch)
    if moe_impl and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if overrides:
        typed = {k: type(getattr(cfg, k))(v) for k, v in overrides.items()}
        cfg = dataclasses.replace(cfg, **typed)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
            "routing": routing, "seq_parallel": seq_parallel,
            "moe_impl": cfg.moe_impl if cfg.n_experts else None}
    if not ok:
        return {**base, "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered, n_chips = lower_cell(cfg, shape, mesh, routing, seq_parallel,
                                      microbatches=microbatches)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        hlo = compiled.as_text()
        if print_hlo:
            print(hlo[:20000])
        rep = analysis.summarize(compiled, hlo, cfg, shape, mesh_desc, n_chips)
        ma = compiled.memory_analysis()
        print(f"[dryrun] {arch} x {shape_name} @ {mesh_desc} "
              f"({routing}): COMPILED in {t_compile:.1f}s")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB  (per device)")
        print(f"  cost_analysis: flops/dev={rep.flops:.3e} "
              f"bytes/dev={rep.hbm_bytes:.3e}")
        print(f"  collectives/dev: " + ", ".join(
            f"{k}={v/2**20:.1f}MiB" for k, v in sorted(rep.coll_bytes.items())) or "none")
        print(f"  roofline: t_comp={rep.t_compute*1e3:.2f}ms "
              f"t_mem={rep.t_memory*1e3:.2f}ms t_coll={rep.t_collective*1e3:.2f}ms "
              f"-> {rep.bottleneck}-bound, frac={rep.roofline_frac:.3f}")
        return {**base, "status": "ok", "t_lower_s": t_lower,
                "t_compile_s": t_compile, **rep.to_dict(),
                "mem": {"argument": ma.argument_size_in_bytes,
                        "output": ma.output_size_in_bytes,
                        "temp": ma.temp_size_in_bytes,
                        "alias": ma.alias_size_in_bytes}}
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        traceback.print_exc()
        return {**base, "status": "failed", "error": f"{type(e).__name__}: {e}"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in LM_SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--routing", default="direct",
                    choices=["direct", "coordinator"])
    ap.add_argument("--seq-parallel", dest="seq_parallel", action="store_true", default=True)
    ap.add_argument("--no-seq-parallel", dest="seq_parallel", action="store_false")
    ap.add_argument("--moe-impl", default=None, choices=["einsum", "gather"])
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="override a ModelConfig field")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default=None, help="label recorded in the JSONL")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)
    overrides = dict(kv.split("=", 1) for kv in getattr(args, "set"))

    cells = []
    if args.all:
        for a in ARCHS:
            for s in LM_SHAPES:
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        res = run_cell(arch, shape, multi_pod=args.multi_pod,
                       routing=args.routing, seq_parallel=args.seq_parallel,
                       print_hlo=args.print_hlo, moe_impl=args.moe_impl,
                       overrides=overrides, microbatches=args.microbatches)
        if args.tag:
            res["tag"] = args.tag
        if res["status"] == "failed":
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
