"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / peak_FLOPs            (per device)
    memory term     = HLO_bytes / HBM_bw                (per device)
    collective term = collective_bytes / link_bw        (per device)

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a 10-trip scan reports exactly 1/10 of the unrolled FLOPs), and collective
traffic isn't reported at all.  Since every model here scans its layer stack,
we parse the compiled HLO text ourselves, walking the computation graph with
loop trip counts (extracted from each loop-condition constant):

  * FLOPs: dot/convolution instructions — 2 * |result| * |contracted dims|
    (elementwise flops are negligible against the matmuls at these shapes).
  * HBM bytes: per top-level instruction, operand bytes + result bytes —
    the fusion-boundary traffic model XLA itself uses (internal ops of a
    fusion are cache-local).  Structural ops (parameter/tuple/gte/constant/
    bitcast) are free; while/call/fusion recurse instead of self-counting.
  * Collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# TPU v5e hardware constants (assignment sheet)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                        r"([a-z0-9\-]+)\(")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_OPS = {"parameter", "tuple", "get-tuple-element", "constant", "bitcast",
             "after-all", "iota", "partition-id", "replica-id", "domain",
             "opt-barrier", "custom-call"}


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(dt: str, dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class _Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "_Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def _split_computations(hlo: str) -> dict[str, str]:
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        # computation header: `%name (params...) -> type {` — params may
        # contain nested tuple parens, so only anchor on name + trailing `{`.
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
        if m and line.rstrip().endswith("{") and ("->" in line or
                                                  line.startswith("ENTRY")):
            cur_name, cur_lines = m.group(1), []
            continue
        if line.startswith("}") and cur_name is not None:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def _result_shapes(line: str, om) -> list[tuple[str, list[int]]]:
    """Result-type shapes: the _OPCODE_RE match spans `= TYPE opcode(` —
    every shape token inside the span belongs to the result type."""
    return _shape_list(line[om.start():om.end()])


def _symbols(body: str) -> dict[str, list[tuple[str, list[int]]]]:
    """var name -> list of (dtype, dims) from each instruction's result type
    (post-optimization HLO omits operand types at use sites)."""
    sym: dict[str, list[tuple[str, list[int]]]] = {}
    for line in body.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        om = _OPCODE_RE.search(line)
        sym[m.group(1)] = (_result_shapes(line, om) if om
                           else _shape_list(m.group(2).split("(")[0]))
    return sym


def _operand_shapes(line: str, start: int, sym) -> list[tuple[str, list[int]]]:
    close = line.find(")", start)
    seg = line[start:close if close >= 0 else len(line)]
    shapes = _shape_list(seg)            # inline-typed operands (if any)
    if shapes:
        return shapes
    out = []
    for name in re.findall(r"%([\w\.\-]+)", seg):
        out.extend(sym.get(name, []))
    return out


_COLL_LINK_FACTOR = {
    # per-device link traffic model (ring algorithms):
    #   all-gather: receive (result - shard) ~ result
    #   reduce-scatter: send ~ operand
    #   all-reduce: RS + AG ~ 2x operand
    #   all-to-all / collective-permute: ~ operand
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-reduce": ("operand", 2.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
}


def _instruction_cost(line: str, sym) -> _Totals:
    t = _Totals()
    m = _OPCODE_RE.search(line)
    if not m:
        return t
    op = m.group(1)
    if op in _SKIP_OPS or op in ("while", "call", "fusion", "conditional"):
        return t
    line_nometa = line.split(", metadata=")[0]
    result = _result_shapes(line_nometa, m)
    operands = _operand_shapes(line_nometa, m.end(), sym)
    res_bytes = sum(_nbytes(dt, d) for dt, d in result)
    opd_bytes = sum(_nbytes(dt, d) for dt, d in operands)
    base = op
    for suf in ("-start", "-done"):
        if base.endswith(suf):
            base = base[: -len(suf)]
    if base in _COLL_OPS:
        if not op.endswith("-done"):
            kind, mult = _COLL_LINK_FACTOR[base]
            t.coll[base] = t.coll.get(base, 0.0) + mult * (
                res_bytes if kind == "result" else (opd_bytes or res_bytes))
        return t
    if op == "dynamic-update-slice":
        # in-place on the donated buffer: traffic = the updated slice (r+w),
        # not the whole operand (decode-cache writes would otherwise count
        # the full 32k cache per token).
        upd = operands[1] if len(operands) > 1 else result
        t.bytes += 2 * _nbytes(*upd) if upd else 0
        return t
    if op == "dynamic-slice":
        # reading one scan step's slice out of a stacked buffer moves the
        # slice, not the buffer
        t.bytes += 2 * res_bytes
        return t
    t.bytes += res_bytes + opd_bytes
    if op == "dot":
        cd = _CDIMS_RE.search(line)
        contracted = 1
        if cd and operands:
            lhs_dims = operands[0][1]
            for i in (int(x) for x in cd.group(1).split(",") if x):
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
        n_out = 1
        for dt, dims in result[:1]:
            for d in dims:
                n_out *= d
        t.flops += 2.0 * n_out * contracted
    elif op == "convolution":
        n_out = 1
        for dt, dims in result[:1]:
            for d in dims:
                n_out *= d
        if len(operands) >= 2:
            rhs = operands[1][1]
            k = 1
            for d in rhs[:-1]:
                k *= d
            t.flops += 2.0 * n_out * k / max(rhs[-1], 1)
        else:
            t.flops += 2.0 * n_out
    return t


def analyze_hlo(hlo: str) -> _Totals:
    """Loop-aware totals over the ENTRY computation."""
    comps = _split_computations(hlo)
    memo: dict[str, _Totals] = {}

    def walk(name: str) -> _Totals:
        if name in memo:
            return memo[name]
        memo[name] = _Totals()      # cycle guard
        acc = _Totals()
        body = comps.get(name, "")
        sym = _symbols(body)
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                cond, wbody = wm.group(1), wm.group(2)
                acc.add(walk(wbody), _trip_count(comps.get(cond, "")))
                continue
            om = _OPCODE_RE.search(line)
            if om and om.group(1) in ("call", "conditional"):
                cm = _TOAPPLY_RE.search(line)
                if cm:
                    acc.add(walk(cm.group(1)))
                continue
            if om and om.group(1) == "fusion":
                # fusion internals are cache-local: count boundary traffic.
                # In-place update fusions (a dynamic-update-slice writing one
                # scan step's slice into a stacked buffer) alias the big
                # operand: count only the small operands (the written slice),
                # not the full buffer — otherwise a 4096-step sLSTM scan
                # "moves" its residual buffer 4096 times (TiBs of phantom
                # traffic).
                line_nometa = line.split(", metadata=")[0]
                result = _result_shapes(line_nometa, om)
                operands = _operand_shapes(line_nometa, om.end(), sym)
                cm = _TOAPPLY_RE.search(line)
                callee = comps.get(cm.group(1), "") if cm else ""
                res_set = {(dt, tuple(d)) for dt, d in result}
                aliased = [op for op in operands
                           if (op[0], tuple(op[1])) in res_set]
                res_bytes = sum(_nbytes(dt, d) for dt, d in result)
                if aliased and "dynamic-update-slice" in callee:
                    small = sum(_nbytes(dt, d) for dt, d in operands
                                if (dt, tuple(d)) not in res_set)
                    acc.bytes += 2 * small    # read inputs + write the slice
                elif "dynamic-slice(" in callee:
                    # slicing fusion: drop operands much larger than the
                    # result (the stacked buffer being indexed)
                    acc.bytes += res_bytes + sum(
                        _nbytes(dt, d) for dt, d in operands
                        if _nbytes(dt, d) <= 4 * max(res_bytes, 1))
                else:
                    acc.bytes += res_bytes
                    acc.bytes += sum(_nbytes(dt, d) for dt, d in operands)
                continue
            acc.add(_instruction_cost(line, sym))
        memo[name] = acc
        return acc

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        acc = _Totals()
        sym: dict = {}
        for line in hlo.splitlines():
            acc.add(_instruction_cost(line, sym))
        return acc
    return walk(entry)


def collective_bytes(hlo: str) -> dict[str, float]:
    return analyze_hlo(hlo).coll


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO flops (loop-aware)
    hbm_bytes: float             # per-device bytes (fusion-boundary model)
    coll_bytes: dict[str, float]
    model_flops: float           # analytic 6*N*D (or decode equivalent) /chip
    peak_mem_bytes: float        # per-device (args+temp) from memory_analysis
    xla_flops: float = 0.0       # raw cost_analysis (loop-unaware, reference)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / max(self.flops, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Useful-compute time over the achievable step time max(terms) —
        the MFU the dry-run's schedule would deliver at best."""
        t_star = self.model_flops / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / max(t_bound, 1e-30)

    def to_dict(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "peak_mem_bytes": self.peak_mem_bytes,
            "xla_flops": self.xla_flops, "xla_bytes": self.xla_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS per global step: 6*N*D train (fwd+bwd), 2*N*D
    forward-only; D = processed tokens; MoE uses active params."""
    n = cfg.n_active_params()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # decode: one token per sequence


def summarize(compiled, hlo_text: str, cfg, shape, mesh_desc: str,
              n_chips: int) -> RooflineReport:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    peak = (getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0))
    tot = analyze_hlo(hlo_text)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_desc,
        flops=tot.flops,
        hbm_bytes=tot.bytes,
        coll_bytes=tot.coll,
        model_flops=model_flops_for(cfg, shape) / n_chips,
        peak_mem_bytes=float(peak),
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )
