"""Training launcher: real steps on the local device(s) with checkpointing,
restart, and the full substrate (data prefetch, AdamW, optional grad
compression).  For cluster dry-runs use launch/dryrun.py; this driver is what
the e2e examples invoke.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b-smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..configs import get_config
from ..data.pipeline import Prefetcher, SyntheticLM
from ..train.optimizer import OptConfig
from ..train.trainer import TrainOptions, init_train_state, make_train_step


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str | None,
               ckpt_every: int = 50, mesh=None, lr: float = 3e-4,
               compress_grads: bool = False, microbatches: int = 1,
               seed: int = 0, log_every: int = 10,
               schedule_steps: int | None = None):
    horizon = schedule_steps or steps
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(horizon // 20, 5),
                        total_steps=horizon)
    options = TrainOptions(compress_grads=compress_grads,
                           microbatches=microbatches,
                           seq_parallel=mesh is not None)
    step_fn, rules = make_train_step(cfg, opt_cfg, mesh, options)
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(seed),
                                         mesh=mesh, rules=rules)
    start = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            template = {"params": params, "opt": opt_state}
            restored = restore_checkpoint(ckpt_dir, last, template)
            params, opt_state = restored["params"], restored["opt"]
            start = last
            print(f"[train] restored step {last} from {ckpt_dir}")

    data = SyntheticLM(cfg.vocab_size, seed=seed)

    def make_batch(i):
        b = data.batch(start + i, batch, seq)
        if cfg.family == "audio":
            rng = np.random.default_rng(i)
            b["frames"] = rng.standard_normal(
                (batch, cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            rng = np.random.default_rng(i)
            b["patches"] = rng.standard_normal(
                (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        return b

    pf = Prefetcher(make_batch)
    losses = []
    pending_save = None
    try:
        t0 = time.time()
        for i in range(start, steps):
            batch_i = next(pf)
            params, opt_state, metrics = step_fn(params, opt_state, batch_i)
            loss = float(metrics["loss"])
            losses.append(loss)
            if (i + 1) % log_every == 0 or i == start:
                dt = (time.time() - t0) / max(i - start + 1, 1)
                print(f"[train] step {i+1}/{steps} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} ({dt*1e3:.0f} ms/step)")
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = save_checkpoint(
                    ckpt_dir, i + 1, {"params": params, "opt": opt_state},
                    blocking=False)
        if pending_save is not None:
            pending_save.join()
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, {"params": params, "opt": opt_state})
    finally:
        pf.close()
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    _, _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, lr=args.lr,
        compress_grads=args.compress_grads, microbatches=args.microbatches)
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
