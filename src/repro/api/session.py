"""`Session`: a compiled serving handle over a plan (the facade's third noun).

Wraps :class:`~repro.core.executor.CompiledSplitExecutor` with the serving
conveniences every driver was hand-rolling: per-(mode, batch-bucket)
compiled-function reuse (jit specializes per batch shape, so requests are
padded to a small set of bucket sizes and every bucket compiles exactly
once), a ``submit()``/``flush()`` micro-batching queue plus bulk
``submit_many()``, ``warmup()`` and rolling latency/throughput stats.

Padding is numerically free: the plan is vmapped over the sample axis, so a
padded slot cannot influence real samples — ``submit_many`` output is
bit-identical to ``run_batch`` over the same inputs (tested in int8).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.executor import CompiledSplitExecutor, reference_forward
from ..core.quantize import QuantizedModel, calibrate_scales, quantize_model
from ..core.splitting import SplitPlan
from .plan import Plan

PRECISIONS = ("int8", "float")
_DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class SessionStats:
    """Rolling serving statistics (engine dispatch time only)."""

    requests: int                   # real requests served
    batches: int                    # engine dispatches issued
    padded: int                     # zero-padded slots executed
    wall_s: float                   # total dispatch wall time
    throughput_rps: float           # requests / wall_s
    mean_latency_s: float           # wall_s / batches (per-dispatch latency)
    per_bucket: dict[int, int]      # bucket size -> dispatch count
    # deployment context from the plan (defaults when serving a bare
    # core SplitPlan): the transport policy the plan was costed under and
    # the seconds/inference the planner predicts pipelining saves vs serial
    transport: str = "serial"
    predicted_overlap_saved_s: float = 0.0


class Ticket:
    """Handle for one queued request; ``result()`` flushes if needed."""

    __slots__ = ("_session", "_value", "_done")

    def __init__(self, session: "Session"):
        self._session = session
        self._value = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            self._session.flush()
        return self._value

    def _fulfill(self, value: np.ndarray) -> None:
        self._value = value
        self._done = True


class Session:
    """Micro-batched serving over a compiled split plan.

    Accepts a :class:`repro.api.Plan` (the normal path — carries cluster and
    search context) or a bare core :class:`SplitPlan` (benchmarks/tests).

    ``precision="int8"`` builds the W8A8 deployment: a supplied ``qmodel``
    wins, else ``calibration`` activations (or ``calibration_samples`` seeded
    random inputs) calibrate the scales.  ``precision="float"`` serves fp32.
    ``buckets`` are the allowed padded batch sizes (ascending; the largest is
    the micro-batch chunk size); each (precision, bucket) pair compiles once.
    """

    def __init__(self, plan: Plan | SplitPlan, *, precision: str = "int8",
                 qmodel: QuantizedModel | None = None,
                 calibration: list[np.ndarray] | None = None,
                 calibration_samples: int = 4, seed: int = 0,
                 use_pallas: bool | None = None, interpret: bool | None = None,
                 max_batch: int = 32, buckets: tuple[int, ...] | None = None):
        if precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {precision!r} (want one of {PRECISIONS})")
        self.plan = plan if isinstance(plan, Plan) else None
        self.split = plan.split if isinstance(plan, Plan) else plan
        self.transport = self.plan.transport if self.plan is not None else "serial"
        if not isinstance(self.split, SplitPlan):
            raise TypeError("plan must be a repro.api.Plan or a core SplitPlan")
        self.model = self.split.model
        self.precision = precision
        self._mode = "int8" if precision == "int8" else "float"
        if precision == "int8" and qmodel is None:
            qmodel = self._calibrate(calibration, calibration_samples, seed)
        self.qmodel = qmodel if precision == "int8" else None
        self.engine = CompiledSplitExecutor(self.split, self.qmodel,
                                            use_pallas=use_pallas,
                                            interpret=interpret)
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        bks = tuple(sorted({int(b) for b in (buckets or _DEFAULT_BUCKETS)
                            if 1 <= int(b) <= max_batch} | {1, int(max_batch)}))
        self.buckets = bks
        self.max_batch = int(max_batch)
        self._pending: list[tuple[np.ndarray, Ticket]] = []
        self._requests = 0
        self._batches = 0
        self._padded = 0
        self._wall_s = 0.0
        self._per_bucket: dict[int, int] = {}

    # -- calibration ---------------------------------------------------------
    def _calibrate(self, calibration, n_samples: int, seed: int) -> QuantizedModel:
        if calibration is None:
            rng = np.random.default_rng(seed)
            calibration = [rng.standard_normal(self.model.input_shape)
                           .astype(np.float32) for _ in range(n_samples)]
        scales = calibrate_scales(
            self.model, calibration,
            lambda m, x: reference_forward(m, x, collect_activations=True)[1])
        return quantize_model(self.model, scales)

    # -- compilation ---------------------------------------------------------
    def warmup(self, buckets: tuple[int, ...] | None = None) -> None:
        """Compile ahead of serving: one trace per bucket size."""
        shape = tuple(self.model.input_shape)
        for b in (buckets or self.buckets):
            self.engine.run_batch(np.zeros((int(b), *shape), np.float32),
                                  mode=self._mode)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    # -- serving -------------------------------------------------------------
    def _check_input(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float32)
        if x.shape != tuple(self.model.input_shape):
            raise ValueError(f"request shape {x.shape} != model input "
                             f"{tuple(self.model.input_shape)}")
        return x

    def _dispatch(self, xs: np.ndarray) -> np.ndarray:
        """One padded engine dispatch for n <= max bucket requests."""
        n = len(xs)
        b = self._bucket(n)
        if b > n:
            pad = np.zeros((b - n, *xs.shape[1:]), np.float32)
            batch = np.concatenate([xs, pad])
        else:
            batch = xs
        t0 = time.perf_counter()
        out = self.engine.run_batch(batch, mode=self._mode)
        dt = time.perf_counter() - t0
        self._requests += n
        self._batches += 1
        self._padded += b - n
        self._wall_s += dt
        self._per_bucket[b] = self._per_bucket.get(b, 0) + 1
        return out[:n]

    def submit_many(self, xs) -> np.ndarray:
        """Serve a bulk of requests, micro-batched into padded buckets.
        Returns outputs aligned with ``xs`` — bit-identical to
        ``run_batch(xs)`` over the same compiled plan."""
        xs = np.asarray(xs, dtype=np.float32)
        if xs.ndim != 4 or xs.shape[1:] != tuple(self.model.input_shape):
            raise ValueError(f"batch shape {xs.shape} != (n, "
                             f"{', '.join(map(str, self.model.input_shape))})")
        if len(xs) == 0:
            dtype = np.int8 if self._mode == "int8" else np.float32
            return np.zeros((0, *self.model.out_shape), dtype)
        return np.concatenate([self._dispatch(xs[i:i + self.max_batch])
                               for i in range(0, len(xs), self.max_batch)])

    def run(self, x) -> np.ndarray:
        """Serve one request now (bucket-1 compiled path)."""
        return self.submit_many(self._check_input(x)[None])[0]

    def submit(self, x) -> Ticket:
        """Queue one request for the next :meth:`flush`; returns a
        :class:`Ticket` whose ``result()`` flushes on demand."""
        t = Ticket(self)
        self._pending.append((self._check_input(x), t))
        return t

    def flush(self) -> int:
        """Serve every queued request in bucket-padded micro-batches;
        returns the number of requests served."""
        if not self._pending:
            return 0
        pending, self._pending = self._pending, []
        ys = self.submit_many(np.stack([x for x, _ in pending]))
        for (_, ticket), y in zip(pending, ys):
            ticket._fulfill(np.asarray(y))
        return len(pending)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    # -- distributed serving -------------------------------------------------
    def distributed(self, **kwargs) -> "object":
        """A :class:`repro.runtime.Coordinator` over this session's plan and
        quantization (same qmodel, so distributed output is bit-identical to
        this session).  Caller drives its async lifecycle::

            async with sess.distributed(spawn="process") as coord:
                y = await coord.infer(x)
        """
        from ..runtime.coordinator import Coordinator
        return Coordinator(self.split, self.qmodel,
                           precision=self.precision, **kwargs)

    # -- observability -------------------------------------------------------
    def stats(self) -> SessionStats:
        return SessionStats(
            requests=self._requests, batches=self._batches,
            padded=self._padded, wall_s=self._wall_s,
            throughput_rps=(self._requests / self._wall_s
                            if self._wall_s > 0 else 0.0),
            mean_latency_s=(self._wall_s / self._batches
                            if self._batches else 0.0),
            per_bucket=dict(self._per_bucket),
            transport=self.transport,
            predicted_overlap_saved_s=(self.plan.overlap_saved_s
                                       if self.plan is not None else 0.0))
