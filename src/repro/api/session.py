"""`Session`: a compiled serving handle over a plan (the facade's third noun).

Wraps :class:`~repro.core.executor.CompiledSplitExecutor` with the serving
conveniences every driver was hand-rolling: per-(mode, batch-bucket)
compiled-function reuse (jit specializes per batch shape, so requests are
padded to a small set of bucket sizes and every bucket compiles exactly
once), a ``submit()``/``flush()`` micro-batching queue plus bulk
``submit_many()``, ``warmup()`` and rolling latency/throughput stats.

Padding is numerically free: the plan is vmapped over the sample axis, so a
padded slot cannot influence real samples — ``submit_many`` output is
bit-identical to ``run_batch`` over the same inputs (tested in int8).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import numpy as np

from ..core.executor import CompiledSplitExecutor, reference_forward
from ..core.quantize import QuantizedModel, calibrate_scales, quantize_model
from ..core.splitting import SplitPlan
from .plan import Plan

PRECISIONS = ("int8", "float")
_DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)
_ROLLING_WINDOW = 512


class RollingLatency:
    """Rolling latency window with percentile queries, optionally keyed
    (bucket size, tenant name, ...).  The single percentile implementation:
    ``SessionStats`` and :class:`repro.serve.QosMonitor` both report through
    it, so serving-layer QoS numbers and session stats cannot drift apart.

    Percentiles use the linear-interpolation definition of
    ``np.percentile`` over the retained window; empty windows return NaN.
    Thread-safe: the serving layer's scheduler thread records while client
    threads query.
    """

    __slots__ = ("window", "_all", "_by_key", "_lock")

    def __init__(self, window: int = _ROLLING_WINDOW):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = int(window)
        self._all: collections.deque[float] = collections.deque(maxlen=window)
        self._by_key: dict[object, collections.deque[float]] = {}
        self._lock = threading.Lock()

    def record(self, value: float, key: object = None) -> None:
        self.record_many((value,), key=key)

    def record_many(self, values, key: object = None) -> None:
        """Record a batch of observations under one lock acquisition (the
        serving hot path records per dispatch, not per request)."""
        with self._lock:
            self._all.extend(float(v) for v in values)
            if key is not None:
                dq = self._by_key.get(key)
                if dq is None:
                    dq = self._by_key[key] = collections.deque(
                        maxlen=self.window)
                dq.extend(float(v) for v in values)

    def __len__(self) -> int:
        return len(self._all)

    def keys(self) -> tuple:
        with self._lock:
            return tuple(self._by_key)

    def values(self, key: object = None) -> tuple[float, ...]:
        """The retained window, oldest first."""
        with self._lock:
            return tuple(self._all if key is None
                         else self._by_key.get(key, ()))

    def percentile(self, q: float, key: object = None) -> float:
        vals = self.values(key)
        if not vals:
            return float("nan")
        return float(np.percentile(np.asarray(vals, dtype=np.float64), q))

    def snapshot(self, qs: tuple[float, ...] = (50, 99)) -> dict[float, float]:
        return {q: self.percentile(q) for q in qs}


@dataclasses.dataclass(frozen=True)
class SessionStats:
    """Rolling serving statistics (engine dispatch time only)."""

    requests: int                   # real requests served
    batches: int                    # engine dispatches issued
    padded: int                     # zero-padded slots executed
    wall_s: float                   # total dispatch wall time
    throughput_rps: float           # requests / wall_s
    mean_latency_s: float           # wall_s / batches (per-dispatch latency)
    per_bucket: dict[int, int]      # bucket size -> dispatch count
    # deployment context from the plan (defaults when serving a bare
    # core SplitPlan): the transport policy the plan was costed under and
    # the seconds/inference the planner predicts pipelining saves vs serial
    transport: str = "serial"
    predicted_overlap_saved_s: float = 0.0
    # rolling dispatch-latency percentiles over the last _ROLLING_WINDOW
    # dispatches (NaN before the first): overall and per bucket size —
    # the service-time estimates admission control predicts queueing with
    latency_p50_s: float = float("nan")
    latency_p99_s: float = float("nan")
    per_bucket_p50_s: dict[int, float] = dataclasses.field(default_factory=dict)
    per_bucket_p99_s: dict[int, float] = dataclasses.field(default_factory=dict)
    # plan-search telemetry carried over from the Plan this session serves
    # (core.search.SearchStats; zeros/NaN when serving a bare SplitPlan or
    # a plan deserialized from a pre-search-stats payload)
    search_candidates_evaluated: int = 0
    search_cache_hit_rate: float = float("nan")
    search_wall_s: float = float("nan")


class Ticket:
    """Handle for one queued request.

    Two fulfillment regimes share this class: a plain :class:`Session`
    ticket (``result()`` synchronously flushes the owning session on demand)
    and a detached ticket (``session=None``, fulfilled by another thread —
    the :class:`repro.serve.Server` scheduler — so ``result()`` waits on an
    event).  ``result(timeout=...)`` raises :class:`TimeoutError` if the
    ticket is still unfulfilled after ``timeout`` seconds, and re-raises the
    dispatch exception if the batch this request rode in failed: a raising
    dispatch rejects its tickets instead of stranding them.
    """

    __slots__ = ("_session", "_value", "_error", "_event", "_t_done")

    def __init__(self, session: "Session | None" = None):
        self._session = session
        self._value = None
        self._error: BaseException | None = None
        self._event = threading.Event()
        self._t_done = float("nan")

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def completed_at(self) -> float:
        """``time.perf_counter()`` stamp of fulfillment/rejection (NaN while
        pending) — lets a load generator compute end-to-end latency without
        racing to observe the event itself."""
        return self._t_done

    def result(self, timeout: float | None = None) -> np.ndarray:
        if not self._event.is_set() and self._session is not None:
            self._session.flush()   # synchronous path: serve the queue now
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket unfulfilled after {timeout} s")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self) -> BaseException | None:
        """The dispatch error that rejected this ticket (None if none/undone)."""
        return self._error

    def _fulfill(self, value: np.ndarray) -> None:
        self._value = value
        self._t_done = time.perf_counter()
        self._event.set()

    def _reject(self, error: BaseException) -> None:
        self._error = error
        self._t_done = time.perf_counter()
        self._event.set()


class InflightDispatch:
    """One asynchronously dispatched padded micro-batch.

    Returned by :meth:`Session.dispatch_async`: the engine call has been
    *enqueued* (jax dispatch is asynchronous) but not forced, so the caller
    can overlap host-side work — forming the next micro-batch, fulfilling
    the previous one's tickets — with this batch's device compute.  This is
    the in-flight bucket slot continuous batching admits into.

    ``wait()`` forces the result, records the dispatch into the owning
    session's stats (wall time measured enqueue -> ready, so under pipelining
    it includes device queueing — the effective per-batch service time), and
    returns the unpadded outputs.
    """

    __slots__ = ("_session", "_n", "_bucket", "_out", "_t0", "_result")

    def __init__(self, session: "Session", n: int, bucket: int, out, t0: float):
        self._session = session
        self._n = n
        self._bucket = bucket
        self._out = out
        self._t0 = t0
        self._result: np.ndarray | None = None

    @property
    def n_requests(self) -> int:
        return self._n

    @property
    def bucket(self) -> int:
        return self._bucket

    def wait(self) -> np.ndarray:
        if self._result is None:
            out = np.asarray(self._out)     # blocks until the device is done
            dt = time.perf_counter() - self._t0
            self._out = None
            self._session._record_dispatch(self._n, self._bucket, dt)
            self._result = out[:self._n]
        return self._result


class Session:
    """Micro-batched serving over a compiled split plan.

    Accepts a :class:`repro.api.Plan` (the normal path — carries cluster and
    search context) or a bare core :class:`SplitPlan` (benchmarks/tests).

    ``precision="int8"`` builds the W8A8 deployment: a supplied ``qmodel``
    wins, else ``calibration`` activations (or ``calibration_samples`` seeded
    random inputs) calibrate the scales.  ``precision="float"`` serves fp32.
    ``buckets`` are the allowed padded batch sizes (ascending; the largest is
    the micro-batch chunk size); each (precision, bucket) pair compiles once.
    """

    def __init__(self, plan: Plan | SplitPlan, *, precision: str = "int8",
                 qmodel: QuantizedModel | None = None,
                 calibration: list[np.ndarray] | None = None,
                 calibration_samples: int = 4, seed: int = 0,
                 use_pallas: bool | None = None, interpret: bool | None = None,
                 max_batch: int = 32, buckets: tuple[int, ...] | None = None):
        if precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {precision!r} (want one of {PRECISIONS})")
        self.plan = plan if isinstance(plan, Plan) else None
        self.split = plan.split if isinstance(plan, Plan) else plan
        self.transport = self.plan.transport if self.plan is not None else "serial"
        if not isinstance(self.split, SplitPlan):
            raise TypeError("plan must be a repro.api.Plan or a core SplitPlan")
        self.model = self.split.model
        self.precision = precision
        self._mode = "int8" if precision == "int8" else "float"
        if precision == "int8" and qmodel is None:
            qmodel = self._calibrate(calibration, calibration_samples, seed)
        self.qmodel = qmodel if precision == "int8" else None
        self._use_pallas = use_pallas
        self._interpret = interpret
        self.engine = CompiledSplitExecutor(self.split, self.qmodel,
                                            use_pallas=use_pallas,
                                            interpret=interpret)
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        bks = tuple(sorted({int(b) for b in (buckets or _DEFAULT_BUCKETS)
                            if 1 <= int(b) <= max_batch} | {1, int(max_batch)}))
        self.buckets = bks
        self.max_batch = int(max_batch)
        self._pending: list[tuple[np.ndarray, Ticket]] = []
        self._requests = 0
        self._batches = 0
        self._padded = 0
        self._wall_s = 0.0
        self._per_bucket: dict[int, int] = {}
        self._rolling = RollingLatency()

    # -- calibration ---------------------------------------------------------
    def _calibrate(self, calibration, n_samples: int, seed: int) -> QuantizedModel:
        if calibration is None:
            rng = np.random.default_rng(seed)
            calibration = [rng.standard_normal(self.model.input_shape)
                           .astype(np.float32) for _ in range(n_samples)]
        scales = calibrate_scales(
            self.model, calibration,
            lambda m, x: reference_forward(m, x, collect_activations=True)[1])
        return quantize_model(self.model, scales)

    # -- compilation ---------------------------------------------------------
    def warmup(self, buckets: tuple[int, ...] | None = None) -> None:
        """Compile ahead of serving: one trace per bucket size."""
        shape = tuple(self.model.input_shape)
        for b in (buckets or self.buckets):
            self.engine.run_batch(np.zeros((int(b), *shape), np.float32),
                                  mode=self._mode)

    def bucket_for(self, n: int) -> int:
        """The padded batch size ``n`` requests dispatch at (the smallest
        configured bucket >= n, capped at the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    # -- serving -------------------------------------------------------------
    def check_input(self, x: np.ndarray) -> np.ndarray:
        """Validate/convert one request sample (public: the serving layer
        validates at admission time, before a request enters any queue)."""
        x = np.asarray(x, dtype=np.float32)
        if x.shape != tuple(self.model.input_shape):
            raise ValueError(f"request shape {x.shape} != model input "
                             f"{tuple(self.model.input_shape)}")
        return x

    def _record_dispatch(self, n: int, bucket: int, wall_s: float) -> None:
        self._requests += n
        self._batches += 1
        self._padded += bucket - n
        self._wall_s += wall_s
        self._per_bucket[bucket] = self._per_bucket.get(bucket, 0) + 1
        self._rolling.record(wall_s, key=bucket)

    def dispatch_async(self, xs: np.ndarray) -> InflightDispatch:
        """Enqueue one bucket-padded engine dispatch for ``n <= max_batch``
        requests WITHOUT forcing the result.

        The continuous-batching seam: jax dispatch is asynchronous, so a
        scheduler can keep a bucket in flight on the device while it forms
        the next micro-batch from whatever has queued — no flush barrier.
        Stats are recorded when the returned handle's ``wait()`` forces.
        """
        n = len(xs)
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"dispatch of {n} requests (want 1..{self.max_batch})")
        b = self.bucket_for(n)
        if b > n:
            pad = np.zeros((b - n, *xs.shape[1:]), np.float32)
            batch = np.concatenate([xs, pad])
        else:
            batch = xs
        t0 = time.perf_counter()
        out = self.engine.run_batch_async(batch, mode=self._mode)
        return InflightDispatch(self, n, b, out, t0)

    def _dispatch(self, xs: np.ndarray) -> np.ndarray:
        """One padded engine dispatch for n <= max bucket requests."""
        return self.dispatch_async(xs).wait()

    def submit_many(self, xs) -> np.ndarray:
        """Serve a bulk of requests, micro-batched into padded buckets.
        Returns outputs aligned with ``xs`` — bit-identical to
        ``run_batch(xs)`` over the same compiled plan."""
        xs = np.asarray(xs, dtype=np.float32)
        if xs.ndim != 4 or xs.shape[1:] != tuple(self.model.input_shape):
            raise ValueError(f"batch shape {xs.shape} != (n, "
                             f"{', '.join(map(str, self.model.input_shape))})")
        if len(xs) == 0:
            dtype = np.int8 if self._mode == "int8" else np.float32
            return np.zeros((0, *self.model.out_shape), dtype)
        return np.concatenate([self._dispatch(xs[i:i + self.max_batch])
                               for i in range(0, len(xs), self.max_batch)])

    def run(self, x) -> np.ndarray:
        """Serve one request now (bucket-1 compiled path)."""
        return self.submit_many(self.check_input(x)[None])[0]

    def submit(self, x) -> Ticket:
        """Queue one request for the next :meth:`flush`; returns a
        :class:`Ticket` whose ``result()`` flushes on demand."""
        t = Ticket(self)
        self._pending.append((self.check_input(x), t))
        return t

    def flush(self) -> int:
        """Serve every queued request in bucket-padded micro-batches;
        returns the number of requests served.

        A raising dispatch REJECTS every ticket of this flush with the
        exception (their ``result()`` re-raises it) and then re-raises, so a
        poisoned batch can never leave callers blocked on tickets that will
        never be fulfilled.  Requests submitted *during* the dispatch (e.g.
        from a fulfillment callback) land in the next flush untouched.
        """
        if not self._pending:
            return 0
        pending, self._pending = self._pending, []
        try:
            ys = self.submit_many(np.stack([x for x, _ in pending]))
        except Exception as e:
            for _, ticket in pending:
                ticket._reject(e)
            raise
        for (_, ticket), y in zip(pending, ys):
            ticket._fulfill(np.asarray(y))
        return len(pending)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    # -- elastic replan ------------------------------------------------------
    def replan(self, plan: Plan | SplitPlan) -> None:
        """Swap this session onto a new plan for the *same* model, keeping
        the quantization, stats, buckets, and queued tickets.

        The new engine reuses the cross-instance executable cache
        (``CompiledSplitExecutor._fn_cache`` is keyed on plan geometry
        fingerprints), so replanning back onto previously-seen geometry is
        a warm start — no re-trace.  Pending tickets simply flush under
        the new plan; output stays bit-exact because the qmodel is shared.
        """
        new_plan = plan if isinstance(plan, Plan) else None
        new_split = plan.split if isinstance(plan, Plan) else plan
        if not isinstance(new_split, SplitPlan):
            raise TypeError("plan must be a repro.api.Plan or a core "
                            "SplitPlan")
        if new_split.model is not self.model and (
                tuple(new_split.model.input_shape)
                != tuple(self.model.input_shape)):
            raise ValueError("replan target was built for a different model")
        self.plan = new_plan
        self.split = new_split
        self.transport = (new_plan.transport if new_plan is not None
                          else "serial")
        self.model = new_split.model
        self.engine = CompiledSplitExecutor(new_split, self.qmodel,
                                            use_pallas=self._use_pallas,
                                            interpret=self._interpret)

    # -- distributed serving -------------------------------------------------
    def distributed(self, *, elastic: bool = False, workers=None,
                    objective=None, **kwargs) -> "object":
        """A :class:`repro.runtime.Coordinator` over this session's plan and
        quantization (same qmodel, so distributed output is bit-identical to
        this session).  Caller drives its async lifecycle::

            async with sess.distributed(spawn="process") as coord:
                y = await coord.infer(x)

        With ``elastic=True`` (requires ``workers``: the
        :class:`~repro.core.allocation.WorkerParams` of the physical
        fleet), returns an :class:`~repro.runtime.ElasticCoordinator` that
        re-plans and serves through worker failure, demotion, and rejoin::

            async with sess.distributed(elastic=True, workers=ws) as ec:
                y = await ec.infer(x)      # survives churn
        """
        if elastic:
            if workers is None:
                raise ValueError("distributed(elastic=True) needs workers=")
            from ..runtime.elastic import ElasticCluster
            from ..runtime.replan import ElasticCoordinator
            cluster = ElasticCluster(self.model, list(workers),
                                     objective=objective)
            return ElasticCoordinator(cluster, self.qmodel,
                                      precision=self.precision, **kwargs)
        from ..runtime.coordinator import Coordinator
        return Coordinator(self.split, self.qmodel,
                           precision=self.precision, **kwargs)

    # -- observability -------------------------------------------------------
    def dispatch_latency_s(self, bucket: int | None = None,
                           q: float = 50.0) -> float:
        """Rolling dispatch-latency percentile (NaN before any dispatch):
        the per-batch service-time estimate the serving layer's admission
        control predicts queueing delay with."""
        return self._rolling.percentile(q, key=bucket)

    def stats(self) -> SessionStats:
        search_stats = (getattr(self.plan, "search_stats", None)
                        if self.plan is not None else None)
        return SessionStats(
            requests=self._requests, batches=self._batches,
            padded=self._padded, wall_s=self._wall_s,
            throughput_rps=(self._requests / self._wall_s
                            if self._wall_s > 0 else 0.0),
            mean_latency_s=(self._wall_s / self._batches
                            if self._batches else 0.0),
            per_bucket=dict(self._per_bucket),
            transport=self.transport,
            predicted_overlap_saved_s=(self.plan.overlap_saved_s
                                       if self.plan is not None else 0.0),
            latency_p50_s=self._rolling.percentile(50),
            latency_p99_s=self._rolling.percentile(99),
            per_bucket_p50_s={b: self._rolling.percentile(50, key=b)
                              for b in self._rolling.keys()},
            per_bucket_p99_s={b: self._rolling.percentile(99, key=b)
                              for b in self._rolling.keys()},
            search_candidates_evaluated=(search_stats or {}).get(
                "candidates_evaluated", 0),
            search_cache_hit_rate=(search_stats or {}).get(
                "cache_hit_rate", float("nan")),
            search_wall_s=(search_stats or {}).get(
                "search_wall_s", float("nan")))
