"""`Planner`: the resource-aware coordinator's planning step as one call.

The paper's coordinator measures the cluster, rates every worker (Eq. 5),
splits the model proportionally (Eq. 6/7) and deploys.  ``Planner`` turns
that pipeline — plus the partitioning-mode and fusion axes this repo grew
beyond the paper — into a declarative search::

    plan = Planner(model, cluster).plan(
        Objective(minimize="latency", ram_cap_bytes=512 * 1024))

The search space is mode ∈ {neuron, kernel, spatial} (plus the opt-in
"mixed" axis: a per-fused-block mode assignment found by dynamic
programming over block boundaries, :mod:`repro.core.mixed`) × fusion
granularity (fused blocks vs per-layer bands, spatial only) × worker
subsets × transport ∈ {serial, pipelined} (the Eq. 5-6
coordinator-serialized model vs the event-driven per-link async transport).

Worker subsets come from the capability-rating prefix ladder (top-k by
Eq. 5 rating, k = 1..max_workers) — and, when ``Objective(beam_width=...)``
is set, from a beam search that also explores *non-prefix* subsets (drop a
high-rated worker on a slow link): each round keeps the ``beam_width``
best-scoring subsets and grows them by one worker, under an optional
``search_budget`` cap on candidate evaluations.  ``beam_width=None`` (the
default) reproduces the ladder exactly, and because the ladder prefixes are
always evaluated too, the beam plan's score is never worse than the
ladder's (CI-gated).

Every candidate is costed through the shared memoized cost-model layer
(:mod:`repro.core.search`): split geometry, the
:func:`repro.core.simulator.simulate` decomposition and the per-worker peak
(:func:`repro.core.memory.peak_ram_per_worker`) are computed once per
(worker-parameters, mode, fusion, caps) fingerprint and reused across
candidates, across objectives, and — when callers share a
:class:`~repro.core.search.CostCache`, as ``ElasticCluster`` does — across
successive replans.  Neuron/kernel candidates run the Eq. 7
storage-overflow redistribution first, exactly as the paper's allocation
does.  The best feasible candidate becomes a :class:`repro.api.Plan`
(carrying the search telemetry: candidates evaluated, cache hit rate,
search wall); if nothing fits, :class:`InfeasibleError` reports the
*binding* constraint (the one the closest candidate missed by the smallest
margin) instead of returning a silently bad plan.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from ..core.allocation import ratings_for
from ..core.reinterpret import ReinterpretedModel
from ..core.search import (CostCache, SearchStats, config_fingerprint,
                           evaluate_candidate)
from ..core.simulator import (TRANSPORTS, SimConfig, measured_kc,
                              simulated_k1)
from ..core.splitting import MODES
from .cluster import Cluster
from .plan import Plan

# the planner's mode axis: the three uniform modes plus "mixed" — a
# per-fused-block assignment searched by dynamic programming over block
# boundaries (core.mixed).  Objective defaults to the uniform modes; opt in
# with Objective(modes=SEARCH_MODES).
SEARCH_MODES = MODES + ("mixed",)


class InfeasibleError(RuntimeError):
    """No candidate satisfied the objective's constraints.

    ``binding_constraint`` names the constraint the *closest* candidate
    violated (``"ram_cap"`` / ``"flash_cap"``); ``details`` carries that
    candidate's numbers (mode, workers, requirement vs cap, overshoot).
    For the ``"mixed"`` axis, ``details["mixed"]`` additionally carries the
    DP's best cap-ignoring assignment and which block's cap bound it.
    """

    def __init__(self, message: str, binding_constraint: str, details: dict):
        super().__init__(message)
        self.binding_constraint = binding_constraint
        self.details = details


@dataclasses.dataclass(frozen=True)
class Objective:
    """What the planner optimizes and what it must respect.

    ``minimize``: ``"latency"`` (simulated end-to-end seconds),
    ``"comm_bytes"`` (bytes moved per inference) or ``"peak_ram"`` (max
    per-worker peak).  ``ram_cap_bytes``/``flash_cap_bytes`` tighten every
    worker's own budget (``None`` keeps the per-worker values from the
    cluster).  ``max_workers`` caps the subset size; ``modes`` restricts the
    partitioning axes searched — the three uniform modes by default; add
    ``"mixed"`` (or pass :data:`SEARCH_MODES`) to also search per-block mode
    assignments via the DP in :mod:`repro.core.mixed`; ``transports``
    restricts the transport policies searched (the tuple order doubles as
    the tie-break preference, so the default prefers serial when pipelining
    buys nothing).

    Search-shape knobs: ``beam_width`` enables beam search over non-prefix
    worker subsets on top of the rating ladder (``None`` = ladder only,
    today's search exactly); ``search_budget`` caps the number of *full*
    cost-model evaluations (cache misses) the search may spend — the ladder
    always completes, and cached candidates are free, so a warm
    :class:`~repro.core.search.CostCache` buys the same budget deeper
    exploration;
    ``mixed_subsets`` lets the mixing DP search up to that many rating-
    prefix worker subsets *per block* in addition to the full set (``None``
    = fixed worker set, the original DP).
    """

    minimize: str = "latency"
    ram_cap_bytes: int | None = None
    flash_cap_bytes: int | None = None
    max_workers: int | None = None
    modes: tuple[str, ...] = MODES
    transports: tuple[str, ...] = TRANSPORTS
    beam_width: int | None = None
    search_budget: int | None = None
    mixed_subsets: int | None = None

    def __post_init__(self) -> None:
        if self.minimize not in ("latency", "comm_bytes", "peak_ram"):
            raise ValueError(
                f"unknown minimize={self.minimize!r} "
                "(want 'latency', 'comm_bytes' or 'peak_ram')")
        if not isinstance(self.modes, tuple):
            object.__setattr__(self, "modes", tuple(self.modes))
        if not self.modes:
            raise ValueError("objective needs at least one mode")
        for m in self.modes:
            if m not in SEARCH_MODES:
                raise ValueError(
                    f"unknown mode {m!r} (want one of {SEARCH_MODES})")
        if not isinstance(self.transports, tuple):
            object.__setattr__(self, "transports", tuple(self.transports))
        if not self.transports:
            raise ValueError("objective needs at least one transport")
        for t in self.transports:
            if t not in TRANSPORTS:
                raise ValueError(
                    f"unknown transport {t!r} (want one of {TRANSPORTS})")
        if self.max_workers is not None and self.max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        for name in ("ram_cap_bytes", "flash_cap_bytes"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0")
        for name in ("beam_width", "search_budget", "mixed_subsets"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be >= 1")

    def score(self, latency_s: float, comm_bytes: int,
              max_peak_ram: int) -> float:
        if self.minimize == "latency":
            return float(latency_s)
        if self.minimize == "comm_bytes":
            return float(comm_bytes)
        return float(max_peak_ram)

    def to_dict(self) -> dict:
        return {"minimize": self.minimize,
                "ram_cap_bytes": self.ram_cap_bytes,
                "flash_cap_bytes": self.flash_cap_bytes,
                "max_workers": self.max_workers,
                "modes": list(self.modes),
                "transports": list(self.transports),
                "beam_width": self.beam_width,
                "search_budget": self.search_budget,
                "mixed_subsets": self.mixed_subsets}

    @classmethod
    def from_dict(cls, data: dict) -> "Objective":
        # plans serialized before the transport axis carry no "transports"
        # key: they were searched under the serial model only; the search-
        # shape knobs default to the ladder when absent
        return cls(minimize=data.get("minimize", "latency"),
                   ram_cap_bytes=data.get("ram_cap_bytes"),
                   flash_cap_bytes=data.get("flash_cap_bytes"),
                   max_workers=data.get("max_workers"),
                   modes=tuple(data.get("modes", MODES)),
                   transports=tuple(data.get("transports", ("serial",))),
                   beam_width=data.get("beam_width"),
                   search_budget=data.get("search_budget"),
                   mixed_subsets=data.get("mixed_subsets"))


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One scored point of the search space (kept on the Plan for reporting
    and for the 'prefers the best feasible candidate' property tests)."""

    mode: str
    fusion: str
    worker_indices: tuple[int, ...]
    feasible: bool
    reason: str | None = None            # why infeasible (None when feasible)
    # "*" on infeasible candidates: RAM/flash feasibility is
    # transport-independent, so one entry covers every transport searched
    transport: str = "serial"
    latency_s: float = float("nan")
    comp_s: float = float("nan")
    comm_s: float = float("nan")
    comm_bytes: int = 0
    max_peak_ram: int = 0
    max_weight_bytes: int = 0
    overlap_saved_s: float = 0.0
    score: float = float("nan")
    # mode == "mixed" only: the per-fused-block mode vector the DP chose
    assignment: tuple[str, ...] | None = None
    # mode == "mixed" with subset search: per-block worker subsets (indices
    # into worker_indices' subset, None entries = all)
    block_workers: tuple | None = None
    # mode == "mixed" infeasible only: binding block / best-assignment info
    detail: dict | None = None

    _NAN_FIELDS = ("latency_s", "comp_s", "comm_s", "score")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["worker_indices"] = list(self.worker_indices)
        d["assignment"] = (list(self.assignment)
                           if self.assignment is not None else None)
        d["block_workers"] = (
            [list(s) if s is not None else None for s in self.block_workers]
            if self.block_workers is not None else None)
        # infeasible candidates carry NaN sentinels; map them to null so the
        # payload stays strict RFC-8259 JSON (json.dumps would emit `NaN`)
        for name in self._NAN_FIELDS:
            if math.isnan(d[name]):
                d[name] = None
        return d

    @classmethod
    def from_dict(cls, data: dict) -> "PlanCandidate":
        data = dict(data)
        data["worker_indices"] = tuple(int(i) for i in data["worker_indices"])
        if data.get("assignment") is not None:
            data["assignment"] = tuple(data["assignment"])
        if data.get("block_workers") is not None:
            data["block_workers"] = tuple(
                tuple(int(w) for w in s) if s is not None else None
                for s in data["block_workers"])
        for name in cls._NAN_FIELDS:
            if data.get(name) is None:
                data[name] = float("nan")
        return cls(**data)


@dataclasses.dataclass(frozen=True)
class _Scored:
    """A feasible candidate plus the heavy artifacts plan() needs."""

    cand: PlanCandidate
    ratings: np.ndarray
    split: object                        # core SplitPlan
    peak: np.ndarray
    weights: np.ndarray


class Planner:
    """Searches split/placement space for a model over a cluster.

    ``sim_cfg`` tunes the analytic timing model (defaults to the calibrated
    :class:`~repro.core.simulator.SimConfig`).  K1 is simulated at the
    cluster's fastest clock (the paper's reference measurement); Kc is
    re-derived per subset size, since the communication coefficient depends
    on how many workers share each layer.

    ``cache`` is the memo for the shared cost-model layer
    (:mod:`repro.core.search`); the default is a fresh private
    :class:`~repro.core.search.CostCache`.  Pass a shared instance to warm-
    start successive searches — ``ElasticCluster`` keeps one across replans
    so losing a worker re-derives only the geometry the old plan didn't
    already cost.
    """

    def __init__(self, model: ReinterpretedModel, cluster: Cluster,
                 sim_cfg: SimConfig | None = None, *,
                 cache: CostCache | None = None):
        self.model = model
        self.cluster = cluster if isinstance(cluster, Cluster) else Cluster(tuple(cluster))
        self.sim_cfg = sim_cfg or SimConfig()
        self.cache = cache if cache is not None else CostCache()
        self._k1 = simulated_k1(model, self.cluster.max_f_mhz, self.sim_cfg)
        self._kc: dict[int, float] = {}
        self.last_stats: SearchStats | None = None

    def _kc_for(self, n: int) -> float:
        if n not in self._kc:
            key = ("kc", (id(self.model), len(self.model.layers)), n,
                   config_fingerprint(self.sim_cfg))
            self._kc[n] = self.cache.get_or(
                key, lambda: measured_kc(self.model, n, self.sim_cfg))
        return self._kc[n]

    def _worker_order(self) -> np.ndarray:
        """Workers ranked by capability rating (desc, index tie-break) — the
        subset ladder: the top-k prefix is the k-worker candidate."""
        r = ratings_for(list(self.cluster.workers), self._k1,
                        self._kc_for(self.cluster.n_workers))
        return np.lexsort((np.arange(len(r)), -r))

    # -- the search ----------------------------------------------------------
    def _evaluate(self, objective: Objective
                  ) -> tuple[list[_Scored | PlanCandidate], SearchStats]:
        """Score every candidate the search shape reaches: the rating-prefix
        ladder always, plus beam-discovered subsets when
        ``objective.beam_width`` is set.  Returns ``_Scored`` for feasible
        candidates, bare ``PlanCandidate`` otherwise, with the search
        telemetry."""
        t0 = time.perf_counter()
        stats = SearchStats(beam_width=objective.beam_width)
        order = [int(i) for i in self._worker_order()]
        n_max = self.cluster.n_workers
        if objective.max_workers is not None:
            n_max = min(n_max, objective.max_workers)
        results: list[_Scored | PlanCandidate] = []
        best_by_subset: dict[tuple[int, ...], float] = {}
        evaluated: set[tuple[int, ...]] = set()

        def eval_subset(idx: tuple[int, ...]) -> None:
            evaluated.add(idx)
            scored = self._score_subset(objective, idx, stats)
            results.extend(scored)
            best = math.inf
            for r in scored:
                if isinstance(r, _Scored):
                    best = min(best, r.cand.score)
            best_by_subset[idx] = best

        # the ladder: top-k rating prefixes, k = 1..n_max — always complete
        # (beam_width=None reproduces this search exactly, and the beam
        # plan below can therefore never score worse than the ladder plan)
        for k in range(1, n_max + 1):
            eval_subset(tuple(sorted(order[:k])))

        if objective.beam_width is not None and n_max > 1:
            self._beam(objective, order, n_max, stats, eval_subset,
                       best_by_subset, evaluated)

        stats.subsets_explored = len(evaluated)
        stats.search_wall_s = time.perf_counter() - t0
        self.last_stats = stats
        return results, stats

    def _beam(self, objective, order, n_max, stats, eval_subset,
              best_by_subset, evaluated) -> None:
        """Beam search over worker subsets: keep the ``beam_width`` best
        subsets of each size, grow each by one worker, re-score.  Ladder
        prefixes participate for free (already evaluated — cache hits cost
        nothing), so the beam explores *around* the ladder rather than
        instead of it.  ``search_budget`` caps the *cache misses* (full
        cost-model runs) the beam phase may spend, spread pro-rata across
        subset sizes so large subsets — where heterogeneous clusters
        actually win — get their share instead of the budget burning out on
        exhaustive small-size growth.  Cached subsets are free, so a warm
        cache widens what the same budget reaches."""
        width = objective.beam_width
        budget = objective.search_budget
        beam_start = stats.cache_misses

        def spent() -> int:
            return stats.cache_misses - beam_start

        frontier: list[tuple[int, ...]] = [(w,) for w in order]
        for size in range(1, n_max + 1):
            # the ladder prefix of this size rides in the frontier for free
            # (already evaluated): expansions branch off the prefixes too,
            # so "prefix k plus a non-prefix worker" — the drop-a-high-
            # rated-worker-on-a-slow-link shape — is one round away instead
            # of `size` rounds of bottom-up growth
            prefix = tuple(sorted(order[:size]))
            if prefix not in frontier:
                frontier.append(prefix)
            size_share = (None if budget is None else
                          spent() + max(0, (budget - spent())
                                        // (n_max - size + 1)))
            scored: list[tuple[float, tuple[int, ...]]] = []
            for sub in frontier:
                if sub not in evaluated:
                    if size_share is not None and spent() >= size_share:
                        continue   # over this size's share; free subsets
                    eval_subset(sub)   # may still score below
                scored.append((best_by_subset.get(sub, math.inf), sub))
            if size == n_max or not scored:
                return
            scored.sort(key=lambda t: (t[0], t[1]))
            seen_next: set[tuple[int, ...]] = set()
            frontier = []
            for _, sub in scored[:width]:
                for w in order:
                    if w in sub:
                        continue
                    ns = tuple(sorted(sub + (w,)))
                    if ns not in seen_next:
                        seen_next.add(ns)
                        frontier.append(ns)

    def _score_subset(self, objective: Objective, idx: tuple[int, ...],
                      stats: SearchStats) -> list[_Scored | PlanCandidate]:
        """Score every (mode, fusion) point of one worker subset through the
        memoized cost-model layer, translating the cached evaluation into
        objective-scored candidates (the cache entry is objective-agnostic:
        both transports' metrics are always present, and uniform-mode
        entries are independent of ``minimize``)."""
        workers = [self.cluster[i] for i in idx]
        k = len(idx)
        base_ratings = ratings_for(workers, self._k1, self._kc_for(k))
        ram_caps = np.array(
            [min(w.ram_bytes, objective.ram_cap_bytes or w.ram_bytes)
             for w in workers], dtype=np.float64)
        flash_caps = np.array(
            [min(w.flash_bytes, objective.flash_cap_bytes or w.flash_bytes)
             for w in workers], dtype=np.float64)
        model_bytes = float(self.model.total_weight_bytes(1))
        out: list[_Scored | PlanCandidate] = []
        for mode in objective.modes:
            for fusion in (("block", "layer") if mode == "spatial"
                           else ("block",)):
                ev = evaluate_candidate(
                    self.model, workers, base_ratings, mode, fusion,
                    ram_caps=ram_caps, flash_caps=flash_caps,
                    model_bytes=model_bytes, cfg=self.sim_cfg,
                    minimize=objective.minimize,
                    mixed_subsets=objective.mixed_subsets,
                    mixed_transport_dp=("pipelined" in objective.transports),
                    cache=self.cache, stats=stats)
                if not ev.feasible:
                    out.append(PlanCandidate(
                        mode=mode, fusion=fusion, worker_indices=idx,
                        feasible=False, transport="*", reason=ev.reason,
                        assignment=ev.assignment,
                        max_peak_ram=ev.max_peak_ram,
                        max_weight_bytes=ev.max_weight_bytes,
                        detail=ev.detail))
                    continue
                for var in ev.variants:
                    for transport in objective.transports:
                        latency_s, comp_s, comm_s, saved_s = \
                            var.metrics[transport]
                        cand = PlanCandidate(
                            mode=mode, fusion=fusion, worker_indices=idx,
                            feasible=True, transport=transport,
                            assignment=var.assignment,
                            block_workers=var.block_workers,
                            latency_s=latency_s, comp_s=comp_s,
                            comm_s=comm_s, comm_bytes=var.total_bytes,
                            max_peak_ram=int(var.peak.max()),
                            max_weight_bytes=int(var.weights.max()),
                            overlap_saved_s=saved_s,
                            score=objective.score(latency_s, var.total_bytes,
                                                  int(var.peak.max())))
                        out.append(_Scored(
                            cand=cand, ratings=var.ratings, split=var.split,
                            peak=var.peak, weights=var.weights))
        return out

    def candidates(self, objective: Objective | None = None) -> list[PlanCandidate]:
        """The full scored candidate table (feasible and infeasible) the
        search considers — what :meth:`plan` picks its winner from."""
        objective = objective or Objective()
        results, _ = self._evaluate(objective)
        return [r.cand if isinstance(r, _Scored) else r for r in results]

    def plan(self, objective: Objective | None = None) -> Plan:
        """Search and return the best feasible :class:`Plan`; raise
        :class:`InfeasibleError` naming the binding constraint if none fits."""
        objective = objective or Objective()
        results, stats = self._evaluate(objective)
        feasible = [r for r in results if isinstance(r, _Scored)]
        if not feasible:
            raise self._infeasible(objective, results)
        # deterministic winner: best score, then fewer workers, then the
        # objective's mode order, then fused before per-layer, then the
        # objective's transport order (serial first by default, so the async
        # transport only wins when it actually lowers the score)
        mode_rank = {m: i for i, m in enumerate(objective.modes)}
        transport_rank = {t: i for i, t in enumerate(objective.transports)}
        best = min(feasible, key=lambda s: (
            s.cand.score, len(s.cand.worker_indices),
            mode_rank[s.cand.mode], s.cand.fusion,
            transport_rank[s.cand.transport]))
        c = best.cand
        return Plan(
            model=self.model, cluster=self.cluster, objective=objective,
            mode=c.mode, fusion=c.fusion, worker_indices=c.worker_indices,
            ratings=best.ratings, split=best.split,
            latency_s=c.latency_s, comp_s=c.comp_s, comm_s=c.comm_s,
            comm_bytes=c.comm_bytes, peak_ram=best.peak,
            weight_bytes=best.weights, score=c.score,
            transport=c.transport, overlap_saved_s=c.overlap_saved_s,
            assignment=c.assignment, block_workers=c.block_workers,
            search_stats=stats.to_dict(),
            candidates=tuple(r.cand if isinstance(r, _Scored) else r
                             for r in results))

    def _infeasible(self, objective: Objective, results) -> InfeasibleError:
        """Build the error naming the constraint the closest candidate missed
        by the smallest relative margin (the binding constraint)."""
        best_cand, best_kind, best_margin = None, "ram_cap", float("inf")
        for r in results:
            cand = r.cand if isinstance(r, _Scored) else r
            if cand.feasible or cand.reason is None:
                continue
            if cand.reason.startswith("split_error"):
                kind = "split_error"
            else:
                kind = ("ram_cap" if cand.reason.startswith("ram_cap")
                        else "flash_cap")
            if kind == "ram_cap" and objective.ram_cap_bytes:
                margin = cand.max_peak_ram / objective.ram_cap_bytes
            elif kind == "flash_cap" and objective.flash_cap_bytes:
                margin = (cand.max_weight_bytes / objective.flash_cap_bytes
                          if cand.max_weight_bytes else float("inf"))
            else:
                margin = float("inf")
            if margin < best_margin:
                best_cand, best_kind, best_margin = cand, kind, margin
        if best_cand is None:
            # no candidate produced numbers (e.g. total flash < model bytes)
            cands = [r.cand if isinstance(r, _Scored) else r for r in results]
            best_cand = cands[0]
            reason = best_cand.reason or ""
            if reason.startswith("flash_cap"):
                best_kind = "flash_cap"
            elif reason.startswith("split_error"):
                best_kind = "split_error"
            else:
                best_kind = "ram_cap"
        details = {"mode": best_cand.mode, "fusion": best_cand.fusion,
                   "worker_indices": list(best_cand.worker_indices),
                   "reason": best_cand.reason,
                   "max_peak_ram": best_cand.max_peak_ram,
                   "max_weight_bytes": best_cand.max_weight_bytes,
                   "ram_cap_bytes": objective.ram_cap_bytes,
                   "flash_cap_bytes": objective.flash_cap_bytes}
        if best_cand.mode == "mixed":
            # the DP's binding-block report: which block's cap bound the
            # search, and the best cap-ignoring assignment it would have
            # chosen — real numbers instead of uniform-mode proxies
            details["assignment"] = (list(best_cand.assignment)
                                     if best_cand.assignment else None)
            if best_cand.detail is not None:
                details["mixed"] = dict(best_cand.detail)
        return InfeasibleError(
            f"no feasible split for the objective; binding constraint "
            f"{best_kind} — closest candidate {best_cand.mode} over "
            f"{len(best_cand.worker_indices)} workers failed with: "
            f"{best_cand.reason}",
            binding_constraint=best_kind, details=details)
