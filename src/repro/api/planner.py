"""`Planner`: the resource-aware coordinator's planning step as one call.

The paper's coordinator measures the cluster, rates every worker (Eq. 5),
splits the model proportionally (Eq. 6/7) and deploys.  ``Planner`` turns
that pipeline — plus the partitioning-mode and fusion axes this repo grew
beyond the paper — into a declarative search::

    plan = Planner(model, cluster).plan(
        Objective(minimize="latency", ram_cap_bytes=512 * 1024))

The search space is mode ∈ {neuron, kernel, spatial} (plus the opt-in
"mixed" axis: a per-fused-block mode assignment found by dynamic
programming over block boundaries, :mod:`repro.core.mixed`) × fusion
granularity (fused blocks vs per-layer bands, spatial only) × worker
subsets (top-k by capability rating, k = 1..max_workers) × transport ∈
{serial, pipelined} (the Eq. 5-6 coordinator-serialized model vs the
event-driven per-link async transport).  Every candidate is costed with the
existing analytic models (:func:`repro.core.simulator.simulate` for
latency/communication, :func:`repro.core.memory.peak_ram_per_worker` for the
per-worker peak) and checked against the RAM/flash budgets; neuron/kernel
candidates run the Eq. 7 storage-overflow redistribution first, exactly as
the paper's allocation does.  The best feasible candidate becomes a
:class:`repro.api.Plan`; if nothing fits, :class:`InfeasibleError` reports
the *binding* constraint (the one the closest candidate missed by the
smallest margin) instead of returning a silently bad plan.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.allocation import ratings_for, redistribute_overflow
from ..core.memory import peak_ram_per_worker
from ..core.mixed import search_mixed_assignment
from ..core.reinterpret import ReinterpretedModel
from ..core.simulator import (TRANSPORTS, SimConfig, measured_kc, simulate,
                              simulated_k1)
from ..core.splitting import MODES
from .cluster import Cluster
from .plan import Plan, build_split_plan

# the planner's mode axis: the three uniform modes plus "mixed" — a
# per-fused-block assignment searched by dynamic programming over block
# boundaries (core.mixed).  Objective defaults to the uniform modes; opt in
# with Objective(modes=SEARCH_MODES).
SEARCH_MODES = MODES + ("mixed",)


class InfeasibleError(RuntimeError):
    """No candidate satisfied the objective's constraints.

    ``binding_constraint`` names the constraint the *closest* candidate
    violated (``"ram_cap"`` / ``"flash_cap"``); ``details`` carries that
    candidate's numbers (mode, workers, requirement vs cap, overshoot).
    """

    def __init__(self, message: str, binding_constraint: str, details: dict):
        super().__init__(message)
        self.binding_constraint = binding_constraint
        self.details = details


@dataclasses.dataclass(frozen=True)
class Objective:
    """What the planner optimizes and what it must respect.

    ``minimize``: ``"latency"`` (simulated end-to-end seconds),
    ``"comm_bytes"`` (bytes moved per inference) or ``"peak_ram"`` (max
    per-worker peak).  ``ram_cap_bytes``/``flash_cap_bytes`` tighten every
    worker's own budget (``None`` keeps the per-worker values from the
    cluster).  ``max_workers`` caps the subset size; ``modes`` restricts the
    partitioning axes searched — the three uniform modes by default; add
    ``"mixed"`` (or pass :data:`SEARCH_MODES`) to also search per-block mode
    assignments via the DP in :mod:`repro.core.mixed`; ``transports``
    restricts the transport policies searched (the tuple order doubles as
    the tie-break preference, so the default prefers serial when pipelining
    buys nothing).
    """

    minimize: str = "latency"
    ram_cap_bytes: int | None = None
    flash_cap_bytes: int | None = None
    max_workers: int | None = None
    modes: tuple[str, ...] = MODES
    transports: tuple[str, ...] = TRANSPORTS

    def __post_init__(self) -> None:
        if self.minimize not in ("latency", "comm_bytes", "peak_ram"):
            raise ValueError(
                f"unknown minimize={self.minimize!r} "
                "(want 'latency', 'comm_bytes' or 'peak_ram')")
        if not isinstance(self.modes, tuple):
            object.__setattr__(self, "modes", tuple(self.modes))
        if not self.modes:
            raise ValueError("objective needs at least one mode")
        for m in self.modes:
            if m not in SEARCH_MODES:
                raise ValueError(
                    f"unknown mode {m!r} (want one of {SEARCH_MODES})")
        if not isinstance(self.transports, tuple):
            object.__setattr__(self, "transports", tuple(self.transports))
        if not self.transports:
            raise ValueError("objective needs at least one transport")
        for t in self.transports:
            if t not in TRANSPORTS:
                raise ValueError(
                    f"unknown transport {t!r} (want one of {TRANSPORTS})")
        if self.max_workers is not None and self.max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        for name in ("ram_cap_bytes", "flash_cap_bytes"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0")

    def score(self, latency_s: float, comm_bytes: int,
              max_peak_ram: int) -> float:
        if self.minimize == "latency":
            return float(latency_s)
        if self.minimize == "comm_bytes":
            return float(comm_bytes)
        return float(max_peak_ram)

    def to_dict(self) -> dict:
        return {"minimize": self.minimize,
                "ram_cap_bytes": self.ram_cap_bytes,
                "flash_cap_bytes": self.flash_cap_bytes,
                "max_workers": self.max_workers,
                "modes": list(self.modes),
                "transports": list(self.transports)}

    @classmethod
    def from_dict(cls, data: dict) -> "Objective":
        # plans serialized before the transport axis carry no "transports"
        # key: they were searched under the serial model only
        return cls(minimize=data.get("minimize", "latency"),
                   ram_cap_bytes=data.get("ram_cap_bytes"),
                   flash_cap_bytes=data.get("flash_cap_bytes"),
                   max_workers=data.get("max_workers"),
                   modes=tuple(data.get("modes", MODES)),
                   transports=tuple(data.get("transports", ("serial",))))


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One scored point of the search space (kept on the Plan for reporting
    and for the 'prefers the best feasible candidate' property tests)."""

    mode: str
    fusion: str
    worker_indices: tuple[int, ...]
    feasible: bool
    reason: str | None = None            # why infeasible (None when feasible)
    # "*" on infeasible candidates: RAM/flash feasibility is
    # transport-independent, so one entry covers every transport searched
    transport: str = "serial"
    latency_s: float = float("nan")
    comp_s: float = float("nan")
    comm_s: float = float("nan")
    comm_bytes: int = 0
    max_peak_ram: int = 0
    max_weight_bytes: int = 0
    overlap_saved_s: float = 0.0
    score: float = float("nan")
    # mode == "mixed" only: the per-fused-block mode vector the DP chose
    assignment: tuple[str, ...] | None = None

    _NAN_FIELDS = ("latency_s", "comp_s", "comm_s", "score")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["worker_indices"] = list(self.worker_indices)
        d["assignment"] = (list(self.assignment)
                           if self.assignment is not None else None)
        # infeasible candidates carry NaN sentinels; map them to null so the
        # payload stays strict RFC-8259 JSON (json.dumps would emit `NaN`)
        for name in self._NAN_FIELDS:
            if math.isnan(d[name]):
                d[name] = None
        return d

    @classmethod
    def from_dict(cls, data: dict) -> "PlanCandidate":
        data = dict(data)
        data["worker_indices"] = tuple(int(i) for i in data["worker_indices"])
        if data.get("assignment") is not None:
            data["assignment"] = tuple(data["assignment"])
        for name in cls._NAN_FIELDS:
            if data.get(name) is None:
                data[name] = float("nan")
        return cls(**data)


@dataclasses.dataclass(frozen=True)
class _Scored:
    """A feasible candidate plus the heavy artifacts plan() needs."""

    cand: PlanCandidate
    ratings: np.ndarray
    split: object                        # core SplitPlan
    peak: np.ndarray
    weights: np.ndarray


class Planner:
    """Searches split/placement space for a model over a cluster.

    ``sim_cfg`` tunes the analytic timing model (defaults to the calibrated
    :class:`~repro.core.simulator.SimConfig`).  K1 is simulated at the
    cluster's fastest clock (the paper's reference measurement); Kc is
    re-derived per subset size, since the communication coefficient depends
    on how many workers share each layer.
    """

    def __init__(self, model: ReinterpretedModel, cluster: Cluster,
                 sim_cfg: SimConfig | None = None):
        self.model = model
        self.cluster = cluster if isinstance(cluster, Cluster) else Cluster(tuple(cluster))
        self.sim_cfg = sim_cfg or SimConfig()
        self._k1 = simulated_k1(model, self.cluster.max_f_mhz, self.sim_cfg)
        self._kc: dict[int, float] = {}

    def _kc_for(self, n: int) -> float:
        if n not in self._kc:
            self._kc[n] = measured_kc(self.model, n, self.sim_cfg)
        return self._kc[n]

    def _worker_order(self) -> np.ndarray:
        """Workers ranked by capability rating (desc, index tie-break) — the
        subset ladder: the top-k prefix is the k-worker candidate."""
        r = ratings_for(list(self.cluster.workers), self._k1,
                        self._kc_for(self.cluster.n_workers))
        return np.lexsort((np.arange(len(r)), -r))

    # -- the search ----------------------------------------------------------
    def _evaluate(self, objective: Objective) -> list[_Scored | PlanCandidate]:
        """Score every (subset size x mode x fusion) candidate.  Returns
        ``_Scored`` for feasible ones, bare ``PlanCandidate`` otherwise."""
        order = self._worker_order()
        n_max = self.cluster.n_workers
        if objective.max_workers is not None:
            n_max = min(n_max, objective.max_workers)
        model_bytes = float(self.model.total_weight_bytes(1))
        results: list[_Scored | PlanCandidate] = []
        for k in range(1, n_max + 1):
            idx = tuple(sorted(int(i) for i in order[:k]))
            workers = [self.cluster[i] for i in idx]
            base_ratings = ratings_for(workers, self._k1, self._kc_for(k))
            ram_caps = np.array(
                [min(w.ram_bytes, objective.ram_cap_bytes or w.ram_bytes)
                 for w in workers], dtype=np.float64)
            flash_caps = np.array(
                [min(w.flash_bytes, objective.flash_cap_bytes or w.flash_bytes)
                 for w in workers], dtype=np.float64)
            for mode in objective.modes:
                for fusion in (("block", "layer") if mode == "spatial"
                               else ("block",)):
                    results.extend(self._score_one(
                        objective, idx, workers, base_ratings, ram_caps,
                        flash_caps, model_bytes, mode, fusion))
        return results

    def _score_one(self, objective, idx, workers, base_ratings, ram_caps,
                   flash_caps, model_bytes, mode, fusion):
        """Score one (subset, mode, fusion) point: a single infeasible
        candidate (feasibility is transport-independent), or one scored
        candidate per transport searched — the split/peak/weights artifacts
        are built once and only the timing model re-runs per transport."""
        ratings = base_ratings
        assignment = None
        if mode in ("neuron", "kernel"):
            # Eq. 7: shift rating mass away from storage-overflowed workers
            # (weights are split in these modes, so shares track ratings)
            if flash_caps.sum() < model_bytes:
                return [PlanCandidate(
                    mode=mode, fusion=fusion, worker_indices=idx,
                    feasible=False, transport="*",
                    reason=(f"flash_cap: total capacity "
                            f"{flash_caps.sum():.0f} B < model "
                            f"{model_bytes:.0f} B"))]
        try:
            if mode in ("neuron", "kernel"):
                ratings = redistribute_overflow(base_ratings, flash_caps,
                                                model_bytes)
            if mode == "mixed":
                # DP over block boundaries (core.mixed): exact for the
                # serial cost model, with the per-worker RAM caps pruning
                # the per-block state space.  Like spatial, mixed plans may
                # replicate weights, so Eq. 7 does not apply.
                search = search_mixed_assignment(
                    self.model, workers, ratings, self.sim_cfg,
                    minimize=objective.minimize, ram_caps=ram_caps)
                assignment = search.assignment
            split = build_split_plan(self.model, ratings, mode, fusion,
                                     assignment=assignment)
            peak = peak_ram_per_worker(split)
        except (ValueError, RuntimeError) as e:
            # a mode that cannot even build a split for these workers is an
            # explicit infeasible candidate, not a search-aborting crash
            return [PlanCandidate(
                mode=mode, fusion=fusion, worker_indices=idx, feasible=False,
                transport="*", reason=f"split_error: {type(e).__name__}: {e}")]
        weights = np.array([split.worker_weight_bytes(w)
                            for w in range(split.n_workers)], dtype=np.int64)
        over_ram = peak > ram_caps
        over_flash = weights > flash_caps
        if over_ram.any() or over_flash.any():
            terms = []
            if over_ram.any():
                w = int(np.argmax(peak / ram_caps))
                terms.append(f"ram_cap: worker {idx[w]} peak {int(peak[w])} B "
                             f"> cap {int(ram_caps[w])} B")
            if over_flash.any():
                w = int(np.argmax(weights / flash_caps))
                terms.append(f"flash_cap: worker {idx[w]} weights "
                             f"{int(weights[w])} B > cap {int(flash_caps[w])} B")
            return [PlanCandidate(mode=mode, fusion=fusion, worker_indices=idx,
                                  feasible=False, reason="; ".join(terms),
                                  transport="*", assignment=assignment,
                                  max_peak_ram=int(peak.max()),
                                  max_weight_bytes=int(weights.max()))]
        # one simulate covers both transports: a pipelined SimResult carries
        # the serial (Eq. 5-6) decomposition exactly (its layer_* arrays are
        # the serial model — see SimResult), so the serial candidate's
        # metrics are derived without a second full analytic pass
        metrics: dict[str, tuple[float, float, float, float]] = {}
        if "pipelined" in objective.transports:
            cfg = dataclasses.replace(self.sim_cfg, transport="pipelined")
            res = simulate(self.model, workers, ratings, cfg, plan=split)
            metrics["pipelined"] = (res.total_time, res.comp_time,
                                    res.comm_time, res.overlap_saved_s)
            serial_total = res.serial_total_time
            serial_comp = float(res.layer_comp.sum())
            metrics["serial"] = (serial_total, serial_comp,
                                 serial_total - serial_comp, 0.0)
        else:
            cfg = dataclasses.replace(self.sim_cfg, transport="serial")
            res = simulate(self.model, workers, ratings, cfg, plan=split)
            metrics["serial"] = (res.total_time, res.comp_time,
                                 res.comm_time, 0.0)
        out = []
        for transport in objective.transports:
            latency_s, comp_s, comm_s, saved_s = metrics[transport]
            cand = PlanCandidate(
                mode=mode, fusion=fusion, worker_indices=idx, feasible=True,
                transport=transport, assignment=assignment,
                latency_s=latency_s, comp_s=comp_s,
                comm_s=comm_s, comm_bytes=res.total_bytes,
                max_peak_ram=int(peak.max()),
                max_weight_bytes=int(weights.max()),
                overlap_saved_s=saved_s,
                score=objective.score(latency_s, res.total_bytes,
                                      int(peak.max())))
            out.append(_Scored(cand=cand, ratings=ratings, split=split,
                               peak=peak, weights=weights))
        return out

    def candidates(self, objective: Objective | None = None) -> list[PlanCandidate]:
        """The full scored candidate table (feasible and infeasible) the
        search considers — what :meth:`plan` picks its winner from."""
        objective = objective or Objective()
        return [r.cand if isinstance(r, _Scored) else r
                for r in self._evaluate(objective)]

    def plan(self, objective: Objective | None = None) -> Plan:
        """Search and return the best feasible :class:`Plan`; raise
        :class:`InfeasibleError` naming the binding constraint if none fits."""
        objective = objective or Objective()
        results = self._evaluate(objective)
        feasible = [r for r in results if isinstance(r, _Scored)]
        if not feasible:
            raise self._infeasible(objective, results)
        # deterministic winner: best score, then fewer workers, then the
        # objective's mode order, then fused before per-layer, then the
        # objective's transport order (serial first by default, so the async
        # transport only wins when it actually lowers the score)
        mode_rank = {m: i for i, m in enumerate(objective.modes)}
        transport_rank = {t: i for i, t in enumerate(objective.transports)}
        best = min(feasible, key=lambda s: (
            s.cand.score, len(s.cand.worker_indices),
            mode_rank[s.cand.mode], s.cand.fusion,
            transport_rank[s.cand.transport]))
        c = best.cand
        return Plan(
            model=self.model, cluster=self.cluster, objective=objective,
            mode=c.mode, fusion=c.fusion, worker_indices=c.worker_indices,
            ratings=best.ratings, split=best.split,
            latency_s=c.latency_s, comp_s=c.comp_s, comm_s=c.comm_s,
            comm_bytes=c.comm_bytes, peak_ram=best.peak,
            weight_bytes=best.weights, score=c.score,
            transport=c.transport, overlap_saved_s=c.overlap_saved_s,
            assignment=c.assignment,
            candidates=tuple(r.cand if isinstance(r, _Scored) else r
                             for r in results))

    def _infeasible(self, objective: Objective, results) -> InfeasibleError:
        """Build the error naming the constraint the closest candidate missed
        by the smallest relative margin (the binding constraint)."""
        best_cand, best_kind, best_margin = None, "ram_cap", float("inf")
        for r in results:
            cand = r.cand if isinstance(r, _Scored) else r
            if cand.feasible or cand.reason is None:
                continue
            if cand.reason.startswith("split_error"):
                kind = "split_error"
            else:
                kind = ("ram_cap" if cand.reason.startswith("ram_cap")
                        else "flash_cap")
            if kind == "ram_cap" and objective.ram_cap_bytes:
                margin = cand.max_peak_ram / objective.ram_cap_bytes
            elif kind == "flash_cap" and objective.flash_cap_bytes:
                margin = (cand.max_weight_bytes / objective.flash_cap_bytes
                          if cand.max_weight_bytes else float("inf"))
            else:
                margin = float("inf")
            if margin < best_margin:
                best_cand, best_kind, best_margin = cand, kind, margin
        if best_cand is None:
            # no candidate produced numbers (e.g. total flash < model bytes)
            cands = [r.cand if isinstance(r, _Scored) else r for r in results]
            best_cand = cands[0]
            reason = best_cand.reason or ""
            if reason.startswith("flash_cap"):
                best_kind = "flash_cap"
            elif reason.startswith("split_error"):
                best_kind = "split_error"
            else:
                best_kind = "ram_cap"
        details = {"mode": best_cand.mode, "fusion": best_cand.fusion,
                   "worker_indices": list(best_cand.worker_indices),
                   "reason": best_cand.reason,
                   "max_peak_ram": best_cand.max_peak_ram,
                   "max_weight_bytes": best_cand.max_weight_bytes,
                   "ram_cap_bytes": objective.ram_cap_bytes,
                   "flash_cap_bytes": objective.flash_cap_bytes}
        return InfeasibleError(
            f"no feasible split for the objective; binding constraint "
            f"{best_kind} — closest candidate {best_cand.mode} over "
            f"{len(best_cand.worker_indices)} workers failed with: "
            f"{best_cand.reason}",
            binding_constraint=best_kind, details=details)
