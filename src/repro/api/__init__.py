"""Coordinator-first facade: ``Cluster`` → ``Planner`` → ``Plan`` → ``Session``.

The paper's central contribution is a *resource-aware coordinator*; this
package is that coordinator as a stable three-noun API::

    from repro.api import Cluster, Objective, Planner

    cluster = Cluster.heterogeneous_demo(8)
    plan = Planner(model, cluster).plan(
        Objective(minimize="latency", ram_cap_bytes=512 * 1024))
    print(plan.report())
    session = plan.compile(precision="int8")
    outputs = session.submit_many(requests)

``Cluster`` validates the measured worker set (presets, JSON round-trip);
``Planner`` searches mode × fusion × worker subsets × transport (the serial
Eq. 5-6 coordinator vs the event-driven per-link async transport) with the
analytic cost models — include ``"mixed"`` in ``Objective.modes`` (or pass
:data:`SEARCH_MODES`) to also search heterogeneous per-block mode
assignments via dynamic programming — and raises :class:`InfeasibleError`
(naming the binding constraint) instead of returning a bad plan; ``Plan``
is scored, serializable and reportable; ``Session`` serves micro-batched
requests through the compiled engine with per-bucket compilation caching
and rolling stats.

The free functions in :mod:`repro.core` (``split_model``, ``simulate``,
``ratings_for``, ...) remain the underlying engine and stay importable, but
new code should go through this facade.
"""
from .cluster import Cluster, ClusterError
from .plan import FUSIONS, Plan, build_split_plan
from .planner import (SEARCH_MODES, InfeasibleError, Objective, PlanCandidate,
                      Planner)
from .session import (InflightDispatch, RollingLatency, Session,
                      SessionStats, Ticket)

__all__ = [
    "Cluster",
    "ClusterError",
    "FUSIONS",
    "InfeasibleError",
    "InflightDispatch",
    "Objective",
    "Plan",
    "PlanCandidate",
    "Planner",
    "RollingLatency",
    "SEARCH_MODES",
    "Session",
    "SessionStats",
    "Ticket",
    "build_split_plan",
]
