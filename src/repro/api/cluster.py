"""`Cluster`: a validated set of worker MCUs (the facade's first noun).

The paper's deployment-initialization step measures each worker's clock,
link delay/bandwidth and memory budgets (§III Pipeline); a ``Cluster`` is
that measurement set as one immutable value — validated once at
construction so every later planning/serving step can trust it — plus the
presets the examples and tests deploy against.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from ..core.allocation import WorkerParams


class ClusterError(ValueError):
    """Invalid cluster description (bad worker parameters, empty set, ...)."""


def json_source_text(source: str | pathlib.Path) -> str:
    """Resolve a ``from_json`` source: a JSON string is returned as-is, a
    path (``pathlib.Path``, or a string that doesn't start with ``{``) is
    read from disk.  Shared by every facade ``from_json`` entry point."""
    if isinstance(source, pathlib.Path) or (
            isinstance(source, str) and not source.lstrip().startswith("{")):
        return pathlib.Path(source).read_text()
    return source


# Default heterogeneous testbed of the serving example: Teensy-class MCUs at
# mixed clocks, some behind slow links (d > 0).  Cycled for n > 8.
_DEMO_FREQS = (600, 600, 528, 450, 450, 396, 150, 150)
_DEMO_DELAYS = (0.0, 0.001, 0.0, 0.002, 0.0, 0.004, 0.001, 0.0)


@dataclasses.dataclass(frozen=True)
class Cluster:
    """An immutable, validated set of :class:`WorkerParams`.

    Construct directly from measured workers, or via the presets
    (:meth:`homogeneous`, :meth:`heterogeneous_demo`) or :meth:`from_json`.
    """

    workers: tuple[WorkerParams, ...]
    name: str = "cluster"

    def __post_init__(self) -> None:
        if not isinstance(self.workers, tuple):
            object.__setattr__(self, "workers", tuple(self.workers))
        if len(self.workers) == 0:
            raise ClusterError("a cluster needs at least one worker")
        for i, w in enumerate(self.workers):
            if not isinstance(w, WorkerParams):
                raise ClusterError(f"worker {i}: expected WorkerParams, got {type(w).__name__}")
            if w.f_mhz <= 0:
                raise ClusterError(f"worker {i}: f_mhz must be > 0 (got {w.f_mhz})")
            if w.b_kb_s <= 0:
                raise ClusterError(f"worker {i}: b_kb_s must be > 0 (got {w.b_kb_s})")
            if w.d_s_per_kb < 0:
                raise ClusterError(f"worker {i}: d_s_per_kb must be >= 0 (got {w.d_s_per_kb})")
            if w.ram_bytes <= 0:
                raise ClusterError(f"worker {i}: ram_bytes must be > 0 (got {w.ram_bytes})")
            if w.flash_bytes <= 0:
                raise ClusterError(f"worker {i}: flash_bytes must be > 0 (got {w.flash_bytes})")

    # -- container protocol --------------------------------------------------
    def __len__(self) -> int:
        return len(self.workers)

    def __iter__(self):
        return iter(self.workers)

    def __getitem__(self, i: int) -> WorkerParams:
        return self.workers[i]

    @property
    def n_workers(self) -> int:
        return len(self.workers)

    @property
    def max_f_mhz(self) -> float:
        """Fastest clock in the cluster — the K1 reference frequency."""
        return max(w.f_mhz for w in self.workers)

    def subset(self, indices, name: str | None = None) -> "Cluster":
        """A new cluster holding ``workers[i] for i in indices`` (order kept)."""
        idx = tuple(int(i) for i in indices)
        for i in idx:
            if not 0 <= i < len(self.workers):
                raise ClusterError(f"subset index {i} out of range for {len(self.workers)} workers")
        return Cluster(tuple(self.workers[i] for i in idx),
                       name=name or f"{self.name}[{len(idx)}]")

    # -- presets -------------------------------------------------------------
    @classmethod
    def homogeneous(cls, n: int, *, f_mhz: float = 600.0, d_s_per_kb: float = 0.0,
                    b_kb_s: float = 11500.0, ram_bytes: int = 512 * 1024,
                    flash_bytes: int = 8 * 1024 * 1024,
                    name: str | None = None) -> "Cluster":
        """``n`` identical workers (the paper's Fig. 9/12 scaling setup)."""
        w = WorkerParams(f_mhz=f_mhz, d_s_per_kb=d_s_per_kb, b_kb_s=b_kb_s,
                         ram_bytes=ram_bytes, flash_bytes=flash_bytes)
        return cls((w,) * int(n), name=name or f"homogeneous-{n}")

    @classmethod
    def heterogeneous_demo(cls, n: int = 8, *, ram_bytes: int = 512 * 1024,
                           flash_bytes: int = 8 * 1024 * 1024) -> "Cluster":
        """The serving example's mixed-clock/mixed-link testbed (cycled)."""
        workers = tuple(
            WorkerParams(f_mhz=_DEMO_FREQS[i % len(_DEMO_FREQS)],
                         d_s_per_kb=_DEMO_DELAYS[i % len(_DEMO_DELAYS)],
                         ram_bytes=ram_bytes, flash_bytes=flash_bytes)
            for i in range(int(n)))
        return cls(workers, name=f"heterogeneous-demo-{n}")

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {"name": self.name,
                "workers": [dataclasses.asdict(w) for w in self.workers]}

    def to_json(self, path: str | pathlib.Path | None = None) -> str:
        """JSON text (also written to ``path`` when given)."""
        text = json.dumps(self.to_dict(), indent=2)
        if path is not None:
            pathlib.Path(path).write_text(text + "\n")
        return text

    @classmethod
    def from_dict(cls, data: dict) -> "Cluster":
        try:
            workers = tuple(WorkerParams(**w) for w in data["workers"])
        except (KeyError, TypeError) as e:
            raise ClusterError(f"malformed cluster description: {e}") from e
        return cls(workers, name=data.get("name", "cluster"))

    @classmethod
    def from_json(cls, source: str | pathlib.Path) -> "Cluster":
        """Load from a JSON file path or a JSON string."""
        try:
            data = json.loads(json_source_text(source))
        except json.JSONDecodeError as e:
            raise ClusterError(f"invalid cluster JSON: {e}") from e
        return cls.from_dict(data)
