"""`Plan`: a scored, serializable split-inference deployment decision.

A ``Plan`` binds together everything the coordinator decided — which
partitioning mode, which fusion granularity, which worker subset, what
capability ratings — plus the simulated cost profile that justified the
decision.  It is produced by :class:`repro.api.Planner`, can round-trip
through JSON (weights are *not* serialized; deserialization re-derives the
:class:`~repro.core.splitting.SplitPlan` from the model + stored ratings and
cross-checks the deterministic metrics), and compiles into a serving
:class:`repro.api.Session` via :meth:`Plan.compile`.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from ..core.memory import peak_ram_per_worker
from ..core.reinterpret import ReinterpretedModel
from ..core.splitting import SplitPlan, split_model, split_model_mixed
from .cluster import Cluster, json_source_text

FUSIONS = ("block", "layer")


def build_split_plan(model: ReinterpretedModel, ratings, mode: str,
                     fusion: str = "block",
                     assignment=None, block_workers=None) -> SplitPlan:
    """Build the concrete :class:`SplitPlan` for one (mode, fusion) candidate.

    ``fusion`` selects the execution granularity of spatial plans:
    ``"block"`` fuses whole inverted-residual blocks per band (the default —
    interior activations never materialize at full resolution), ``"layer"``
    bands every conv layer independently (no fused blocks: more boundary
    traffic, no interior-halo recompute).  Neuron/kernel plans have a single
    granularity; ``fusion`` is ignored for them.  Delegates to core
    :func:`split_model` — the splitting semantics live in one place.

    ``mode="mixed"`` builds a heterogeneous plan from ``assignment`` (the
    per-fused-block mode vector, required; always block-fused granularity) —
    core :func:`split_model_mixed`.  ``block_workers`` optionally narrows
    each block to a worker subset (per-block index iterables, ``None``
    entries keep all workers); uniform modes ignore it.
    """
    if fusion not in FUSIONS:
        raise ValueError(f"unknown fusion {fusion!r} (want one of {FUSIONS})")
    if mode == "mixed":
        if assignment is None:
            raise ValueError("mode='mixed' needs a per-block assignment")
        return split_model_mixed(model, ratings, assignment,
                                 block_workers=block_workers)
    return split_model(model, ratings, mode=mode, fused=(fusion == "block"))


def _model_fingerprint(model: ReinterpretedModel) -> dict:
    """Cheap structural identity used to reject deserializing a plan against
    the wrong model (weights themselves are never serialized)."""
    return {"n_layers": len(model.layers),
            "input_shape": list(model.input_shape),
            "total_macs": int(model.total_macs()),
            "total_weight_bytes": int(model.total_weight_bytes(1))}


@dataclasses.dataclass(frozen=True)
class Plan:
    """A feasible, scored deployment: the Planner's output.

    ``worker_indices`` index into ``cluster``; ``ratings``/``peak_ram``/
    ``weight_bytes`` are aligned with that subset.  ``candidates`` keeps the
    full scored search table (feasible and not) for :meth:`report`.
    """

    model: ReinterpretedModel
    cluster: Cluster
    objective: "object"                  # repro.api.Objective
    mode: str
    fusion: str
    worker_indices: tuple[int, ...]
    ratings: np.ndarray
    split: SplitPlan
    latency_s: float
    comp_s: float
    comm_s: float
    comm_bytes: int
    peak_ram: np.ndarray                 # per selected worker, bytes (int8)
    weight_bytes: np.ndarray             # per selected worker, bytes (int8)
    score: float
    # transport policy the winning candidate was costed under ("serial" is
    # the Eq. 5-6 coordinator-serialized model; "pipelined" the per-link
    # async transport) and the seconds pipelining saved vs serial (0 when
    # transport == "serial")
    transport: str = "serial"
    overlap_saved_s: float = 0.0
    # mixed plans only: per-fused-block mode vector (group_blocks
    # granularity) the DP search chose; None for uniform plans
    assignment: tuple[str, ...] | None = None
    # mixed plans with Objective(mixed_subsets=...): per-block worker
    # subsets the DP chose (indices into worker_indices' subset, None
    # entries = all workers); None when every block uses the full subset
    block_workers: tuple | None = None
    # search telemetry from the Planner (core.search.SearchStats.to_dict():
    # candidates evaluated, cache hit rate, search wall); None when the
    # plan was deserialized from a pre-v2-search payload
    search_stats: dict | None = None
    candidates: tuple = ()

    # -- derived views -------------------------------------------------------
    @property
    def workers(self) -> tuple:
        """The selected :class:`WorkerParams`, in plan order."""
        return tuple(self.cluster[i] for i in self.worker_indices)

    @property
    def n_workers(self) -> int:
        return len(self.worker_indices)

    @property
    def max_peak_ram(self) -> int:
        return int(np.max(self.peak_ram))

    @property
    def max_weight_bytes(self) -> int:
        return int(np.max(self.weight_bytes))

    # -- reporting -----------------------------------------------------------
    @staticmethod
    def _rle(assignment) -> str:
        """Run-length-encode a per-block mode vector for display:
        ('spatial',)*5 + ('kernel',)*3 -> 'spatial*5 kernel*3'."""
        runs: list[tuple[str, int]] = []
        for m in assignment:
            if runs and runs[-1][0] == m:
                runs[-1] = (m, runs[-1][1] + 1)
            else:
                runs.append((m, 1))
        return " ".join(m if k == 1 else f"{m}*{k}" for m, k in runs)

    def report(self) -> str:
        """Human-readable summary: the decision, its cost profile, and the
        scored candidate table the search considered."""
        lines = [
            f"Plan: mode={self.mode}"
            + (f"/{self.fusion}" if self.mode == "spatial" else "")
            + f", transport={self.transport}"
            + f", {self.n_workers}/{self.cluster.n_workers} workers "
            f"{list(self.worker_indices)} of {self.cluster.name!r}",
            f"  objective: minimize {getattr(self.objective, 'minimize', '?')}"
            f"  score={self.score:.6g}",
            f"  simulated latency: {self.latency_s * 1e3:.1f} ms "
            f"(comp {self.comp_s * 1e3:.1f} + comm {self.comm_s * 1e3:.1f})"
            + (f", overlap saves {self.overlap_saved_s * 1e3:.1f} ms "
               "vs serial" if self.transport == "pipelined" else ""),
            f"  bytes moved/inference: {self.comm_bytes / 1e6:.2f} MB",
            f"  max per-worker peak RAM: {self.max_peak_ram / 1024:.1f} KB",
            f"  max per-worker weights:  {self.max_weight_bytes / 1024:.1f} KB",
            f"  ratings: {np.round(np.asarray(self.ratings), 2).tolist()}",
        ]
        if self.assignment is not None:
            lines.insert(1, "  per-block modes: " + self._rle(self.assignment))
        if self.block_workers is not None and any(
                s is not None for s in self.block_workers):
            lines.append("  per-block workers: " + " ".join(
                "all" if s is None else str(list(s))
                for s in self.block_workers))
        if self.search_stats:
            s = self.search_stats
            lines.append(
                f"  search: {s.get('candidates_evaluated', 0)} candidates "
                f"({s.get('subsets_explored', 0)} subsets, "
                f"cache hit rate {s.get('cache_hit_rate', 0.0):.0%}) "
                f"in {s.get('search_wall_s', 0.0) * 1e3:.0f} ms")
        if self.candidates:
            lines.append("  search ({} candidates):".format(len(self.candidates)))
            for c in self.candidates:
                tag = f"{c.mode}" + (f"/{c.fusion}" if c.mode == "spatial" else "")
                tag += f"/{getattr(c, 'transport', 'serial')}"
                if c.feasible:
                    lines.append(
                        f"    {tag:24s} workers={len(c.worker_indices)} "
                        f"latency={c.latency_s * 1e3:8.1f}ms "
                        f"peak={c.max_peak_ram / 1024:7.1f}KB "
                        f"score={c.score:.6g}"
                        + ("   <- selected" if self._is_selected(c) else ""))
                else:
                    lines.append(
                        f"    {tag:24s} workers={len(c.worker_indices)} "
                        f"INFEASIBLE ({c.reason})")
        return "\n".join(lines)

    def _is_selected(self, cand) -> bool:
        return (cand.mode == self.mode and cand.fusion == self.fusion
                and cand.transport == self.transport
                and tuple(cand.worker_indices) == tuple(self.worker_indices)
                and getattr(cand, "assignment", None) == self.assignment
                and getattr(cand, "block_workers", None) == self.block_workers)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        # schema v2 adds "assignment" (per-fused-block mode vector of mixed
        # plans; null for uniform).  v1 payloads predate mode mixing and
        # load as uniform-mode plans (from_dict tolerates the missing key).
        # "block_workers" and "search_stats" are additive v2 keys (null when
        # absent): per-block worker subsets and the search telemetry.
        return {
            "version": 2,
            "kind": "repro.api.Plan",
            "model": _model_fingerprint(self.model),
            "cluster": self.cluster.to_dict(),
            "objective": self.objective.to_dict(),
            "mode": self.mode,
            "fusion": self.fusion,
            "transport": self.transport,
            "assignment": (list(self.assignment)
                           if self.assignment is not None else None),
            "block_workers": (
                [list(s) if s is not None else None
                 for s in self.block_workers]
                if self.block_workers is not None else None),
            "search_stats": self.search_stats,
            "worker_indices": list(self.worker_indices),
            "ratings": [float(r) for r in np.asarray(self.ratings)],
            "metrics": {
                "latency_s": float(self.latency_s),
                "comp_s": float(self.comp_s),
                "comm_s": float(self.comm_s),
                "comm_bytes": int(self.comm_bytes),
                "overlap_saved_s": float(self.overlap_saved_s),
                "score": float(self.score),
            },
            "peak_ram": [int(b) for b in np.asarray(self.peak_ram)],
            "weight_bytes": [int(b) for b in np.asarray(self.weight_bytes)],
            "candidates": [c.to_dict() for c in self.candidates],
        }

    def to_json(self, path: str | pathlib.Path | None = None) -> str:
        # allow_nan=False guards the contract: the payload must stay strict
        # RFC-8259 JSON (candidate NaN sentinels are mapped to null upstream)
        text = json.dumps(self.to_dict(), indent=2, allow_nan=False)
        if path is not None:
            pathlib.Path(path).write_text(text + "\n")
        return text

    @classmethod
    def from_dict(cls, data: dict, model: ReinterpretedModel) -> "Plan":
        """Rebuild a plan against ``model``.  The split plan is re-derived
        from the stored ratings/mode (weights are not serialized) and the
        deterministic peak-RAM metric is cross-checked against the stored
        value, so loading a plan against the wrong model fails loudly."""
        from .planner import Objective, PlanCandidate  # circular at import time
        if data.get("kind") != "repro.api.Plan":
            raise ValueError("not a serialized repro.api.Plan")
        fp_stored, fp_model = data["model"], _model_fingerprint(model)
        if fp_stored != fp_model:
            raise ValueError(
                f"plan/model mismatch: plan was built for {fp_stored}, "
                f"got {fp_model}")
        cluster = Cluster.from_dict(data["cluster"])
        ratings = np.asarray(data["ratings"], dtype=np.float64)
        # v1 payloads carry no "assignment": they predate mode mixing and
        # rebuild as uniform-mode plans
        assignment = data.get("assignment")
        if data["mode"] == "mixed" and assignment is None:
            raise ValueError("mixed plan payload lacks its per-block "
                             "assignment")
        block_workers = data.get("block_workers")
        if block_workers is not None:
            block_workers = tuple(
                tuple(int(w) for w in s) if s is not None else None
                for s in block_workers)
        split = build_split_plan(model, ratings, data["mode"], data["fusion"],
                                 assignment=assignment,
                                 block_workers=block_workers)
        peak = peak_ram_per_worker(split)
        stored_peak = np.asarray(data["peak_ram"], dtype=np.int64)
        if not np.array_equal(peak, stored_peak):
            raise ValueError(
                "deserialized plan failed its peak-RAM cross-check: "
                f"recomputed {peak.tolist()} != stored {stored_peak.tolist()}")
        m = data["metrics"]
        return cls(
            model=model, cluster=cluster,
            objective=Objective.from_dict(data["objective"]),
            mode=data["mode"], fusion=data["fusion"],
            transport=data.get("transport", "serial"),
            worker_indices=tuple(int(i) for i in data["worker_indices"]),
            ratings=ratings, split=split,
            latency_s=float(m["latency_s"]), comp_s=float(m["comp_s"]),
            comm_s=float(m["comm_s"]), comm_bytes=int(m["comm_bytes"]),
            peak_ram=stored_peak,
            weight_bytes=np.asarray(data["weight_bytes"], dtype=np.int64),
            score=float(m["score"]),
            overlap_saved_s=float(m.get("overlap_saved_s", 0.0)),
            assignment=(tuple(assignment) if assignment is not None
                        else None),
            block_workers=block_workers,
            search_stats=data.get("search_stats"),
            candidates=tuple(PlanCandidate.from_dict(c)
                             for c in data.get("candidates", ())))

    @classmethod
    def from_json(cls, source: str | pathlib.Path,
                  model: ReinterpretedModel) -> "Plan":
        """Load from a JSON file path or a JSON string (needs the model the
        plan was built for — weights are never serialized)."""
        return cls.from_dict(json.loads(json_source_text(source)), model)

    def worker_geometry(self) -> list[dict]:
        """JSON-serializable per-worker shard geometry: what each worker
        stores and computes, per block group — the payload skeleton the
        distributed runtime ships at setup (``repro.runtime.shards``)."""
        from ..runtime.shards import worker_geometry_summary
        return worker_geometry_summary(self.split)

    # -- serving -------------------------------------------------------------
    def compile(self, precision: str = "int8", **session_kwargs):
        """Compile this plan into a serving :class:`repro.api.Session`
        (micro-batched ``CompiledSplitExecutor`` wrapper).  ``precision`` is
        ``"int8"`` (W8A8, auto-calibrated unless ``calibration=``/``qmodel=``
        given) or ``"float"``."""
        from .session import Session
        return Session(self, precision=precision, **session_kwargs)
