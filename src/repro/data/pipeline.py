"""Deterministic sharded synthetic data pipeline with background prefetch.

Each host process reads only its shard of the global batch (disjointness is
property-tested); a double-buffering prefetch thread keeps the next batch
ready while the step runs — the host-side half of compute/IO overlap.  The
token stream is a fixed-seed PRNG "corpus" with a repeating n-gram structure
so small models measurably learn (loss decreases) in the examples.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Deterministic pseudo-corpus: a Markov-ish token stream where token
    t+1 = (a * t + noise) % vocab with segment structure — learnable but
    non-trivial."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> dict:
        """Global batch ``step``; returns this shard's slice (host-disjoint,
        deterministic in (step, shard))."""
        assert batch_size % n_shards == 0
        local = batch_size // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        start = rng.integers(0, self.vocab, (local, 1))
        mult = rng.integers(2, 8, (local, 1))
        noise = rng.integers(0, 5, (local, seq_len))
        idx = np.arange(seq_len)[None, :]
        toks = (start + mult * idx + noise) % self.vocab
        return {"tokens": toks.astype(np.int32)}


class Prefetcher:
    """Background double-buffering over a batch-producing callable."""

    def __init__(self, make_batch, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._make = make_batch
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self._make(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
