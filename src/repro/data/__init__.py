from .pipeline import Prefetcher, SyntheticLM
