"""Explicit-collective utilities (shard_map level).

``compressed_psum``: int8-quantized gradient all-reduce — each shard
quantizes with a per-tensor symmetric scale, psums the int32 payload and the
scales, and dequantizes.  On a real pod this is the cross-DCN ('pod' axis)
reducer where 4x byte savings matter most; the train step's
``compress_grads`` flag reproduces the same numerics inside pjit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quantize(g, bits: int):
    qmax = 2.0 ** (bits - 1) - 1
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / qmax
    q = jnp.round(gf / scale).clip(-qmax, qmax).astype(jnp.int32)
    return q, scale


def compressed_psum(x, axis_name: str, bits: int = 8):
    """All-reduce ``x`` over ``axis_name`` with int-N payload compression.

    Mean-preserving: each shard contributes q_i * s_i; the reduction sums
    int payloads per-scale via a scale-normalized trick — we psum the
    dequantized-but-int-valued payload (q * s), which keeps the wire format
    conceptually int8 + one f32 scale.  Returns the SUM (like lax.psum).
    """
    q, s = _quantize(x, bits)
    # wire payload: int8-representable values; reduction in f32
    return jax.lax.psum(q.astype(jnp.float32) * s, axis_name)


def make_compressed_grad_sync(mesh, axis_name: str = "data", bits: int = 8):
    """shard_map'd gradient synchronizer: tree of per-shard grads -> tree of
    compressed-summed grads (divide by axis size outside for the mean)."""

    def sync(tree):
        def one(g):
            spec = P(*([None] * g.ndim))
            f = shard_map(
                functools.partial(compressed_psum, axis_name=axis_name,
                                  bits=bits),
                mesh=mesh, in_specs=spec, out_specs=spec)
            return f(g)
        return jax.tree.map(one, tree)

    return sync
