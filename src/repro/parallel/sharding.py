"""Sharding rules: the paper's fine-grained output-neuron splitting mapped to
mesh axes (DESIGN.md §2).

Params and activations are annotated with *logical axis names*; a rules table
maps logical names -> mesh axes per execution mode.  Column-parallel linears
('ff', 'heads', 'vocab' on the output dim) are the paper's Alg. 1/2 kernel-
and column-wise splits; 'embed' FSDP sharding over the data axis is the
ZeRO-style weight distribution that bounds per-device parameter bytes.

``routing`` selects the paper-faithful coordinator pattern (activations
replicated at every layer boundary — everything flows "through the
coordinator") vs the beyond-paper ``direct`` mode (activations stay sharded;
reduce-scatter/all-gather pairs = direct worker-to-worker forwarding, the
paper's explicit future work).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis mapping (None = replicate)."""

    mesh: Mesh | None
    rules: dict[str, Any]

    @staticmethod
    def _dedup(axes_list: list) -> list:
        """A mesh axis may appear only once in a PartitionSpec; on conflict
        the earlier (leftmost) dim keeps it."""
        seen: set[str] = set()
        out = []
        for axes in axes_list:
            if axes is None:
                out.append(None)
                continue
            tup = (axes,) if isinstance(axes, str) else tuple(axes)
            tup = tuple(a for a in tup if a not in seen)
            seen.update(tup)
            out.append(tup if tup else None)
        return out

    def spec(self, names: tuple[str | None, ...]) -> P:
        return P(*self._dedup([self.rules.get(n) if n else None for n in names]))

    def sharding(self, names: tuple[str | None, ...]) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(names))

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def fit_spec(self, names: tuple[str | None, ...],
                 shape: tuple[int, ...]) -> P:
        """Like spec(), but drops mesh axes on dims they don't divide —
        pjit argument shardings require exact divisibility (uneven sharding
        is only legal on internal constraints, where GSPMD pads)."""
        out = []
        for n, dim in zip(names, shape):
            axes = self.rules.get(n) if n else None
            if axes is not None and dim % self._axis_size(axes) != 0:
                axes = None
            out.append(axes)
        return P(*self._dedup(out))

    def fit_sharding(self, names: tuple[str | None, ...],
                     shape: tuple[int, ...]) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.fit_spec(names, shape))

    def sds(self, shape: tuple[int, ...], dtype,
            names: tuple[str | None, ...]) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct with a divisibility-fitted sharding."""
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=self.fit_sharding(names, shape))


def _axes(mesh: Mesh | None) -> set[str]:
    return set(mesh.axis_names) if mesh is not None else set()


def make_rules(mesh: Mesh | None, mode: str = "train",
               routing: str = "direct", seq_parallel: bool = True) -> MeshRules:
    """Build the rules table for a mesh.

    mode: 'train' (FSDP over data + TP over model) or 'serve' (TP only;
    MoE experts over data).
    routing: 'direct' | 'coordinator' (paper-faithful baseline).
    """
    ax = _axes(mesh)
    data_axes = tuple(a for a in ("pod", "data") if a in ax) or None
    model = "model" if "model" in ax else None
    # FSDP over the data axis in BOTH modes: d_model always divides the mesh
    # (head dims often don't), so this is the axis that reliably bounds
    # per-device parameter bytes — the paper's core memory goal.  In serve
    # mode this trades per-layer weight all-gathers for fitting in HBM.
    fsdp = data_axes
    rules: dict[str, Any] = {
        # --- parameter logical axes ---
        "embed": fsdp,            # FSDP: shard d_model dim of weights on data
        "ff": model,              # column-parallel output dim (paper Alg. 2)
        "ff_in": model,           # row-parallel input dim (down-projection)
        "heads": model,           # kernel-wise q-group split (MQA archs)
        "kv_heads": model,        # kernel-wise kv-head split (GQA/MHA archs)
        "vocab": model,           # output-neuron split of the LM head
        "experts": model if mode == "train" else data_axes,
        "expert_ff": model if mode == "serve" else None,
        "rnn": model,             # RG-LRU channels are independent neurons
        "layers": None,           # scanned layer axis is never sharded
        # --- activation logical axes ---
        "batch": data_axes,
        "seq": model if seq_parallel else None,
        "act_embed": None,
        "act_heads": model,
        "act_ff": model,
        "kv_seq": model,          # decode KV cache sharded along sequence
        "moe_groups": data_axes,
        "act_experts": model if mode == "train" else data_axes,
    }
    if routing == "coordinator":
        # Paper-faithful: every layer-boundary activation is replicated (all
        # traffic through the coordinator); weights stay split.  The model
        # axis then all-gathers activations instead of reduce-scattering.
        rules.update({"act_heads": None, "act_ff": None, "seq": None,
                      "kv_seq": None})
    return MeshRules(mesh=mesh, rules=rules)


# --- thread-local rules context (models call shard_act without plumbing) ---
_ctx = threading.local()


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield
    finally:
        _ctx.rules = prev


def current_rules() -> MeshRules | None:
    return getattr(_ctx, "rules", None)


def shard_act(x, names: tuple[str | None, ...]):
    """Apply a sharding constraint if a rules context is active."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    if jax.eval_shape(lambda v: v, x).ndim != len(names):
        raise ValueError(f"rank mismatch: {x.shape} vs names {names}")
    return jax.lax.with_sharding_constraint(x, r.sharding(names))


def param_shardings(spec_tree, rules: MeshRules, shapes=None):
    """Map a tree of logical-name tuples to NamedShardings.  When ``shapes``
    (a matching tree of ShapeDtypeStructs/arrays) is given, shardings are
    divisibility-fitted per dim."""
    if shapes is None:
        return jax.tree.map(
            lambda names: rules.sharding(tuple(names)),
            spec_tree, is_leaf=lambda v: isinstance(v, tuple))
    return jax.tree.map(
        lambda names, s: rules.fit_sharding(tuple(names), tuple(s.shape)),
        spec_tree, shapes, is_leaf=lambda v: isinstance(v, tuple))
