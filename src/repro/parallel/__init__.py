from .sharding import MeshRules, make_rules, param_shardings, shard_act, use_rules
