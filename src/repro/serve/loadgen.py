"""Open-loop Poisson load generator for the multi-tenant server.

Open-loop means arrivals follow a pre-drawn schedule that does NOT react to
completions — the generator submits at the scheduled instant (or
immediately, if it has fallen behind the clock) whether or not earlier
requests finished.  This is the discipline that exposes real tail latency:
a closed-loop driver slows down exactly when the server struggles
(coordinated omission) and reports flattering percentiles.

Two instruments:

* :func:`run_open_loop` — drive one or more tenants concurrently (one
  generator thread each) at fixed offered rates for a duration; report
  per-tenant p50/p99 end-to-end latency, achieved throughput and the
  rejection rate (``Overloaded`` responses are *counted*, not retried —
  shed load is the admission policy working).
* :func:`saturation_throughput` — the server's sustainable ceiling on one
  tenant: enqueue a deep closed burst and measure drain rate (best of
  ``repeats``).  Offered rates for open-loop runs are usually set relative
  to this.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from .admission import Overloaded
from .server import Server


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """One tenant's open-loop run, measured from the client side."""

    tenant: str
    offered_rps: float              # Poisson arrival rate driven
    duration_s: float               # scheduled generation window
    submitted: int
    accepted: int
    rejected: int                   # typed Overloaded shed responses
    failed: int                     # tickets that raised (dispatch errors)
    completed: int
    p50_s: float                    # end-to-end: submit -> result ready
    p99_s: float
    throughput_rps: float           # completions / wall (incl. drain)
    rejection_rate: float

    def describe(self) -> str:
        return (f"{self.tenant} @ {self.offered_rps:.0f} req/s offered: "
                f"p50={self.p50_s * 1e3:.2f}ms p99={self.p99_s * 1e3:.2f}ms "
                f"served {self.throughput_rps:.0f} req/s, "
                f"rejected {self.rejection_rate:.1%}")


def _percentile(values: list[float], q: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


def _drive_one(server: Server, tenant: str, make_input, rate_rps: float,
               duration_s: float, rng: np.random.Generator,
               result_timeout_s: float, out: dict) -> None:
    # pre-draw the whole Poisson schedule: exponential inter-arrivals,
    # absolute offsets — generation cost cannot distort the arrival process
    n_max = max(1, int(rate_rps * duration_s * 1.5 + 10 * rate_rps ** 0.5))
    offsets = np.cumsum(rng.exponential(1.0 / rate_rps, size=n_max))
    offsets = offsets[offsets < duration_s]
    accepted: list[tuple[object, float]] = []   # (ticket, t_submit)
    rejected = 0
    t0 = time.perf_counter()
    for off in offsets:
        now = time.perf_counter() - t0
        if off > now:
            time.sleep(off - now)   # ahead of schedule: wait for the instant
        # behind schedule: submit immediately (open loop — never skip)
        try:
            t_submit = time.perf_counter()
            ticket = server.submit(tenant, make_input())
            accepted.append((ticket, t_submit))
        except Overloaded:
            rejected += 1
    # drain: wait for every accepted ticket.  Latency is submit -> the
    # ticket's own fulfillment stamp, NOT the time this drain loop got to
    # it — draining sequentially after the window must not inflate tails.
    latencies: list[float] = []
    failed = 0
    deadline = time.perf_counter() + result_timeout_s
    for ticket, t_submit in accepted:
        try:
            ticket.result(timeout=max(0.001, deadline - time.perf_counter()))
            latencies.append(ticket.completed_at - t_submit)
        except Exception:   # timeout or rejected ticket: count, keep draining
            failed += 1
    wall = time.perf_counter() - t0
    submitted = len(offsets)
    out[tenant] = LoadReport(
        tenant=tenant, offered_rps=float(rate_rps),
        duration_s=float(duration_s), submitted=submitted,
        accepted=len(accepted), rejected=rejected, failed=failed,
        completed=len(latencies),
        p50_s=_percentile(latencies, 50), p99_s=_percentile(latencies, 99),
        throughput_rps=(len(latencies) / wall if wall > 0 else 0.0),
        rejection_rate=(rejected / submitted if submitted else 0.0))


def run_open_loop(server: Server, rates_rps: dict[str, float],
                  make_input, duration_s: float = 2.0, *, seed: int = 0,
                  result_timeout_s: float = 30.0) -> dict[str, LoadReport]:
    """Drive ``{tenant: offered_rate}`` concurrently (one open-loop Poisson
    generator thread per tenant) against a *running* server.

    ``make_input`` is either a zero-arg callable returning one input sample
    or a ``{tenant: callable}`` mapping.  Returns ``{tenant: LoadReport}``.
    """
    if not server.running:
        raise RuntimeError("server must be started before driving load")
    makers = (make_input if isinstance(make_input, dict)
              else {t: make_input for t in rates_rps})
    out: dict[str, LoadReport] = {}
    threads = []
    for i, (tenant, rate) in enumerate(sorted(rates_rps.items())):
        if rate <= 0:
            raise ValueError(f"offered rate for {tenant!r} must be > 0")
        rng = np.random.default_rng(seed + i)
        th = threading.Thread(
            target=_drive_one,
            args=(server, tenant, makers[tenant], float(rate),
                  float(duration_s), rng, float(result_timeout_s), out),
            name=f"loadgen-{tenant}", daemon=True)
        threads.append(th)
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return out


def saturation_throughput(server: Server, tenant: str, make_input, *,
                          n_requests: int = 128, repeats: int = 3,
                          result_timeout_s: float = 60.0) -> float:
    """Sustainable requests/s ceiling for one tenant: submit a closed burst
    of ``n_requests`` back-to-back (retrying the few the admission gate
    sheds, so exactly ``n_requests`` complete) and measure the drain rate;
    best of ``repeats`` damps warm-up and scheduler noise."""
    best = 0.0
    for _ in range(repeats):
        tickets = []
        t0 = time.perf_counter()
        submitted = 0
        while submitted < n_requests:
            try:
                tickets.append(server.submit(tenant, make_input()))
                submitted += 1
            except Overloaded:
                # closed burst: wait for the head ticket, then keep going
                if tickets:
                    tickets[0].result(timeout=result_timeout_s)
                else:
                    time.sleep(0.001)
        for t in tickets:
            t.result(timeout=result_timeout_s)
        wall = time.perf_counter() - t0
        best = max(best, n_requests / wall)
    return best
