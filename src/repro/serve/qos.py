"""Per-tenant QoS monitoring for the multi-tenant server.

``QosMonitor`` is the serving layer's single observability surface: every
admission, rejection, dispatch and completion event flows through it, and it
answers the two questions the rest of the subsystem asks —

* *admission control*: "how long will a request admitted now wait?" —
  answered from the rolling per-bucket engine dispatch latencies of each
  tenant's registered ``Session`` (:meth:`service_time_s` delegates to
  ``Session.dispatch_latency_s``).  The monitor does NOT keep a second
  dispatch-latency store: the session's ``RollingLatency`` windows — the
  ones ``SessionStats`` reports — are the single stats implementation
  shared between the session and the serving layer;
* *operators / the load generator*: rolling end-to-end p50/p99 latency,
  queue depth, throughput and accept/reject counters per tenant
  (:meth:`snapshot`).

The push-event design is grounded in sparse_framework's monitor plumbing
(``MonitorClient`` in SNIPPETS.md): serving nodes push lifecycle events into
a rolling store; reporters sample it without perturbing the hot path.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time

from ..api.session import RollingLatency


@dataclasses.dataclass(frozen=True)
class TenantQos:
    """One tenant's rolling QoS sample (NaN percentiles before traffic)."""

    tenant: str
    submitted: int                  # admission attempts seen
    accepted: int
    rejected: int                   # typed Overloaded rejections
    completed: int
    failed: int                     # tickets rejected by a raising dispatch
    queue_depth: int                # queued requests at sample time
    inflight: int                   # requests inside in-flight dispatches
    latency_p50_s: float            # end-to-end: admit -> fulfilled
    latency_p99_s: float
    throughput_rps: float           # completions / rolling-window span
    rejection_rate: float           # rejected / submitted

    def describe(self) -> str:
        return (f"{self.tenant}: p50={self.latency_p50_s * 1e3:.2f}ms "
                f"p99={self.latency_p99_s * 1e3:.2f}ms "
                f"{self.throughput_rps:.0f} req/s "
                f"depth={self.queue_depth} "
                f"acc={self.accepted} rej={self.rejected} "
                f"({self.rejection_rate:.1%})")


class _TenantTrack:
    __slots__ = ("latency", "completions", "submitted", "accepted",
                 "rejected", "completed", "failed")

    def __init__(self, window: int):
        self.latency = RollingLatency(window)
        # completion timestamps: throughput over the retained span
        self.completions = RollingLatency(window)
        self.submitted = 0
        self.accepted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0


class QosMonitor:
    """Rolling per-tenant QoS aggregation (thread-safe: submit threads and
    the scheduler thread push concurrently)."""

    def __init__(self, window: int = 1024, clock=time.monotonic):
        self.window = int(window)
        self._clock = clock
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantTrack] = {}
        # tenant -> Session whose rolling dispatch windows answer
        # service_time_s (one stats implementation, owned by the session)
        self._sessions: dict[str, object] = {}

    def _track(self, tenant: str) -> _TenantTrack:
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = _TenantTrack(self.window)
        return t

    def register_session(self, tenant: str, session) -> None:
        """Bind a tenant to the ``Session`` whose rolling per-bucket
        dispatch latencies back :meth:`service_time_s`."""
        with self._lock:
            self._sessions[tenant] = session

    # -- lifecycle events ---------------------------------------------------
    def on_submit(self, tenant: str) -> None:
        with self._lock:
            self._track(tenant).submitted += 1

    def on_admit(self, tenant: str) -> None:
        with self._lock:
            self._track(tenant).accepted += 1

    def on_reject(self, tenant: str) -> None:
        with self._lock:
            self._track(tenant).rejected += 1

    def on_complete(self, tenant: str, latency_s: float) -> None:
        self.on_complete_batch(tenant, (latency_s,))

    def on_complete_batch(self, tenant: str, latencies_s) -> None:
        """Record one dispatch's worth of completions in one pass (the
        scheduler completes per batch; per-request locking would tax the
        serving hot path)."""
        latencies_s = tuple(latencies_s)
        with self._lock:
            t = self._track(tenant)
            t.completed += len(latencies_s)
            t.latency.record_many(latencies_s)
            now = self._clock()
            t.completions.record_many(now for _ in latencies_s)

    def on_failure(self, tenant: str, n: int = 1) -> None:
        with self._lock:
            self._track(tenant).failed += n

    # -- queries ------------------------------------------------------------
    def service_time_s(self, tenant: str, bucket: int | None = None) -> float:
        """Rolling p50 engine wall per dispatched batch (NaN when cold —
        e.g. before the tenant's first served dispatch, when only the
        model-free queue-cap gate can hold).

        Prefers the requested bucket's window in the tenant session's
        rolling stats; falls back to the all-bucket window so admission
        control has an estimate as soon as ANY batch size has been measured.
        """
        with self._lock:
            session = self._sessions.get(tenant)
        if session is None:
            return float("nan")
        v = (session.dispatch_latency_s(bucket=int(bucket))
             if bucket is not None else float("nan"))
        if math.isnan(v):
            v = session.dispatch_latency_s()
        return v

    def snapshot(self, tenant: str, queue_depth: int = 0,
                 inflight: int = 0) -> TenantQos:
        with self._lock:
            t = self._track(tenant)
            span = 0.0
            if len(t.completions) >= 2:
                stamps = t.completions.values()
                span = stamps[-1] - stamps[0]
            return TenantQos(
                tenant=tenant,
                submitted=t.submitted,
                accepted=t.accepted,
                rejected=t.rejected,
                completed=t.completed,
                failed=t.failed,
                queue_depth=queue_depth,
                inflight=inflight,
                latency_p50_s=t.latency.percentile(50),
                latency_p99_s=t.latency.percentile(99),
                throughput_rps=((len(t.completions) - 1) / span
                                if span > 0 else 0.0),
                rejection_rate=(t.rejected / t.submitted
                                if t.submitted else 0.0))

    def tenants(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._tenants)
