"""Admission control: per-tenant SLOs enforced at submit time.

A multi-tenant server under open-loop load has exactly one sane failure
mode: *reject early and say why*.  Queues that grow without bound convert
overload into unbounded latency for every accepted request; admission
control instead keeps the accepted population's tail latency bounded by
shedding the excess with a typed :class:`Overloaded` response the client
can back off on.

Two independent gates, both per tenant:

* **queue-depth cap** (``SLO.queue_cap``) — a hard backstop that needs no
  latency model, so it also protects a cold tenant whose service time has
  not been measured yet;
* **SLO-aware shedding** — once the tenant's engine service time is known
  (rolling per-bucket dispatch p50 from :class:`~repro.serve.qos.QosMonitor`,
  i.e. the same windows ``SessionStats`` reports), the predicted queueing
  delay of a request admitted *now* is ``batches_ahead x batch_service_s``;
  when that exceeds the tenant's p99 target the request is rejected rather
  than admitted into a queue position that cannot meet its SLO.  Load is
  shed — never served by collapsing the queue or silently dropping queued
  work.
"""
from __future__ import annotations

import dataclasses
import math
import time

from .qos import QosMonitor


@dataclasses.dataclass(frozen=True)
class SLO:
    """One tenant's service-level objective.

    ``p99_target_s`` — the tail-latency budget the admission policy defends
    (predicted queueing delay above it rejects).  ``queue_cap`` — hard cap
    on queued requests (the model-free backstop).  Either can be disabled
    with ``None``/``inf``.
    """

    p99_target_s: float = 0.5
    queue_cap: int | None = 256

    def __post_init__(self):
        if self.p99_target_s is not None and self.p99_target_s <= 0:
            raise ValueError("p99_target_s must be positive (or None)")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1 (or None)")


class Overloaded(RuntimeError):
    """Typed load-shed response: the tenant cannot take this request now.

    Carries enough structure for a client to back off intelligently:
    which gate fired (``reason``: ``"queue_cap"``, ``"slo"`` —
    ``"shutdown"`` for requests rejected by a non-draining stop, or
    ``"rebalancing"`` when the elastic runtime sheds at its retry-queue
    cap during a topology transition), the queue state it saw, and the
    predicted delay vs the tenant's target.
    """

    def __init__(self, tenant: str, reason: str, *, queue_depth: int,
                 predicted_delay_s: float = float("nan"),
                 p99_target_s: float = float("nan")):
        self.tenant = tenant
        self.reason = reason
        self.queue_depth = queue_depth
        self.predicted_delay_s = predicted_delay_s
        self.p99_target_s = p99_target_s
        if reason == "queue_cap":
            detail = f"queue depth {queue_depth} at cap"
        elif reason == "slo":
            detail = (f"predicted queueing delay "
                      f"{predicted_delay_s * 1e3:.1f} ms exceeds p99 target "
                      f"{p99_target_s * 1e3:.1f} ms at depth {queue_depth}")
        else:
            detail = f"rejected at queue depth {queue_depth}"
        super().__init__(f"tenant {tenant!r} overloaded ({reason}): {detail}")


class AdmissionController:
    """Policy over the monitor's rolling service-time estimates.

    The percentile query behind :meth:`predicted_delay_s` walks a rolling
    window, which is too heavy to pay on *every* submit at serving rates —
    the estimate is cached per tenant for ``cache_ttl_s`` (service time
    drifts over seconds, submits arrive every few hundred microseconds).
    """

    def __init__(self, monitor: QosMonitor, *, cache_ttl_s: float = 0.05,
                 clock=time.monotonic):
        self.monitor = monitor
        self.cache_ttl_s = float(cache_ttl_s)
        self._clock = clock
        self._service_cache: dict[str, tuple[float, float]] = {}

    def _service_time_s(self, tenant: str, max_batch: int) -> float:
        now = self._clock()
        hit = self._service_cache.get(tenant)
        if hit is not None and now - hit[0] < self.cache_ttl_s:
            return hit[1]
        est = self.monitor.service_time_s(tenant, bucket=max_batch)
        self._service_cache[tenant] = (now, est)
        return est

    def predicted_delay_s(self, tenant: str, *, queue_depth: int,
                          inflight_batches: int, max_batch: int) -> float:
        """Expected wait before a request admitted now is *dispatched*:
        every batch ahead of it (in flight, plus full batches formable from
        the queue in front of it — the request itself rides in the next
        partial one, which costs it nothing) costs one rolling-p50 batch
        service time.  Zero on an idle tenant; NaN while the tenant is cold
        (no dispatch measured yet)."""
        service_s = self._service_time_s(tenant, max_batch)
        if math.isnan(service_s):
            return float("nan")
        batches_ahead = inflight_batches + queue_depth // max(1, max_batch)
        return batches_ahead * service_s

    def admit(self, tenant: str, slo: SLO, *, queue_depth: int,
              inflight_batches: int, max_batch: int) -> None:
        """Raise :class:`Overloaded` if this request must be shed; record
        the submit/admit/reject outcome on the monitor either way."""
        self.monitor.on_submit(tenant)
        if slo.queue_cap is not None and queue_depth >= slo.queue_cap:
            self.monitor.on_reject(tenant)
            raise Overloaded(tenant, "queue_cap", queue_depth=queue_depth,
                             p99_target_s=slo.p99_target_s or float("nan"))
        if slo.p99_target_s is not None:
            predicted = self.predicted_delay_s(
                tenant, queue_depth=queue_depth,
                inflight_batches=inflight_batches, max_batch=max_batch)
            if not math.isnan(predicted) and predicted > slo.p99_target_s:
                self.monitor.on_reject(tenant)
                raise Overloaded(tenant, "slo", queue_depth=queue_depth,
                                 predicted_delay_s=predicted,
                                 p99_target_s=slo.p99_target_s)
        self.monitor.on_admit(tenant)
