"""Multi-tenant serving layer above :class:`repro.api.Session`.

One :class:`Server` hosts several named tenants (several compiled plans, or
one model at several resolutions) over the shared cross-instance executable
cache, with continuous batching (a scheduler thread admits queued requests
into in-flight bucket dispatches — no ``flush()`` barriers), per-tenant SLO
admission control (typed :class:`Overloaded` shedding), rolling QoS
monitoring, and an open-loop Poisson load generator::

    from repro.serve import SLO, Server, run_open_loop

    server = Server()
    server.add_tenant("mnv2@112", plan_112, slo=SLO(p99_target_s=0.2))
    server.add_tenant("mnv2@96", plan_96, slo=SLO(p99_target_s=0.1))
    with server:
        reports = run_open_loop(server, {"mnv2@112": 200.0, "mnv2@96": 400.0},
                                make_input, duration_s=5.0)
"""
from .admission import SLO, AdmissionController, Overloaded
from .loadgen import LoadReport, run_open_loop, saturation_throughput
from .qos import QosMonitor, TenantQos
from .scheduler import EdfBatcher, QueuedRequest
from .server import Server

__all__ = [
    "AdmissionController",
    "EdfBatcher",
    "LoadReport",
    "Overloaded",
    "QosMonitor",
    "QueuedRequest",
    "SLO",
    "Server",
    "TenantQos",
    "run_open_loop",
    "saturation_throughput",
]
