"""Multi-tenant continuous-batching server over compiled split plans.

The ``Session`` facade serves one caller at a time: every batch needs a
client-driven ``flush()`` barrier, and every client owns a whole compiled
plan.  ``Server`` is the layer above it for the millions-of-users story —
one process hosts several named *tenants* (several compiled plans, or one
model at several resolutions), each wrapped in its own ``Session``, all
sharing the class-level cross-instance executable cache (tenants with
identical shard geometry never re-trace) and one scheduler:

* **continuous batching** — a single scheduler thread drains per-tenant
  FIFO queues, forming bucket-padded micro-batches from *whatever is
  queued* and admitting them into in-flight dispatch slots
  (``Session.dispatch_async``: jax dispatch is asynchronous, so while one
  bucket computes on the device the scheduler is already stacking/padding
  the next and fulfilling the previous — no ``flush()`` barrier anywhere,
  host work overlaps device work);
* **admission control** — per-tenant :class:`~repro.serve.admission.SLO`
  (queue-depth cap + predicted-queueing-delay shedding) enforced at
  ``submit()``, rejecting with a typed
  :class:`~repro.serve.admission.Overloaded` instead of queueing work that
  cannot meet its target;
* **QoS monitoring** — every lifecycle event lands in the shared
  :class:`~repro.serve.qos.QosMonitor` (rolling p50/p99, throughput,
  accept/reject counters), whose service-time model is the tenant
  session's own rolling dispatch stats.

Per-request results are bit-identical to ``Session.run`` on the same plan:
the engine is vmapped over the sample axis, so neither bucket padding nor
which requests share a micro-batch can influence a sample's output.

Failure isolation: a dispatch that raises rejects exactly the tickets that
rode in it (their ``result()`` re-raises) and the scheduler keeps serving —
one tenant's poisoned batch cannot take the server down.

Synchronous by design: clients are threads calling ``submit()`` and
blocking on tickets.  The asyncio distributed runtime (``repro.runtime``)
stays a per-plan execution backend underneath a ``Session``; this scheduler
is the seam where those backends plug in later.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

from ..api.plan import Plan
from ..api.session import Session, Ticket
from ..core.executor import CompiledSplitExecutor
from ..core.splitting import SplitPlan
from .admission import SLO, AdmissionController, Overloaded
from .qos import QosMonitor, TenantQos
from .scheduler import EdfBatcher, make_request


class _Tenant:
    __slots__ = ("name", "session", "slo", "queue")

    def __init__(self, name: str, session: Session, slo: SLO):
        self.name = name
        self.session = session
        self.slo = slo
        self.queue = collections.deque()


class Server:
    """Continuous-batching, SLO-guarded serving over named tenants.

    ``max_inflight`` is the dispatch pipeline depth: how many bucket
    dispatches may be in flight on the device before the scheduler blocks
    on the oldest (2 overlaps host batch-forming with device compute;
    1 degenerates to the barrier behaviour).

    Usage::

        server = Server()
        server.add_tenant("mnv2@112", plan, slo=SLO(p99_target_s=0.2))
        with server:                      # start()/stop(drain=True)
            ticket = server.submit("mnv2@112", x)   # may raise Overloaded
            y = ticket.result(timeout=5.0)
    """

    def __init__(self, *, max_inflight: int = 2, monitor_window: int = 1024,
                 batcher: EdfBatcher | None = None, clock=time.monotonic):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.max_inflight = int(max_inflight)
        self.monitor = QosMonitor(window=monitor_window, clock=clock)
        self.admission = AdmissionController(self.monitor)
        self.batcher = batcher or EdfBatcher()
        self._clock = clock
        self._tenants: dict[str, _Tenant] = {}
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._running = False
        self._draining = False
        self._inflight_batches = 0
        self._thread: threading.Thread | None = None

    # -- tenancy -------------------------------------------------------------
    def add_tenant(self, name: str, plan: Plan | SplitPlan | Session, *,
                   slo: SLO | None = None, warmup: bool = True,
                   **session_kwargs) -> Session:
        """Host a compiled plan under ``name``.

        ``plan`` may be a ready :class:`Session` or a ``Plan``/``SplitPlan``
        (compiled here with ``session_kwargs``).  ``warmup`` pre-compiles
        every bucket on the caller's thread so the scheduler never traces;
        identical shard geometry across tenants hits the shared
        cross-instance executable cache instead of re-tracing.
        """
        if self._thread is not None:
            raise RuntimeError("add_tenant before start(): tenancy is static")
        if name in self._tenants:
            raise ValueError(f"duplicate tenant {name!r}")
        session = (plan if isinstance(plan, Session)
                   else Session(plan, **session_kwargs))
        if warmup:
            session.warmup()
        self._tenants[name] = _Tenant(name, session, slo or SLO())
        self.monitor.register_session(name, session)
        return session

    def replan_tenant(self, name: str, plan: Plan | SplitPlan) -> None:
        """Swap a live tenant onto a new plan for the same model (elastic
        topology change under load).

        Runs under the scheduler lock, so the cutover is atomic with
        respect to batch formation: requests already queued dispatch under
        the new plan, and every unchanged shard geometry hits the shared
        cross-instance executable cache (``Session.replan`` re-traces only
        new bucket geometries).  A plan built for a different model is
        rejected before anything is touched.
        """
        with self._lock:
            tenant = self._tenant(name)
            tenant.session.replan(plan)
        if self._thread is None:
            # not started yet: warm on the caller's thread like add_tenant
            tenant.session.warmup()

    def session(self, tenant: str) -> Session:
        return self._tenant(tenant).session

    def _tenant(self, name: str) -> _Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r} "
                           f"(hosted: {sorted(self._tenants)})") from None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Server":
        with self._lock:
            if self._running:
                return self
            if not self._tenants:
                raise RuntimeError("start() with no tenants")
            self._running = True
            self._draining = False
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-scheduler", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float | None = None) -> None:
        """Stop the scheduler.  ``drain=True`` serves everything already
        admitted first; ``drain=False`` rejects queued requests with
        :class:`Overloaded` (reason ``"shutdown"``) so no ticket is ever
        stranded."""
        with self._lock:
            if not self._running and self._thread is None:
                return
            self._running = False
            self._draining = drain
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    @property
    def running(self) -> bool:
        return self._running

    # -- client surface ------------------------------------------------------
    def submit(self, tenant: str, x) -> Ticket:
        """Admit one request for ``tenant``; returns a detached
        :class:`Ticket` (``result(timeout=...)``).  Raises
        :class:`Overloaded` when admission control sheds the request and
        ``ValueError`` on a malformed input (checked before admission)."""
        t = self._tenant(tenant)
        x = t.session.check_input(x)
        with self._lock:
            if not self._running:
                raise RuntimeError("server is not running")
            self.admission.admit(
                tenant, t.slo, queue_depth=len(t.queue),
                inflight_batches=self._inflight_batches,
                max_batch=t.session.max_batch)
            req = make_request(x, tenant, self._clock(), t.slo)
            t.queue.append(req)
            self._work.notify()
        return req.ticket

    def run(self, tenant: str, x, timeout: float | None = None) -> np.ndarray:
        """Submit-and-wait convenience (one request, end to end)."""
        return self.submit(tenant, x).result(timeout=timeout)

    # -- observability -------------------------------------------------------
    def stats(self, tenant: str | None = None):
        """Rolling :class:`TenantQos` for one tenant, or ``{name: TenantQos}``
        for all."""
        if tenant is not None:
            t = self._tenant(tenant)
            return self.monitor.snapshot(tenant, queue_depth=len(t.queue),
                                         inflight=self._inflight_batches)
        return {name: self.stats(name) for name in self._tenants}

    def queue_depth(self, tenant: str) -> int:
        return len(self._tenant(tenant).queue)

    @staticmethod
    def cache_stats() -> dict:
        """Hit/miss counters of the cross-instance executable cache all
        tenants share (:class:`CompiledSplitExecutor`)."""
        return CompiledSplitExecutor.cache_stats()

    # -- scheduler loop ------------------------------------------------------
    def _has_queued(self) -> bool:
        return any(t.queue for t in self._tenants.values())

    def _form_batch(self, full_only: bool = False):
        """Under the lock: pick a tenant (EDF) and take its next micro-batch.

        ``full_only`` restricts candidates to tenants with a full
        ``max_batch`` queued — the scheduler's bucket-filling rule: partial
        (padded) buckets are dispatched only when the device would otherwise
        go idle, never while another dispatch is still in flight, so
        saturation throughput is not spent on padding.
        """
        queues = {n: t.queue for n, t in self._tenants.items()
                  if not full_only or len(t.queue) >= t.session.max_batch}
        name = self.batcher.select(queues)
        if name is None:
            return None
        t = self._tenants[name]
        reqs = self.batcher.take(t.queue, t.session.max_batch)
        self._inflight_batches += 1
        return t, reqs

    def _loop(self) -> None:
        inflight: collections.deque = collections.deque()
        while True:
            batch = None
            with self._lock:
                while self._running and not self._has_queued() and not inflight:
                    self._work.wait(0.1)
                if not self._has_queued() and not inflight:
                    if not self._running:
                        break
                    continue
                if (not self._running and not self._draining):
                    # reject everything still queued: no stranded tickets
                    for t in self._tenants.values():
                        while t.queue:
                            req = t.queue.popleft()
                            req.ticket._reject(Overloaded(
                                t.name, "shutdown",
                                queue_depth=len(t.queue)))
                    batch = None
                elif len(inflight) < self.max_inflight:
                    batch = self._form_batch(full_only=bool(inflight))
            if batch is not None:
                tenant, reqs = batch
                try:
                    xs = np.stack([r.x for r in reqs])
                    disp = tenant.session.dispatch_async(xs)
                except Exception as e:  # noqa: BLE001 — isolate the batch
                    self._fail_batch(tenant, reqs, e)
                    continue
                inflight.append((disp, reqs, tenant))
                if len(inflight) < self.max_inflight:
                    continue    # keep the device pipe full before blocking
            if inflight:
                self._complete(*inflight.popleft())
            elif batch is None:
                with self._lock:
                    if not self._running and not self._has_queued():
                        break

    def _fail_batch(self, tenant: _Tenant, reqs, error: BaseException) -> None:
        for r in reqs:
            r.ticket._reject(error)
        self.monitor.on_failure(tenant.name, len(reqs))
        with self._lock:
            self._inflight_batches -= 1
            self._work.notify()

    def _complete(self, disp, reqs, tenant: _Tenant) -> None:
        try:
            outs = disp.wait()
        except Exception as e:  # noqa: BLE001 — isolate the batch
            self._fail_batch(tenant, reqs, e)
            return
        now = self._clock()
        for r, y in zip(reqs, outs):
            r.ticket._fulfill(np.asarray(y))
        self.monitor.on_complete_batch(
            tenant.name, [now - r.t_arrival for r in reqs])
        with self._lock:
            self._inflight_batches -= 1
            self._work.notify()


__all__ = ["Server", "SLO", "Overloaded", "QosMonitor", "TenantQos"]
