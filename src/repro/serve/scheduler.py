"""Continuous-batching scheduler policy: which tenant dispatches next, and
with how many requests.

Tenants host *different compiled plans* (or the same model at different
resolutions), so requests from two tenants can never ride the same engine
dispatch — batching is always per tenant, and the scheduling question is
purely *which tenant's queue to drain next*.  The policy here is
earliest-deadline-first over queue heads: each queued request's deadline is
``arrival + p99_target``, and the tenant whose oldest request is closest to
(or furthest past) its deadline forms the next micro-batch.  With equal SLO
targets this degenerates to FCFS on arrival order, so no tenant can be
starved: its head request's deadline only gets older.

Batch formation is greedy up to the tenant session's ``max_batch``: under
saturation every dispatch is a full bucket (max throughput), under light
load a lone request dispatches immediately at bucket 1 (min latency) — the
continuous-batching tradeoff with no tuning knob.
"""
from __future__ import annotations

from ..api.session import Ticket
from .admission import SLO


class QueuedRequest:
    """One admitted request waiting for (or riding) a dispatch."""

    __slots__ = ("x", "ticket", "tenant", "t_arrival", "deadline")

    def __init__(self, x, tenant: str, t_arrival: float, deadline: float):
        self.x = x                  # validated (C, H, W) float32 sample
        self.ticket = Ticket()      # detached: fulfilled by the scheduler
        self.tenant = tenant
        self.t_arrival = t_arrival
        self.deadline = deadline


def make_request(x, tenant: str, t_arrival: float, slo: SLO) -> QueuedRequest:
    target = slo.p99_target_s if slo.p99_target_s is not None else float("inf")
    return QueuedRequest(x, tenant, t_arrival, t_arrival + target)


class EdfBatcher:
    """Earliest-deadline-first tenant selection + greedy batch formation.

    Operates on a ``{tenant: deque[QueuedRequest]}`` view owned (and locked)
    by the server — the batcher is pure policy and holds no state, so it can
    be swapped without touching queue plumbing.
    """

    def select(self, queues: dict[str, object]) -> str | None:
        """The tenant whose head-of-line request has the earliest deadline
        (None if every queue is empty)."""
        best, best_deadline = None, None
        for tenant, q in queues.items():
            if not q:
                continue
            d = q[0].deadline
            if best_deadline is None or d < best_deadline:
                best, best_deadline = tenant, d
        return best

    def take(self, queue, max_batch: int) -> list[QueuedRequest]:
        """Pop up to ``max_batch`` head requests (arrival order preserved:
        responses stay FIFO per tenant)."""
        n = min(len(queue), max_batch)
        return [queue.popleft() for _ in range(n)]
