"""Fine-grained splitting strategy (paper §IV.B, Algorithms 1 and 2) plus the
spatial patch mode (MCUNetV2-style, beyond the paper).

Three partitioning modes:

* ``mode="neuron"`` (default, the paper's Algorithms 1/2): output neurons of
  every layer are partitioned into contiguous flat-index ranges, one per
  worker, proportional to capability ratings.  For conv layers the flat order
  is CHW row-major, so a worker's range touches a channel span ``[c_lo,c_hi]``
  and the worker receives exactly the kernels ``W[c]`` for the channels it
  touches (Alg. 1 lines 6–10: kernel assignment + usage counting).  For
  linear layers each column of the weight matrix is one output neuron
  (Alg. 2), so the worker receives the columns in its range.

* ``mode="kernel"``: conv/dwconv ranges are snapped to whole-channel
  boundaries (the strict kernel-wise reading of Alg. 1 — no kernel is ever
  duplicated, at the cost of coarser load balance).  Linear layers split
  neuron-wise as in Alg. 2.

* ``mode="spatial"``: conv/dwconv layers are partitioned along the output
  *height* axis — each worker owns a contiguous band of output rows across
  **all** channels, receiving the band's receptive-field input window (band +
  halo rows) and holding the **full** layer weights.  Whole inverted-residual
  blocks (``fusion.group_blocks``) execute fused per band, so intermediate
  activations (e.g. MobileNetV2's 6x expanded hidden) exist only at band
  size.  This trades weight replication + halo recompute for a much smaller
  activation working set — the winning trade in early high-resolution /
  low-channel stages where routed input regions dominate per-worker peak RAM.
  Linear/avgpool layers fall back to their flat splits.

Beyond the three uniform modes, :func:`split_model_mixed` builds a
*heterogeneous* plan: a different mode (and optionally a different worker
subset) per fused block, so the early high-resolution stages can run spatial
while the late channel-heavy stages run kernel/neuron — the regime split
MCUNetV2 exploits.  The per-block assignment is searched by
:func:`repro.core.mixed.search_mixed_assignment`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import band_bounds
from .fusion import FusedBlock, group_blocks
from .reinterpret import LayerSpec, ReinterpretedModel, macs_for_positions

MODES = ("neuron", "kernel", "spatial")


@dataclasses.dataclass(frozen=True)
class WorkerShard:
    """One worker's share of one layer."""

    worker: int
    start: int                      # first assigned flat output index
    stop: int                       # one past last assigned flat output index
    # conv/dwconv: kernels (output channels) held locally, with usage counts
    # (Alg. 1 "increment usage count") — c -> number of assigned positions.
    kernel_usage: dict[int, int]
    # linear: columns held locally (== range(start, stop)); conv: channel span.
    weight_bytes: int               # fragment size at 1 byte/param (int8)

    @property
    def n_positions(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class SpatialShard(WorkerShard):
    """One worker's output-height band of one conv/dwconv layer
    (``mode="spatial"``).

    The worker computes output rows ``[row_lo, row_hi)`` of **every** channel
    and needs (unpadded) input rows ``[in_lo, in_hi)`` — its band's receptive
    field, i.e. band + halo rows, derived through the layer's row mapping.
    For layers inside a fused block the band includes the halo rows demanded
    by downstream stages, so ``n_positions`` over workers can exceed ``n_out``
    (halo recompute).  ``start``/``stop`` are unused (the band is not a
    contiguous CHW flat range); ``n_positions`` is overridden accordingly.
    """

    row_lo: int = 0                 # half-open output-row band
    row_hi: int = 0
    in_lo: int = 0                  # half-open unpadded input-row window
    in_hi: int = 0                  # (band + halo) routed/held by the worker
    out_channels: int = 0
    out_width: int = 0

    @property
    def n_positions(self) -> int:  # type: ignore[override]
        return (self.row_hi - self.row_lo) * self.out_width * self.out_channels

    @property
    def n_rows(self) -> int:
        return self.row_hi - self.row_lo

    @property
    def in_rows(self) -> int:
        """Height of the routed/held input window (band + halo)."""
        return max(self.in_hi - self.in_lo, 0)


@dataclasses.dataclass(frozen=True)
class LayerSplit:
    layer: LayerSpec
    shards: list[WorkerShard]
    mode: str = "neuron"            # "neuron" | "kernel" | "spatial"
    # Fused-block position (spatial mode): only the first layer of a block
    # downloads routed input and only the last uploads aggregated output;
    # interior activations stay worker-local at band size.
    block_first: bool = True
    block_last: bool = True

    def shard_of(self, worker: int) -> WorkerShard:
        return self.shards[worker]


def partition_bounds(total: int, ratings: np.ndarray) -> np.ndarray:
    """Contiguous partition of ``range(total)`` proportional to ratings.

    Returns ``bounds`` of length N+1 with bounds[0]=0, bounds[-1]=total.
    Uses cumulative rounding so the shares are within 1 of the exact
    proportional amount and the partition is exact (no gaps/overlap) — the
    paper's ``while i - s < n`` loop with the remainder landing on the last
    worker, made deterministic.  One rounding rule for every axis:
    delegates to :func:`allocation.band_bounds`, so flat neuron/kernel
    ranges and spatial row bands can never diverge.
    """
    return band_bounds(ratings, total)


def split_conv_layer(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    """Algorithm 1: split a conv/dwconv layer across workers kernel-wise."""
    if layer.kind not in ("conv", "dwconv"):
        raise ValueError(f"not a conv layer: {layer.kind}")
    c, h, w = layer.out_shape
    hw = h * w
    bounds = partition_bounds(c * hw, ratings)
    per_kernel_params = int(np.prod(layer.weight.shape[1:])) if layer.weight is not None else 0
    shards = []
    for r in range(len(ratings)):
        s, e = int(bounds[r]), int(bounds[r + 1])
        usage: dict[int, int] = {}
        if e > s:
            c_lo, c_hi = s // hw, (e - 1) // hw
            for c1 in range(c_lo, c_hi + 1):
                # positions of channel c1 inside [s, e)
                lo = max(s, c1 * hw)
                hi = min(e, (c1 + 1) * hw)
                usage[c1] = hi - lo
        wbytes = len(usage) * per_kernel_params + len(usage)  # + per-channel bias
        shards.append(WorkerShard(r, s, e, usage, wbytes))
    return LayerSplit(layer, shards)


def split_linear_layer(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    """Algorithm 2: split a linear layer across workers column-wise."""
    if layer.kind != "linear":
        raise ValueError(f"not a linear layer: {layer.kind}")
    h_in = layer.in_shape[0]
    w_out = layer.out_shape[0]
    bounds = partition_bounds(w_out, ratings)
    shards = []
    for r in range(len(ratings)):
        s, e = int(bounds[r]), int(bounds[r + 1])
        usage = {j: 1 for j in range(s, e)}  # one column per output neuron
        wbytes = (e - s) * h_in + (e - s)
        shards.append(WorkerShard(r, s, e, usage, wbytes))
    return LayerSplit(layer, shards)


def split_conv_layer_kernel(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    """Strict kernel-wise split: contiguous *whole-channel* spans per worker
    (Alg. 1 without mid-channel boundaries — no kernel duplication)."""
    if layer.kind not in ("conv", "dwconv"):
        raise ValueError(f"not a conv layer: {layer.kind}")
    c, h, w = layer.out_shape
    hw = h * w
    c_bounds = partition_bounds(c, ratings)
    per_kernel_params = int(np.prod(layer.weight.shape[1:])) if layer.weight is not None else 0
    shards = []
    for r in range(len(ratings)):
        c_s, c_e = int(c_bounds[r]), int(c_bounds[r + 1])
        usage = {c1: hw for c1 in range(c_s, c_e)}
        wbytes = len(usage) * per_kernel_params + len(usage)
        shards.append(WorkerShard(r, c_s * hw, c_e * hw, usage, wbytes))
    return LayerSplit(layer, shards, mode="kernel")


def split_layer(layer: LayerSpec, ratings: np.ndarray,
                mode: str = "neuron") -> LayerSplit:
    if layer.kind in ("conv", "dwconv"):
        if mode == "kernel":
            return split_conv_layer_kernel(layer, ratings)
        return split_conv_layer(layer, ratings)
    if layer.kind == "linear":
        return split_linear_layer(layer, ratings)
    # avgpool & friends stay coordinator-side: zero-weight single "shard".
    shards = [WorkerShard(r, 0, 0, {}, 0) for r in range(len(ratings))]
    return LayerSplit(layer, shards)


def split_block_spatial(layers: list[LayerSpec],
                        ratings: np.ndarray) -> list[LayerSplit]:
    """Spatial split of one fused block (or singleton conv layer).

    The *block output* height is banded proportionally to ratings
    (``allocation.band_bounds``); each layer's per-worker band is then derived
    backwards through the block with the receptive-field row mapping
    (``LayerSpec.input_rows_for_output_rows``), so interior stages compute the
    halo rows their consumers need and the block-input window is exactly the
    band's receptive field (band + halo).
    """
    last = layers[-1]
    if any(lyr.kind not in ("conv", "dwconv") for lyr in layers):
        raise ValueError("spatial blocks must contain only conv/dwconv layers")
    n = len(ratings)
    h_out = last.out_shape[1]
    bounds = band_bounds(np.asarray(ratings, dtype=np.float64), h_out)
    # per layer, per worker: (row_lo, row_hi, in_lo, in_hi)
    bands: list[list[tuple[int, int, int, int]]] = [
        [None] * n for _ in layers]  # type: ignore[list-item]
    for w in range(n):
        r_lo, r_hi = int(bounds[w]), int(bounds[w + 1])
        for li in reversed(range(len(layers))):
            lyr = layers[li]
            if r_hi > r_lo:
                in_lo, in_hi = lyr.input_rows_for_output_rows(r_lo, r_hi - 1)
            else:
                in_lo = in_hi = 0
            bands[li][w] = (r_lo, r_hi, in_lo, in_hi)
            # the upstream stage must produce this stage's input window
            r_lo, r_hi = in_lo, in_hi
    splits: list[LayerSplit] = []
    for li, lyr in enumerate(layers):
        c_out, _, w_out = lyr.out_shape
        per_kernel_params = int(np.prod(lyr.weight.shape[1:])) if lyr.weight is not None else 0
        shards: list[WorkerShard] = []
        for w in range(n):
            r_lo, r_hi, in_lo, in_hi = bands[li][w]
            band_pos = (r_hi - r_lo) * w_out
            if band_pos > 0:
                usage = {c1: band_pos for c1 in range(c_out)}
                # full weights + per-channel bias replicated on active workers
                wbytes = c_out * per_kernel_params + c_out
            else:
                usage, wbytes = {}, 0
            shards.append(SpatialShard(w, 0, 0, usage, wbytes,
                                       row_lo=r_lo, row_hi=r_hi,
                                       in_lo=in_lo, in_hi=in_hi,
                                       out_channels=c_out, out_width=w_out))
        splits.append(LayerSplit(lyr, shards, mode="spatial",
                                 block_first=(li == 0),
                                 block_last=(li == len(layers) - 1)))
    return splits


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Static output/input geometry of one conv/dwconv shard, precomputed
    host-side so a traced executor contains no geometry arithmetic.

    All fields are plain Python ints / numpy arrays fixed at plan-compile
    time (the flat ranges are data-independent): the channel span the worker
    holds kernels for, the output-row interval it produces, the padded-input
    row window the coordinator routes to it, and the flat map from its global
    output range ``[start, stop)`` into its computed bounding box.

    Because shards are contiguous ascending flat ranges and the bbox spans
    full rows whenever the shard crosses a channel boundary, ``bbox_index``
    is always a contiguous run — ``bbox_start`` exposes it as a plain slice
    offset so the hot path is a static slice, not a gather.  The index map is
    kept (and property-tested) because it is the general contract.
    """

    worker: int
    start: int                      # global flat output range [start, stop)
    stop: int
    c_lo: int                       # inclusive channel span of the fragment
    c_hi: int
    row_lo: int                     # inclusive output-row interval computed
    row_hi: int
    in_r0: int                      # padded-input row window routed to the
    in_r1: int                      # worker (half-open)
    bbox_index: np.ndarray          # int64 (n_positions,) map into bbox flat

    @property
    def n_positions(self) -> int:
        return self.stop - self.start

    @property
    def n_channels(self) -> int:
        return self.c_hi - self.c_lo + 1

    @property
    def n_rows(self) -> int:
        return self.row_hi - self.row_lo + 1

    @property
    def bbox_start(self) -> int:
        """Offset of ``start`` inside the shard's bbox flat buffer (the
        contiguous-slice fast path; see class docstring)."""
        return int(self.bbox_index[0]) if self.n_positions else 0


@dataclasses.dataclass(frozen=True)
class SpatialBandGeometry:
    """Static band geometry of one spatial shard stage, precomputed host-side
    (the spatial counterpart of :class:`ShardGeometry`): the output-row band,
    the unpadded input-row window routed to / held by the worker (band +
    halo), and the explicit zero-padding rows to apply above/below the window
    so a VALID conv over ``pad(window)`` yields exactly rows
    ``[row_lo, row_hi)``.  Interior bands get halo rows instead of padding;
    bands touching the tensor edge get real zeros — both are plain Python
    ints, so the traced executors contain only static slices.
    """

    worker: int
    row_lo: int                     # half-open output-row band
    row_hi: int
    in_lo: int                      # half-open unpadded input-row window
    in_hi: int
    pad_top: int                    # zero rows above/below the window
    pad_bot: int

    @property
    def n_rows(self) -> int:
        return self.row_hi - self.row_lo


def spatial_band_geometry(layer: LayerSpec,
                          split: LayerSplit) -> list[SpatialBandGeometry | None]:
    """Per-worker :class:`SpatialBandGeometry` for one spatial LayerSplit
    (``None`` for empty bands)."""
    kh, _ = layer.kernel
    sh, _ = layer.stride
    ph, _ = layer.padding
    out: list[SpatialBandGeometry | None] = []
    for shard in split.shards:
        if not isinstance(shard, SpatialShard):
            raise ValueError("spatial_band_geometry needs SpatialShards")
        if shard.row_hi <= shard.row_lo:
            out.append(None)
            continue
        # padded-input window of the band: [row_lo*sh, (row_hi-1)*sh + kh)
        win0 = shard.row_lo * sh
        win_len = (shard.row_hi - 1 - shard.row_lo) * sh + kh
        pad_top = max(0, ph - win0)
        pad_bot = win_len - pad_top - (shard.in_hi - shard.in_lo)
        assert pad_bot >= 0, "band window shorter than its padded extent"
        out.append(SpatialBandGeometry(shard.worker, shard.row_lo,
                                       shard.row_hi, shard.in_lo, shard.in_hi,
                                       pad_top, pad_bot))
    return out


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Full-model split: per-layer shards + per-worker totals.

    ``blocks`` holds the fused execution groups (tuples of layer indices) the
    executors iterate over — singletons except for spatial(-assigned) fused
    blocks, which run fused per band.

    ``mode`` is one of the uniform modes, or ``"mixed"`` for a heterogeneous
    plan built by :func:`split_model_mixed`.  Mixed plans additionally carry
    ``assignment`` — the per-fused-block mode vector over
    ``fusion.group_blocks(model)``, the canonical serialized form — and
    ``block_modes``, the effective mode of each entry of ``blocks`` (spatial
    assignments over non-conv blocks fall back to ``"neuron"`` there).
    """

    model: ReinterpretedModel
    splits: list[LayerSplit]
    ratings: np.ndarray
    mode: str = "neuron"
    blocks: tuple[tuple[int, ...], ...] | None = None
    # mixed plans only: per-group_blocks-block requested mode, and the
    # effective mode of each executor group in ``blocks``
    assignment: tuple[str, ...] | None = None
    block_modes: tuple[str, ...] | None = None

    @property
    def n_workers(self) -> int:
        return len(self.ratings)

    @property
    def block_groups(self) -> tuple[tuple[int, ...], ...]:
        if self.blocks is not None:
            return self.blocks
        return tuple((i,) for i in range(len(self.splits)))

    @property
    def group_modes(self) -> tuple[str, ...]:
        """Effective mode of every entry of :attr:`block_groups` (uniform
        plans report their single mode everywhere)."""
        if self.block_modes is not None:
            return self.block_modes
        return tuple(self.splits[g[0]].mode for g in self.block_groups)

    @property
    def is_mixed(self) -> bool:
        return self.mode == "mixed"

    def worker_weight_bytes(self, worker: int) -> int:
        return sum(sp.shard_of(worker).weight_bytes for sp in self.splits)

    def worker_macs(self, worker: int) -> int:
        return sum(
            macs_for_positions(sp.layer, sp.shard_of(worker).n_positions)
            for sp in self.splits)


def split_model(model: ReinterpretedModel, ratings,
                mode: str = "neuron", fused: bool = True) -> SplitPlan:
    """Split every layer with the same ratings vector (paper reuses R across
    layers; per-layer ratings are supported by calling split_layer directly).

    ``mode``: ``"neuron"`` (default, Alg. 1/2 flat ranges), ``"kernel"``
    (whole-channel conv spans), or ``"spatial"`` (output-height bands + fused
    blocks; see module docstring).

    ``fused`` (spatial only): ``True`` bands whole inverted-residual blocks
    (``fusion.group_blocks`` — interior activations stay at band size);
    ``False`` bands every layer independently (singleton blocks: no
    interior-halo recompute, more boundary traffic).  Ignored for the flat
    modes, which have a single granularity.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r} (want one of {MODES})")
    ratings = np.asarray(ratings, dtype=np.float64)
    if mode != "spatial":
        splits = [split_layer(lyr, ratings, mode) for lyr in model.layers]
        return SplitPlan(model=model, splits=splits, ratings=ratings, mode=mode)
    grouping = (group_blocks(model) if fused
                else [FusedBlock((i,)) for i in range(len(model.layers))])
    splits_by_idx: dict[int, LayerSplit] = {}
    blocks: list[tuple[int, ...]] = []
    for block in grouping:
        layers = [model.layers[i] for i in block.indices]
        if all(lyr.kind in ("conv", "dwconv") for lyr in layers):
            for idx, sp in zip(block.indices, split_block_spatial(layers, ratings)):
                splits_by_idx[idx] = sp
            blocks.append(tuple(block.indices))
        else:
            # linear / avgpool: spatial banding does not apply — flat split,
            # one singleton block per layer.
            for idx in block.indices:
                splits_by_idx[idx] = split_layer(model.layers[idx], ratings)
                blocks.append((idx,))
    splits = [splits_by_idx[i] for i in range(len(model.layers))]
    return SplitPlan(model=model, splits=splits, ratings=ratings,
                     mode="spatial", blocks=tuple(blocks))


def _masked_ratings(ratings: np.ndarray,
                    workers: tuple[int, ...] | None) -> np.ndarray:
    """Zero out every rating outside ``workers`` (None keeps all).  The
    excluded workers receive empty shards everywhere in the block — the
    per-block worker-subset mechanism of mixed plans."""
    if workers is None:
        return ratings
    mask = np.zeros_like(ratings)
    for w in workers:
        if not 0 <= int(w) < len(ratings):
            raise ValueError(f"worker index {w} outside cluster of "
                             f"{len(ratings)} workers")
        mask[int(w)] = ratings[int(w)]
    if mask.sum() <= 0:
        raise ValueError("block worker subset has no positive rating")
    return mask


def split_model_mixed(model: ReinterpretedModel, ratings,
                      assignment,
                      block_workers=None) -> SplitPlan:
    """Heterogeneous split: a different partitioning mode per fused block.

    ``assignment`` is a sequence of modes (one of :data:`MODES`), one per
    fused block of ``fusion.group_blocks(model)``.  A block assigned
    ``"spatial"`` runs fused per output-row band (as in
    ``split_model(mode="spatial")``); blocks assigned a flat mode execute
    layer-by-layer like the uniform flat plans.  A ``"spatial"`` assignment
    over a block containing non-conv layers falls back to the flat neuron
    split, exactly like the uniform spatial constructor — the *effective*
    per-group modes are recorded in ``SplitPlan.block_modes``.

    ``block_workers`` (optional) gives each block its own worker subset: a
    sequence aligned with ``assignment`` whose entries are iterables of
    worker indices (or ``None`` for all workers).  Excluded workers receive
    empty shards for the block's layers; every split still spans the full
    cluster width, so cross-boundary accounting (``mapping.comm_volume``,
    ``memory.plan_memory``) indexes consistently even when adjacent blocks
    use different subsets.

    The resulting plan has ``mode="mixed"`` and both executors run it
    directly — each block group dispatches on its own split mode, and int8
    execution stays bit-exact across every mode seam (tested in
    ``tests/test_mixed.py``).
    """
    ratings = np.asarray(ratings, dtype=np.float64)
    grouping = group_blocks(model)
    assignment = tuple(assignment)
    if len(assignment) != len(grouping):
        raise ValueError(
            f"assignment length {len(assignment)} != {len(grouping)} fused "
            f"blocks (group_blocks granularity)")
    for m in assignment:
        if m not in MODES:
            raise ValueError(f"unknown mode {m!r} (want one of {MODES})")
    if block_workers is None:
        block_workers = [None] * len(grouping)
    block_workers = list(block_workers)
    if len(block_workers) != len(grouping):
        raise ValueError(
            f"block_workers length {len(block_workers)} != "
            f"{len(grouping)} fused blocks")
    splits_by_idx: dict[int, LayerSplit] = {}
    blocks: list[tuple[int, ...]] = []
    block_modes: list[str] = []
    for block, mode, subset in zip(grouping, assignment, block_workers):
        sub = None if subset is None else tuple(int(w) for w in subset)
        r_b = _masked_ratings(ratings, sub)
        layers = [model.layers[i] for i in block.indices]
        if (mode == "spatial"
                and all(lyr.kind in ("conv", "dwconv") for lyr in layers)):
            for idx, sp in zip(block.indices,
                               split_block_spatial(layers, r_b)):
                splits_by_idx[idx] = sp
            blocks.append(tuple(block.indices))
            block_modes.append("spatial")
        else:
            eff = mode if mode != "spatial" else "neuron"
            for idx in block.indices:
                splits_by_idx[idx] = split_layer(model.layers[idx], r_b, eff)
                blocks.append((idx,))
                block_modes.append(eff)
    splits = [splits_by_idx[i] for i in range(len(model.layers))]
    return SplitPlan(model=model, splits=splits, ratings=ratings,
                     mode="mixed", blocks=tuple(blocks),
                     assignment=assignment, block_modes=tuple(block_modes))
