"""Fine-grained splitting strategy (paper §IV.B, Algorithms 1 and 2).

Output neurons of every layer are partitioned into contiguous flat-index
ranges, one per worker, proportional to capability ratings.  For conv layers
the flat order is CHW row-major, so a worker's range touches a channel span
``[c_lo, c_hi]`` and the worker receives exactly the kernels ``W[c]`` for the
channels it touches (Alg. 1 lines 6–10: kernel assignment + usage counting).
For linear layers each column of the weight matrix is one output neuron
(Alg. 2), so the worker receives the columns in its range.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .reinterpret import LayerSpec, ReinterpretedModel, macs_for_positions


@dataclasses.dataclass(frozen=True)
class WorkerShard:
    """One worker's share of one layer."""

    worker: int
    start: int                      # first assigned flat output index
    stop: int                       # one past last assigned flat output index
    # conv/dwconv: kernels (output channels) held locally, with usage counts
    # (Alg. 1 "increment usage count") — c -> number of assigned positions.
    kernel_usage: dict[int, int]
    # linear: columns held locally (== range(start, stop)); conv: channel span.
    weight_bytes: int               # fragment size at 1 byte/param (int8)

    @property
    def n_positions(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class LayerSplit:
    layer: LayerSpec
    shards: list[WorkerShard]

    def shard_of(self, worker: int) -> WorkerShard:
        return self.shards[worker]


def partition_bounds(total: int, ratings: np.ndarray) -> np.ndarray:
    """Contiguous partition of ``range(total)`` proportional to ratings.

    Returns ``bounds`` of length N+1 with bounds[0]=0, bounds[-1]=total.
    Uses cumulative rounding so the shares are within 1 of the exact
    proportional amount and the partition is exact (no gaps/overlap) — the
    paper's ``while i - s < n`` loop with the remainder landing on the last
    worker, made deterministic.
    """
    ratings = np.asarray(ratings, dtype=np.float64)
    if np.any(ratings < 0):
        raise ValueError("ratings must be non-negative")
    s = ratings.sum()
    if s <= 0:
        raise ValueError("at least one rating must be positive")
    cum = np.cumsum(ratings) / s
    bounds = np.round(cum * total).astype(np.int64)
    bounds = np.concatenate([[0], bounds])
    bounds[-1] = total  # guard rounding
    # enforce monotonicity (rounding can momentarily tie)
    bounds = np.maximum.accumulate(bounds)
    return bounds


def split_conv_layer(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    """Algorithm 1: split a conv/dwconv layer across workers kernel-wise."""
    if layer.kind not in ("conv", "dwconv"):
        raise ValueError(f"not a conv layer: {layer.kind}")
    c, h, w = layer.out_shape
    hw = h * w
    bounds = partition_bounds(c * hw, ratings)
    per_kernel_params = int(np.prod(layer.weight.shape[1:])) if layer.weight is not None else 0
    shards = []
    for r in range(len(ratings)):
        s, e = int(bounds[r]), int(bounds[r + 1])
        usage: dict[int, int] = {}
        if e > s:
            c_lo, c_hi = s // hw, (e - 1) // hw
            for c1 in range(c_lo, c_hi + 1):
                # positions of channel c1 inside [s, e)
                lo = max(s, c1 * hw)
                hi = min(e, (c1 + 1) * hw)
                usage[c1] = hi - lo
        wbytes = len(usage) * per_kernel_params + len(usage)  # + per-channel bias
        shards.append(WorkerShard(r, s, e, usage, wbytes))
    return LayerSplit(layer, shards)


def split_linear_layer(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    """Algorithm 2: split a linear layer across workers column-wise."""
    if layer.kind != "linear":
        raise ValueError(f"not a linear layer: {layer.kind}")
    h_in = layer.in_shape[0]
    w_out = layer.out_shape[0]
    bounds = partition_bounds(w_out, ratings)
    shards = []
    for r in range(len(ratings)):
        s, e = int(bounds[r]), int(bounds[r + 1])
        usage = {j: 1 for j in range(s, e)}  # one column per output neuron
        wbytes = (e - s) * h_in + (e - s)
        shards.append(WorkerShard(r, s, e, usage, wbytes))
    return LayerSplit(layer, shards)


def split_layer(layer: LayerSpec, ratings: np.ndarray) -> LayerSplit:
    if layer.kind in ("conv", "dwconv"):
        return split_conv_layer(layer, ratings)
    if layer.kind == "linear":
        return split_linear_layer(layer, ratings)
    # avgpool & friends stay coordinator-side: zero-weight single "shard".
    n = layer.n_out
    shards = [WorkerShard(r, 0, 0, {}, 0) for r in range(len(ratings))]
    return LayerSplit(layer, shards)


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Static output/input geometry of one conv/dwconv shard, precomputed
    host-side so a traced executor contains no geometry arithmetic.

    All fields are plain Python ints / numpy arrays fixed at plan-compile
    time (the flat ranges are data-independent): the channel span the worker
    holds kernels for, the output-row interval it produces, the padded-input
    row window the coordinator routes to it, and the flat map from its global
    output range ``[start, stop)`` into its computed bounding box.

    Because shards are contiguous ascending flat ranges and the bbox spans
    full rows whenever the shard crosses a channel boundary, ``bbox_index``
    is always a contiguous run — ``bbox_start`` exposes it as a plain slice
    offset so the hot path is a static slice, not a gather.  The index map is
    kept (and property-tested) because it is the general contract.
    """

    worker: int
    start: int                      # global flat output range [start, stop)
    stop: int
    c_lo: int                       # inclusive channel span of the fragment
    c_hi: int
    row_lo: int                     # inclusive output-row interval computed
    row_hi: int
    in_r0: int                      # padded-input row window routed to the
    in_r1: int                      # worker (half-open)
    bbox_index: np.ndarray          # int64 (n_positions,) map into bbox flat

    @property
    def n_positions(self) -> int:
        return self.stop - self.start

    @property
    def n_channels(self) -> int:
        return self.c_hi - self.c_lo + 1

    @property
    def n_rows(self) -> int:
        return self.row_hi - self.row_lo + 1

    @property
    def bbox_start(self) -> int:
        """Offset of ``start`` inside the shard's bbox flat buffer (the
        contiguous-slice fast path; see class docstring)."""
        return int(self.bbox_index[0]) if self.n_positions else 0


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Full-model split: per-layer shards + per-worker totals."""

    model: ReinterpretedModel
    splits: list[LayerSplit]
    ratings: np.ndarray

    @property
    def n_workers(self) -> int:
        return len(self.ratings)

    def worker_weight_bytes(self, worker: int) -> int:
        return sum(sp.shard_of(worker).weight_bytes for sp in self.splits)

    def worker_macs(self, worker: int) -> int:
        return sum(
            macs_for_positions(sp.layer, sp.shard_of(worker).n_positions)
            for sp in self.splits)


def split_model(model: ReinterpretedModel, ratings) -> SplitPlan:
    """Split every layer with the same ratings vector (paper reuses R across
    layers; per-layer ratings are supported by calling split_layer directly)."""
    ratings = np.asarray(ratings, dtype=np.float64)
    splits = [split_layer(l, ratings) for l in model.layers]
    return SplitPlan(model=model, splits=splits, ratings=ratings)
