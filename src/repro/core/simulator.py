"""Networked-MCU simulator (paper §VII.D): one coordinator + N workers with
the same partitioning and communication logic as the testbed, scaled to 120
workers.

Timing model (paper Eq. 1):
    t_w = W_w / f_w + (d_w + 1/B_w) * f(W_w)
with the compute term refined by a frequency-independent flash-access
component that reproduces Table I's observation that K1 *rises* as the clock
drops (memory-bound fraction grows with f):

    cycles(macs, f) = macs * (CPM + FLASH_NS * f_mhz / 1000)

Communication volumes are not modeled with Eq. 2's linear f(W)=K1*Kc*W
approximation — they are *derived exactly* from the cross-layer activation
mapping (RouteM): per layer, each worker downloads its input region bytes
(duplication across overlapping receptive fields included) and uploads its
assigned outputs.  Eq. 2's Kc then falls out of the simulation
(Kc = comm_bytes / out_bytes per unit workload) instead of being assumed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import WorkerParams
from .mapping import comm_volume
from .memory import layerwise_peak
from .reinterpret import ReinterpretedModel, macs_for_positions
from .splitting import SplitPlan, split_model


@dataclasses.dataclass
class SimConfig:
    cycles_per_mac: float = 73.0      # CPM, calibrated vs Table I/II (bench)
    flash_ns_per_mac: float = 118.0   # frequency-independent weight-fetch ns
    itemsize: int = 1                 # int8 activations on the wire
    overlap: bool = True              # §V.D eager partial-result streaming
    coordinator_bw_kb_s: float = 115000.0  # PC side (GbE) — rarely binding


@dataclasses.dataclass
class SimResult:
    layer_comp: np.ndarray      # (L,) per-layer compute critical path (s)
    layer_comm: np.ndarray      # (L,) per-layer communication critical path (s)
    layer_bytes: np.ndarray     # (L,) total bytes moved at this boundary
    per_worker_comp: np.ndarray  # (L, N) compute seconds
    per_worker_comm: np.ndarray  # (L, N)
    peak_ram: np.ndarray        # (L, N) bytes

    @property
    def layer_total(self) -> np.ndarray:
        return self.layer_comp + self.layer_comm

    @property
    def total_time(self) -> float:
        return float(self.layer_total.sum())

    @property
    def comp_time(self) -> float:
        return float(self.layer_comp.sum())

    @property
    def comm_time(self) -> float:
        return float(self.layer_comm.sum())

    @property
    def total_bytes(self) -> int:
        return int(self.layer_bytes.sum())


def _comp_seconds(macs: np.ndarray, f_mhz: np.ndarray, cfg: SimConfig) -> np.ndarray:
    cycles = macs * (cfg.cycles_per_mac + cfg.flash_ns_per_mac * f_mhz / 1000.0)
    return cycles / (f_mhz * 1e6)


def simulate(model: ReinterpretedModel, workers: list[WorkerParams],
             ratings: np.ndarray | None = None,
             cfg: SimConfig | None = None,
             plan: SplitPlan | None = None) -> SimResult:
    """Run one end-to-end inference through the timing model.

    ``ratings`` defaults to uniform; ``plan`` may be passed to reuse a split.
    """
    cfg = cfg or SimConfig()
    n = len(workers)
    if ratings is None:
        ratings = np.ones(n)
    if plan is None:
        plan = split_model(model, ratings)
    f_mhz = np.array([p.f_mhz for p in workers])
    d = np.array([p.d_s_per_kb for p in workers])
    inv_b = np.array([1.0 / p.b_kb_s for p in workers])

    L = len(model.layers)
    comp = np.zeros((L, n))
    comm = np.zeros((L, n))
    nbytes = np.zeros(L)
    per_layer_total = np.zeros(L)
    layer_comp_arr = np.zeros(L)
    prev_split = None
    for li, split in enumerate(plan.splits):
        layer = split.layer
        macs = np.array([macs_for_positions(layer, split.shard_of(w).n_positions)
                         for w in range(n)], dtype=np.float64)
        comp[li] = _comp_seconds(macs, f_mhz, cfg)
        vol = comm_volume(prev_split, layer, split, itemsize=cfg.itemsize)
        down_kb = vol.download_bytes / 1024.0
        up_kb = vol.upload_bytes / 1024.0
        # per-worker link time (Eq. 1's communication term, exact bytes)
        t_down = (d + inv_b) * down_kb
        t_up = (d + inv_b) * up_kb
        comm[li] = t_down + t_up
        nbytes[li] = vol.total_bytes
        prev_split = split
        # all traffic flows through the coordinator (§VI.B), which serializes
        # sends/receives — the reason communication grows with N (Fig. 9/10)
        t_down_serial = t_down.sum()
        t_up_serial = t_up.sum()
        max_comp = comp[li].max()
        if cfg.overlap:
            # eager partial results (§V.D): uploads stream while other
            # workers still compute
            totals = t_down_serial + np.maximum(max_comp, t_up_serial)
        else:
            totals = t_down_serial + max_comp + t_up_serial
        per_layer_total[li] = totals
        layer_comp_arr[li] = max_comp

    layer_comp = layer_comp_arr
    layer_comm = per_layer_total - layer_comp
    return SimResult(layer_comp=layer_comp, layer_comm=layer_comm,
                     layer_bytes=nbytes, per_worker_comp=comp,
                     per_worker_comm=comm,
                     peak_ram=layerwise_peak(plan, itemsize=cfg.itemsize))


@dataclasses.dataclass(frozen=True)
class ModeReport:
    """One partitioning mode's simulated cost profile (compare_modes)."""

    mode: str
    total_time_s: float
    comp_time_s: float
    comm_time_s: float
    total_bytes: int
    max_peak_ram: int        # max over layers x workers (Fig. 12's metric)
    max_weight_bytes: int    # largest per-worker weight footprint


def compare_modes(model: ReinterpretedModel, workers: list[WorkerParams],
                  ratings: np.ndarray | None = None,
                  cfg: SimConfig | None = None,
                  modes: tuple[str, ...] = ("neuron", "kernel", "spatial"),
                  ) -> dict[str, ModeReport]:
    """Simulate the same deployment under each partitioning mode — the
    comm/peak-RAM tradeoff report: spatial trades weight replication + halo
    recompute for a smaller activation working set and less routed traffic in
    the early high-resolution stages; the channel/neuron modes split weights
    but route overlapping input regions to every worker."""
    out: dict[str, ModeReport] = {}
    for mode in modes:
        plan = split_model(model, ratings if ratings is not None
                           else np.ones(len(workers)), mode=mode)
        res = simulate(model, workers, ratings, cfg, plan=plan)
        out[mode] = ModeReport(
            mode=mode,
            total_time_s=res.total_time,
            comp_time_s=res.comp_time,
            comm_time_s=res.comm_time,
            total_bytes=res.total_bytes,
            max_peak_ram=int(res.peak_ram.max()),
            max_weight_bytes=max(plan.worker_weight_bytes(w)
                                 for w in range(plan.n_workers)))
    return out


def measured_kc(model: ReinterpretedModel, n_workers: int,
                cfg: SimConfig | None = None) -> float:
    """Estimate Eq. 2's communication coefficient Kc by 'profiling or
    simulation' (§V.B): bytes exchanged per byte of output produced."""
    cfg = cfg or SimConfig()
    plan = split_model(model, np.ones(n_workers))
    total_out = sum(lyr.n_out for lyr in model.layers) * cfg.itemsize
    total_comm = 0
    prev = None
    for split in plan.splits:
        total_comm += comm_volume(prev, split.layer, split, cfg.itemsize).total_bytes
        prev = split
    return total_comm / max(total_out, 1)


def simulated_k1(model: ReinterpretedModel, f_mhz: float,
                 cfg: SimConfig | None = None) -> float:
    """Table I's K1 (KB of output per Mcycle) at a given clock, single MCU,
    no transfers (the paper's dummy-input measurement)."""
    cfg = cfg or SimConfig()
    macs = model.total_macs()
    out_kb = sum(lyr.n_out for lyr in model.layers) * cfg.itemsize / 1024.0
    mcycles = macs * (cfg.cycles_per_mac + cfg.flash_ns_per_mac * f_mhz / 1000.0) / 1e6
    return out_kb / mcycles
