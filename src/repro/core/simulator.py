"""Networked-MCU simulator (paper §VII.D): one coordinator + N workers with
the same partitioning and communication logic as the testbed, scaled to 120
workers.

Timing model (paper Eq. 1):
    t_w = W_w / f_w + (d_w + 1/B_w) * f(W_w)
with the compute term refined by a frequency-independent flash-access
component that reproduces Table I's observation that K1 *rises* as the clock
drops (memory-bound fraction grows with f):

    cycles(macs, f) = macs * (CPM + FLASH_NS * f_mhz / 1000)

Communication volumes are not modeled with Eq. 2's linear f(W)=K1*Kc*W
approximation — they are *derived exactly* from the cross-layer activation
mapping (RouteM): per layer, each worker downloads its input region bytes
(duplication across overlapping receptive fields included) and uploads its
assigned outputs.  Eq. 2's Kc then falls out of the simulation
(Kc = comm_bytes / out_bytes per unit workload) instead of being assumed.

Transport policies (``SimConfig.transport``):

* ``"serial"`` (default) — the paper's Eq. 5–6 behavior, bit-compatible
  with every committed baseline: all traffic flows through the coordinator,
  which serializes sends and receives per layer boundary.
* ``"pipelined"`` — an event-driven async transport: each
  coordinator<->worker link is an independent full-duplex FIFO queue with
  that worker's ``d``/``B`` from :class:`WorkerParams`, and download ->
  compute -> upload are overlappable stages per worker (a worker computes
  shard *i* while downloading shard *i+1*'s input region and uploading
  shard *i-1*'s output).  Uploads stream eagerly (§V.D): an upload occupies
  the uplink from compute *start* and completes no earlier than both the
  compute and the wire time.  A download of shard *i+1* becomes ready once
  the uploads it depends on have completed — for spatial plans that is only
  the producers whose output rows overlap the consumer's input window
  (band + halo), so disjoint bands pipeline deeply; flat neuron/kernel
  shards consume overlapping regions of every producer and degrade to a
  per-boundary barrier.  The result carries a per-worker :class:`Timeline`
  of events reduced to makespan / link-utilization / idle-time stats.
  With a single worker there is no second link to overlap with and the
  policies coincide by construction (the serial schedule *is* the
  single-link timeline).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import WorkerParams
from .mapping import comm_volume
from .memory import layerwise_peak
from .reinterpret import ReinterpretedModel, macs_for_positions
from .splitting import SpatialShard, SplitPlan, split_model

TRANSPORTS = ("serial", "pipelined")


@dataclasses.dataclass
class SimConfig:
    cycles_per_mac: float = 73.0      # CPM, calibrated vs Table I/II (bench)
    flash_ns_per_mac: float = 118.0   # frequency-independent weight-fetch ns
    itemsize: int = 1                 # int8 activations on the wire
    overlap: bool = True              # §V.D eager partial-result streaming
    coordinator_bw_kb_s: float = 115000.0  # PC side (GbE) — rarely binding
    transport: str = "serial"         # "serial" (Eq. 5-6) | "pipelined"

    def __post_init__(self) -> None:
        if self.transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {self.transport!r} "
                             f"(want one of {TRANSPORTS})")


@dataclasses.dataclass(frozen=True)
class TimelineEvent:
    """One scheduled stage on one worker's pipeline."""

    worker: int
    kind: str                   # "download" | "compute" | "upload"
    segment: int                # transfer-segment index (fused block / layer)
    layer: int                  # first layer index of the segment
    start_s: float
    end_s: float
    nbytes: int = 0             # transfer events only (0 for compute)

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclasses.dataclass(frozen=True)
class Timeline:
    """Per-worker event schedule produced by the pipelined transport.

    ``events`` are globally start-ordered; per worker, events of one kind
    never overlap (each link direction and the core are FIFO resources),
    but an upload may overlap its own compute (§V.D streaming) and a
    download may overlap other workers' stages.
    """

    n_workers: int
    events: tuple[TimelineEvent, ...]
    makespan_s: float

    def worker_events(self, worker: int) -> tuple[TimelineEvent, ...]:
        return tuple(e for e in self.events if e.worker == worker)

    def busy_s(self, kind: str) -> np.ndarray:
        """Per-worker total busy seconds for one stage kind."""
        out = np.zeros(self.n_workers)
        for e in self.events:
            if e.kind == kind:
                out[e.worker] += e.duration_s
        return out

    @property
    def compute_busy_s(self) -> np.ndarray:
        return self.busy_s("compute")

    @property
    def link_busy_s(self) -> np.ndarray:
        """Per-worker seconds the link is occupied (download + upload)."""
        return self.busy_s("download") + self.busy_s("upload")

    @property
    def idle_s(self) -> np.ndarray:
        """Per-worker seconds the core sits idle inside the makespan."""
        return np.maximum(self.makespan_s - self.compute_busy_s, 0.0)

    @property
    def link_utilization(self) -> np.ndarray:
        """Per-worker fraction of the makespan the link is busy."""
        if self.makespan_s <= 0:
            return np.zeros(self.n_workers)
        return self.link_busy_s / self.makespan_s


@dataclasses.dataclass
class SimResult:
    layer_comp: np.ndarray      # (L,) per-layer compute critical path (s)
    layer_comm: np.ndarray      # (L,) per-layer communication critical path (s)
    layer_bytes: np.ndarray     # (L,) total bytes moved at this boundary
    per_worker_comp: np.ndarray  # (L, N) compute seconds
    per_worker_comm: np.ndarray  # (L, N)
    # (L, N) bytes; None when the caller passed compute_peak=False (the
    # planner gates RAM via memory.peak_ram_per_worker on the same split,
    # so the layerwise sweep here would be duplicate work on the search
    # hot path)
    peak_ram: np.ndarray | None
    # transport="pipelined" extras.  The layer_* arrays above always hold the
    # serial (Eq. 5-6) decomposition, so the serial-equivalent latency stays
    # derivable from any result; ``timeline`` carries the event schedule.
    transport: str = "serial"
    timeline: Timeline | None = None

    @property
    def layer_total(self) -> np.ndarray:
        return self.layer_comp + self.layer_comm

    @property
    def serial_total_time(self) -> float:
        """End-to-end seconds under the serial (Eq. 5-6) transport."""
        return float(self.layer_total.sum())

    @property
    def total_time(self) -> float:
        if self.timeline is not None:
            return float(self.timeline.makespan_s)
        return self.serial_total_time

    @property
    def overlap_saved_s(self) -> float:
        """Seconds the pipelined transport saves vs serial (0 when serial)."""
        if self.timeline is None:
            return 0.0
        return self.serial_total_time - float(self.timeline.makespan_s)

    @property
    def comp_time(self) -> float:
        if self.timeline is not None:
            # compute critical path under overlap: the busiest core
            return float(self.timeline.compute_busy_s.max())
        return float(self.layer_comp.sum())

    @property
    def comm_time(self) -> float:
        return self.total_time - self.comp_time

    @property
    def total_bytes(self) -> int:
        return int(self.layer_bytes.sum())


def _comp_seconds(macs: np.ndarray, f_mhz: np.ndarray, cfg: SimConfig) -> np.ndarray:
    cycles = macs * (cfg.cycles_per_mac + cfg.flash_ns_per_mac * f_mhz / 1000.0)
    return cycles / (f_mhz * 1e6)


def simulate(model: ReinterpretedModel, workers: list[WorkerParams],
             ratings: np.ndarray | None = None,
             cfg: SimConfig | None = None,
             plan: SplitPlan | None = None,
             compute_peak: bool = True) -> SimResult:
    """Run one end-to-end inference through the timing model.

    ``ratings`` defaults to uniform; ``plan`` may be passed to reuse a split
    — including heterogeneous ``split_model_mixed`` plans, whose segments
    are timed under both transports (spatial→spatial seams keep the exact
    row-overlap dependencies, mixed seams barrier per boundary).
    ``cfg.transport`` picks the communication model: ``"serial"`` (Eq. 5-6,
    the default) or ``"pipelined"`` (per-link FIFO queues with overlapped
    download/compute/upload; the result carries a :class:`Timeline`).
    ``compute_peak=False`` skips the layerwise peak-RAM sweep (the result's
    ``peak_ram`` is None) — for callers like the plan search that gate RAM
    separately on the same split.
    """
    cfg = cfg or SimConfig()
    n = len(workers)
    for i, p in enumerate(workers):
        if p.b_kb_s <= 0:
            raise ValueError(f"worker {i}: zero-bandwidth link "
                             f"(b_kb_s={p.b_kb_s!r}) cannot move activations")
    if ratings is None:
        ratings = np.ones(n)
    if plan is None:
        plan = split_model(model, ratings)
    f_mhz = np.array([p.f_mhz for p in workers])
    d = np.array([p.d_s_per_kb for p in workers])
    inv_b = np.array([1.0 / p.b_kb_s for p in workers])

    L = len(model.layers)
    comp = np.zeros((L, n))
    comm = np.zeros((L, n))
    nbytes = np.zeros(L)
    down_s = np.zeros((L, n))    # per-layer per-worker download wire time
    up_s = np.zeros((L, n))      # upload wire time of layer li-1's producers
    down_b = np.zeros((L, n), dtype=np.int64)
    up_b = np.zeros((L, n), dtype=np.int64)
    per_layer_total = np.zeros(L)
    layer_comp_arr = np.zeros(L)
    prev_split = None
    for li, split in enumerate(plan.splits):
        layer = split.layer
        macs = np.array([macs_for_positions(layer, split.shard_of(w).n_positions)
                         for w in range(n)], dtype=np.float64)
        comp[li] = _comp_seconds(macs, f_mhz, cfg)
        vol = comm_volume(prev_split, layer, split, itemsize=cfg.itemsize)
        down_kb = vol.download_bytes / 1024.0
        up_kb = vol.upload_bytes / 1024.0
        # per-worker link time (Eq. 1's communication term, exact bytes)
        t_down = (d + inv_b) * down_kb
        t_up = (d + inv_b) * up_kb
        comm[li] = t_down + t_up
        nbytes[li] = vol.total_bytes
        down_s[li], up_s[li] = t_down, t_up
        down_b[li], up_b[li] = vol.download_bytes, vol.upload_bytes
        prev_split = split
        # all traffic flows through the coordinator (§VI.B), which serializes
        # sends/receives — the reason communication grows with N (Fig. 9/10)
        t_down_serial = t_down.sum()
        t_up_serial = t_up.sum()
        max_comp = comp[li].max()
        if cfg.overlap:
            # eager partial results (§V.D): uploads stream while other
            # workers still compute
            totals = t_down_serial + np.maximum(max_comp, t_up_serial)
        else:
            totals = t_down_serial + max_comp + t_up_serial
        per_layer_total[li] = totals
        layer_comp_arr[li] = max_comp

    layer_comp = layer_comp_arr
    layer_comm = per_layer_total - layer_comp
    timeline = None
    if cfg.transport == "pipelined":
        if n == 1:
            timeline = _single_link_timeline(per_layer_total, comp, down_s,
                                             up_s, down_b, up_b, cfg.overlap)
        else:
            timeline = _pipelined_timeline(plan, comp, down_s, up_s,
                                           down_b, up_b)
    return SimResult(layer_comp=layer_comp, layer_comm=layer_comm,
                     layer_bytes=nbytes, per_worker_comp=comp,
                     per_worker_comm=comm,
                     peak_ram=(layerwise_peak(plan, itemsize=cfg.itemsize)
                               if compute_peak else None),
                     transport=cfg.transport, timeline=timeline)


def _single_link_timeline(per_layer_total: np.ndarray, comp: np.ndarray,
                          down_s: np.ndarray, up_s: np.ndarray,
                          down_b: np.ndarray, up_b: np.ndarray,
                          overlap: bool) -> Timeline:
    """With one worker there is no second link to overlap with: the pipelined
    transport degenerates to the serial schedule (makespan == Eq. 5-6 total),
    rendered as that worker's timeline."""
    events: list[TimelineEvent] = []
    t = 0.0
    for li in range(comp.shape[0]):
        if down_b[li, 0]:
            events.append(TimelineEvent(0, "download", li, li, t,
                                        t + down_s[li, 0],
                                        int(down_b[li, 0])))
        c0 = t + down_s[li, 0]
        if comp[li, 0] > 0:
            events.append(TimelineEvent(0, "compute", li, li, c0,
                                        c0 + comp[li, 0]))
        if up_b[li, 0]:
            # layer li's bucket carries the *previous* boundary's upload —
            # streamed alongside this layer's compute exactly as Eq. 5-6's
            # overlap term does, or after it when overlap is off
            u0 = c0 if overlap else c0 + comp[li, 0]
            events.append(TimelineEvent(0, "upload", li, max(li - 1, 0), u0,
                                        u0 + up_s[li, 0], int(up_b[li, 0])))
        t += per_layer_total[li]
    return Timeline(n_workers=1, events=tuple(events),
                    makespan_s=float(per_layer_total.sum()))


def _segments(plan: SplitPlan) -> list[tuple[int, ...]]:
    """Transfer segments: maximal runs of layers that exchange no traffic
    internally (fused spatial blocks; singleton for every flat layer)."""
    segs: list[list[int]] = []
    for li, split in enumerate(plan.splits):
        if split.block_first or not segs:
            segs.append([li])
        else:
            segs[-1].append(li)
    return [tuple(s) for s in segs]


def _boundary_deps(prev_split, split, up_bytes: np.ndarray) -> list[list[int]]:
    """For each consumer worker of ``split``, the producer workers of
    ``prev_split`` whose uploads its download waits on.

    When both sides are spatial bands the dependency is exact: only the
    producers whose output rows intersect the consumer's input window
    (band + halo).  Flat shards consume overlapping regions of essentially
    every producer, so they (and mixed boundaries) wait on every producer
    that uploads anything — the per-boundary barrier the serial model also
    implies.
    """
    n = len(split.shards)
    # producers are enumerated over the *producer* split's width (up_bytes
    # is producer-indexed — see CommVolume), consumers over this split's
    uploading = [p for p in range(len(prev_split.shards))
                 if p < len(up_bytes) and up_bytes[p] > 0]
    spatial = (all(isinstance(s, SpatialShard) for s in split.shards)
               and all(isinstance(s, SpatialShard) for s in prev_split.shards))
    if not spatial:
        return [list(uploading) for _ in range(n)]
    deps: list[list[int]] = []
    for w in range(n):
        cs = split.shards[w]
        if cs.n_positions == 0:
            deps.append([])
            continue
        deps.append([p for p in uploading
                     if prev_split.shards[p].row_lo < cs.in_hi
                     and prev_split.shards[p].row_hi > cs.in_lo])
    return deps


def pipelined_dependencies(plan: SplitPlan,
                           itemsize: int = 1) -> list[list[list[int]]]:
    """Per segment boundary, per consumer worker: the producer workers whose
    uploads the consumer's download waits on under the pipelined transport.

    ``result[b][w]`` lists the producers of segment ``b`` (the boundary
    between segments ``b`` and ``b+1``) that consumer worker ``w`` of segment
    ``b+1`` depends on — :func:`_boundary_deps` evaluated with the exact
    upload volumes of the boundary.  This is the public form shared by
    :func:`_pipelined_timeline` and the real distributed runtime
    (``repro.runtime.coordinator``), so the simulated and the executed
    schedule derive their dependency edges from one definition.
    """
    segs = _segments(plan)
    deps: list[list[list[int]]] = []
    for si in range(1, len(segs)):
        first = segs[si][0]
        prev_split = plan.splits[segs[si - 1][-1]]
        split = plan.splits[first]
        vol = comm_volume(prev_split, split.layer, split, itemsize=itemsize)
        deps.append(_boundary_deps(prev_split, split, vol.upload_bytes))
    return deps


def dependency_edges(plan: SplitPlan) -> set[tuple[int, int, int]]:
    """The pipelined schedule's dependency-edge set, as
    ``(consumer_segment, consumer_worker, producer_worker)`` triples.

    A download for segment ``s`` on worker ``w`` may not start before
    producer ``p``'s segment ``s-1`` upload completed.  The measured runtime
    Timeline must realize a *superset* of these edges (a barrier waits on
    more producers, never fewer) — the structural half of the
    measured-vs-predicted validation in ``runtime/validate.py``.
    """
    return {(si + 1, w, p)
            for si, boundary in enumerate(pipelined_dependencies(plan))
            for w, producers in enumerate(boundary)
            for p in producers}


def _pipelined_timeline(plan: SplitPlan, comp: np.ndarray,
                        down_s: np.ndarray, up_s: np.ndarray,
                        down_b: np.ndarray, up_b: np.ndarray) -> Timeline:
    """Event-driven schedule over per-worker full-duplex FIFO links.

    Per segment ``s`` and worker ``w`` three stages are scheduled:

    * download: starts once the downlink is free *and* the uploads it
      depends on (:func:`_boundary_deps`) completed;
    * compute: starts once the download landed and the core is free;
    * upload (eager §V.D streaming): occupies the uplink from compute start,
      completes no earlier than the compute and the wire time.

    Earliest-start scheduling over fixed FIFO orders is deterministic and,
    with ``cfg.overlap=False`` serial as the reference, never slower — the
    serial schedule satisfies every constraint here, plus the coordinator
    serialization this transport removes.
    """
    n = comp.shape[1]
    segs = _segments(plan)
    dl_free = np.zeros(n)
    up_free = np.zeros(n)
    core_free = np.zeros(n)
    up_end = np.zeros(n)          # upload completion of the previous segment
    events: list[TimelineEvent] = []
    for si, seg in enumerate(segs):
        first = seg[0]
        seg_comp = comp[list(seg)].sum(axis=0)
        if si == 0:
            deps = [[] for _ in range(n)]
        else:
            deps = _boundary_deps(plan.splits[segs[si - 1][-1]],
                                  plan.splits[first], up_b[first])
        prev_up_end = up_end.copy()
        new_up_end = np.zeros(n)
        for w in range(n):
            ready = max((prev_up_end[p] for p in deps[w]), default=0.0)
            dl_start = max(ready, dl_free[w])
            dl_end = dl_start + down_s[first, w]
            if down_b[first, w]:
                events.append(TimelineEvent(w, "download", si, first,
                                            dl_start, dl_end,
                                            int(down_b[first, w])))
            dl_free[w] = dl_end
            c_start = max(dl_end, core_free[w])
            c_end = c_start + seg_comp[w]
            if seg_comp[w] > 0:
                events.append(TimelineEvent(w, "compute", si, first,
                                            c_start, c_end))
            core_free[w] = c_end
            # the upload of this segment's output is accounted at the next
            # segment's first layer (comm_volume's prev-split convention)
            if si + 1 < len(segs):
                nxt = segs[si + 1][0]
                if up_b[nxt, w]:
                    u_start = max(c_start, up_free[w])
                    u_end = max(c_end, u_start + up_s[nxt, w])
                    events.append(TimelineEvent(w, "upload", si, first,
                                                u_start, u_end,
                                                int(up_b[nxt, w])))
                    up_free[w] = u_end
                    new_up_end[w] = u_end
                else:
                    new_up_end[w] = c_end
            else:
                new_up_end[w] = c_end
        up_end = new_up_end
    makespan = 0.0
    if events:
        makespan = max(e.end_s for e in events)
    events.sort(key=lambda e: (e.start_s, e.worker, e.kind))
    return Timeline(n_workers=n, events=tuple(events), makespan_s=makespan)


@dataclasses.dataclass(frozen=True)
class ModeReport:
    """One partitioning mode's simulated cost profile (compare_modes).

    ``feasible=False`` marks a mode whose split could not be built for the
    given workers/ratings (``reason`` says why); its metrics are NaN/0 and
    must not be compared.  The transport stats are meaningful for
    ``transport="pipelined"`` (zero under serial, which has no timeline).
    """

    mode: str
    total_time_s: float
    comp_time_s: float
    comm_time_s: float
    total_bytes: int
    max_peak_ram: int        # max over layers x workers (Fig. 12's metric)
    max_weight_bytes: int    # largest per-worker weight footprint
    transport: str = "serial"
    overlap_saved_s: float = 0.0     # serial-equivalent minus makespan
    mean_link_utilization: float = 0.0
    max_idle_s: float = 0.0          # worst per-worker core idle time
    feasible: bool = True
    reason: str | None = None


def compare_modes(model: ReinterpretedModel, workers: list[WorkerParams],
                  ratings: np.ndarray | None = None,
                  cfg: SimConfig | None = None,
                  modes: tuple[str, ...] = ("neuron", "kernel", "spatial"),
                  ) -> dict[str, ModeReport]:
    """Simulate the same deployment under each partitioning mode — the
    comm/peak-RAM tradeoff report: spatial trades weight replication + halo
    recompute for a smaller activation working set and less routed traffic in
    the early high-resolution stages; the channel/neuron modes split weights
    but route overlapping input regions to every worker.

    A mode whose split cannot be built for these workers yields an explicit
    infeasible entry (``feasible=False`` plus the reason) instead of being
    silently dropped or aborting the surviving modes.
    """
    out: dict[str, ModeReport] = {}
    for mode in modes:
        try:
            plan = split_model(model, ratings if ratings is not None
                               else np.ones(len(workers)), mode=mode)
            res = simulate(model, workers, ratings, cfg, plan=plan)
        except (ValueError, RuntimeError) as e:
            out[mode] = ModeReport(
                mode=mode, total_time_s=float("nan"),
                comp_time_s=float("nan"), comm_time_s=float("nan"),
                total_bytes=0, max_peak_ram=0, max_weight_bytes=0,
                transport=(cfg or SimConfig()).transport,
                feasible=False, reason=f"{type(e).__name__}: {e}")
            continue
        tl = res.timeline
        out[mode] = ModeReport(
            mode=mode,
            total_time_s=res.total_time,
            comp_time_s=res.comp_time,
            comm_time_s=res.comm_time,
            total_bytes=res.total_bytes,
            max_peak_ram=int(res.peak_ram.max()),
            max_weight_bytes=max(plan.worker_weight_bytes(w)
                                 for w in range(plan.n_workers)),
            transport=res.transport,
            overlap_saved_s=res.overlap_saved_s,
            mean_link_utilization=(float(tl.link_utilization.mean())
                                   if tl is not None else 0.0),
            max_idle_s=float(tl.idle_s.max()) if tl is not None else 0.0)
    return out


def measured_kc(model: ReinterpretedModel, n_workers: int,
                cfg: SimConfig | None = None) -> float:
    """Estimate Eq. 2's communication coefficient Kc by 'profiling or
    simulation' (§V.B): bytes exchanged per byte of output produced."""
    cfg = cfg or SimConfig()
    plan = split_model(model, np.ones(n_workers))
    total_out = sum(lyr.n_out for lyr in model.layers) * cfg.itemsize
    total_comm = 0
    prev = None
    for split in plan.splits:
        total_comm += comm_volume(prev, split.layer, split, cfg.itemsize).total_bytes
        prev = split
    return total_comm / max(total_out, 1)


def simulated_k1(model: ReinterpretedModel, f_mhz: float,
                 cfg: SimConfig | None = None) -> float:
    """Table I's K1 (KB of output per Mcycle) at a given clock, single MCU,
    no transfers (the paper's dummy-input measurement)."""
    cfg = cfg or SimConfig()
    macs = model.total_macs()
    out_kb = sum(lyr.n_out for lyr in model.layers) * cfg.itemsize / 1024.0
    mcycles = macs * (cfg.cycles_per_mac + cfg.flash_ns_per_mac * f_mhz / 1000.0) / 1e6
    return out_kb / mcycles
