"""Post-training int8 quantization (paper §V.D).

Weights: symmetric per-output-channel int8.  Activations: symmetric
per-tensor int8, calibrated from a float forward pass over calibration
inputs (max-abs).  Accumulation in int32, requantization to the next layer's
activation scale — matching an integer-arithmetic-only MCU runtime
(Jacob et al., CVPR'18, the paper's [2]).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .fusion import apply_activation
from .reinterpret import ReinterpretedModel


@dataclasses.dataclass
class QuantizedLayer:
    w_q: np.ndarray | None          # int8, same layout as LayerSpec.weight
    w_scale: np.ndarray | None      # per-output-channel float scale
    b_q: np.ndarray | None          # int32 bias at scale (s_in * s_w)
    in_scale: float                 # activation scale feeding this layer
    out_scale: float                # activation scale of this layer's output


@dataclasses.dataclass
class QuantizedModel:
    model: ReinterpretedModel
    layers: list[QuantizedLayer]
    input_scale: float


def quantize_tensor_per_channel(w: np.ndarray, channel_axis: int) -> tuple[np.ndarray, np.ndarray]:
    mx = np.max(np.abs(w), axis=tuple(i for i in range(w.ndim) if i != channel_axis))
    scale = np.maximum(mx, 1e-12) / 127.0
    shape = [1] * w.ndim
    shape[channel_axis] = -1
    q = np.clip(np.round(w / scale.reshape(shape)), -127, 127).astype(np.int8)
    return q, scale.astype(np.float64)


def quantize_activation(x: np.ndarray, scale: float) -> np.ndarray:
    return np.clip(np.round(x / scale), -127, 127).astype(np.int8)


def dequantize(q: np.ndarray, scale) -> np.ndarray:
    return q.astype(np.float32) * np.asarray(scale, dtype=np.float32)


def calibrate_scales(model: ReinterpretedModel, calib_inputs: list[np.ndarray],
                     forward_fn) -> list[float]:
    """Max-abs activation scale per layer boundary.  ``forward_fn(model, x)``
    must return the list of post-activation outputs per layer (float path)."""
    n_layers = len(model.layers)
    maxes = np.zeros(n_layers + 1)
    for x in calib_inputs:
        maxes[0] = max(maxes[0], float(np.max(np.abs(x))))
        acts = forward_fn(model, x)
        for i, a in enumerate(acts):
            maxes[i + 1] = max(maxes[i + 1], float(np.max(np.abs(a))))
    return list(np.maximum(maxes, 1e-12) / 127.0)


def quantize_model(model: ReinterpretedModel, act_scales: list[float]) -> QuantizedModel:
    """act_scales: length n_layers+1 (input scale followed by per-layer output
    scales) from :func:`calibrate_scales`."""
    assert len(act_scales) == len(model.layers) + 1
    qlayers: list[QuantizedLayer] = []
    for i, layer in enumerate(model.layers):
        s_in, s_out = act_scales[i], act_scales[i + 1]
        if layer.weight is None:
            qlayers.append(QuantizedLayer(None, None, None, s_in, s_out))
            continue
        ch_axis = 0 if layer.kind in ("conv", "dwconv") else 1
        w_q, w_s = quantize_tensor_per_channel(layer.weight, ch_axis)
        bias = layer.bias if layer.bias is not None else np.zeros(
            layer.weight.shape[ch_axis], np.float32)
        b_q = np.round(bias / (s_in * w_s)).astype(np.int64)
        qlayers.append(QuantizedLayer(w_q, w_s, b_q, s_in, s_out))
    return QuantizedModel(model, qlayers, act_scales[0])


def epilogue_params(ql: QuantizedLayer) -> tuple[np.ndarray, np.ndarray]:
    """The int8 layer's fused-epilogue constants: the float32 per-channel
    dequant multiplier ``scale = s_in * w_scale`` and the int32 bias ``b_q``
    (already at accumulator scale).

    The epilogue contract — shared bit-for-bit by the eager executor, the
    compiled jnp path and the Pallas kernels — is

        y_real = f32(acc_i32 + b_q) * scale            # one f32 multiply
        q_out  = clip(round(y_real * (1 / out_scale)))  # one f32 multiply

    The bias is added in exact int32 arithmetic and every float step is a
    *multiply*: float adds are deliberately avoided because XLA contracts
    ``a*b + c`` into an FMA inside large fused graphs (jit) but not in
    op-by-op dispatch, which flips requantization rounding at ties.  With
    multiplies only, eager and jitted execution round identically.
    """
    m = (ql.in_scale * ql.w_scale).astype(np.float32)
    return m, ql.b_q.astype(np.int32)


def requantize(acc_i32, scale, out_scale: float, activation: str | None):
    """Biased int32 accumulator -> int8 output at ``out_scale`` (jnp,
    on-device).  ``scale`` is the float32 multiplier array from
    :func:`epilogue_params`, broadcastable against ``acc_i32`` (per leading
    channel for (C, H, W) accumulators, per position for flat accumulators).
    See :func:`epilogue_params` for the exactness contract.
    """
    y = acc_i32.astype(jnp.float32) * scale
    y = apply_activation(y, activation)
    return jnp.clip(jnp.round(y * (1.0 / float(out_scale))),
                    -127, 127).astype(jnp.int8)


def quantize_activation_jnp(x, scale: float):
    """jnp counterpart of :func:`quantize_activation` (float32
    multiply-by-reciprocal — see :func:`epilogue_params` for why) — used
    on-device by both executors so the eager and compiled int8 paths round
    identically."""
    x = jnp.asarray(x, jnp.float32)
    return jnp.clip(jnp.round(x * (1.0 / float(scale))),
                    -127, 127).astype(jnp.int8)
