"""Model reinterpretation (paper §IV.A).

Standard DL frameworks expose models at *layer* granularity; the paper's
fine-grained splitting needs *neuron-level* dependencies.  This module defines
the internal representation a pre-trained model is "reinterpreted" into:

  * :class:`LayerSpec` — one entry per fused computation (conv/dwconv/linear/
    pool) carrying tensor dimensions, kernel parameters and the weight tensors
    themselves (the paper serializes the same metadata from its Rust tracer).
  * receptive-field queries — for any output neuron ``(c, h, w)`` of a layer,
    the exact set of input activations required to compute it (paper Fig. 3,
    ``get_input()`` in Alg. 3).

All shapes are CHW (channel, height, width); linear layers are represented as
``(features, 1, 1)`` so that the same flat-index arithmetic (Alg. 1/3's
``i // (h*w)`` decomposition) applies uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

Shape3 = tuple[int, int, int]


@dataclasses.dataclass
class LayerSpec:
    """One reinterpreted layer: structural metadata + parameters.

    ``kind``:
      * ``conv``    — dense 2-D convolution, weight ``(Cout, Cin, kh, kw)``
      * ``dwconv``  — depthwise convolution (groups == Cin == Cout), weight
                      ``(C, 1, kh, kw)``
      * ``linear``  — fully connected, weight ``(in_features, out_features)``
                      (column ``j`` == output neuron ``j``, paper Alg. 2)
      * ``avgpool`` — global average pool (no weights; coordinator-side)
    """

    name: str
    kind: str
    in_shape: Shape3
    out_shape: Shape3
    weight: np.ndarray | None = None
    bias: np.ndarray | None = None
    stride: tuple[int, int] = (1, 1)
    padding: tuple[int, int] = (0, 0)
    kernel: tuple[int, int] = (1, 1)
    activation: str | None = None      # None | "relu" | "relu6" (fused, §V.D)
    # Residual bookkeeping: coordinator-side (the coordinator "prepares the
    # input activations for the next layer", Alg. 4 line 9 — adds happen there).
    save_as: str | None = None         # stash this layer's output under a key
    residual_from: str | None = None   # add stashed activation to this output

    def __post_init__(self) -> None:
        if self.kind in ("conv", "dwconv") and self.weight is not None:
            self.kernel = tuple(self.weight.shape[-2:])

    # -- size helpers ------------------------------------------------------
    @property
    def n_out(self) -> int:
        c, h, w = self.out_shape
        return c * h * w

    @property
    def n_in(self) -> int:
        c, h, w = self.in_shape
        return c * h * w

    def weight_bytes(self, itemsize: int = 1) -> int:
        if self.weight is None:
            return 0
        return int(np.prod(self.weight.shape)) * itemsize

    # -- neuron-level dependency queries (paper Fig. 3) ---------------------
    def receptive_field(self, c: int, h: int, w: int) -> tuple[range, range, range]:
        """Input region (channels, rows, cols) feeding output neuron (c,h,w).

        Returns half-open ranges clipped to the input bounds.  ``get_input``
        in Alg. 3 is the point-set materialization of this query.
        """
        ci, hi, wi = self.in_shape
        if self.kind == "linear":
            return range(ci), range(1), range(1)
        if self.kind == "avgpool":
            return range(c, c + 1), range(hi), range(wi)
        kh, kw = self.kernel
        sh, sw = self.stride
        ph, pw = self.padding
        h0, w0 = h * sh - ph, w * sw - pw
        rows = range(max(h0, 0), min(h0 + kh, hi))
        cols = range(max(w0, 0), min(w0 + kw, wi))
        if self.kind == "dwconv":
            return range(c, c + 1), rows, cols
        return range(ci), rows, cols  # dense conv reads every input channel

    def get_input(self, c: int, h: int, w: int) -> Iterator[tuple[int, int, int]]:
        """Materialized receptive field — literal Alg. 3 ``get_input()``."""
        chs, rows, cols = self.receptive_field(c, h, w)
        for cc in chs:
            for hh in rows:
                for ww in cols:
                    yield (cc, hh, ww)

    def input_rows_for_output_rows(self, h_lo: int, h_hi: int) -> tuple[int, int]:
        """Input row interval (inclusive lo, exclusive hi) needed for output
        rows [h_lo, h_hi] (inclusive).  Vectorized form of receptive_field
        used by the scalable mapping path."""
        _, hi, _ = self.in_shape
        if self.kind in ("linear", "avgpool"):
            return 0, hi
        kh, _ = self.kernel
        sh, _ = self.stride
        ph, _ = self.padding
        lo = max(h_lo * sh - ph, 0)
        hi_ = min(h_hi * sh - ph + kh, hi)
        return lo, hi_

    def input_cols_for_output_cols(self, w_lo: int, w_hi: int) -> tuple[int, int]:
        _, _, wi = self.in_shape
        if self.kind in ("linear", "avgpool"):
            return 0, wi
        _, kw = self.kernel
        _, sw = self.stride
        _, pw = self.padding
        lo = max(w_lo * sw - pw, 0)
        hi_ = min(w_hi * sw - pw + kw, wi)
        return lo, hi_


def conv_out_hw(in_hw: tuple[int, int], kernel: tuple[int, int],
                stride: tuple[int, int], padding: tuple[int, int]) -> tuple[int, int]:
    h = (in_hw[0] + 2 * padding[0] - kernel[0]) // stride[0] + 1
    w = (in_hw[1] + 2 * padding[1] - kernel[1]) // stride[1] + 1
    return h, w


@dataclasses.dataclass
class ReinterpretedModel:
    """Ordered layer list + consistency checks (the serialized representation
    the paper deploys; ours stays in memory / npz)."""

    layers: list[LayerSpec]
    input_shape: Shape3

    def __post_init__(self) -> None:
        prev = self.input_shape
        for lyr in self.layers:
            # Element count must chain; exact shape may differ by a flatten
            # (CHW row-major flat order is preserved, so indices still line up).
            if int(np.prod(lyr.in_shape)) != int(np.prod(prev)):
                raise ValueError(
                    f"layer {lyr.name}: in_shape {lyr.in_shape} != upstream {prev}")
            prev = lyr.out_shape

    @property
    def out_shape(self) -> Shape3:
        return self.layers[-1].out_shape

    def total_weight_bytes(self, itemsize: int = 1) -> int:
        return sum(lyr.weight_bytes(itemsize) for lyr in self.layers)

    def total_macs(self) -> int:
        return sum(layer_macs(lyr) for lyr in self.layers)


def layer_macs(layer: LayerSpec) -> int:
    """Multiply-accumulates for the full layer (workload unit W, §V.A)."""
    c, h, w = layer.out_shape
    if layer.kind == "linear":
        return layer.in_shape[0] * c
    if layer.kind == "avgpool":
        return layer.n_in
    kh, kw = layer.kernel
    cin = 1 if layer.kind == "dwconv" else layer.in_shape[0]
    return c * h * w * kh * kw * cin


def macs_for_positions(layer: LayerSpec, n_positions: int) -> int:
    """MACs for ``n_positions`` output neurons (uniform per-position cost)."""
    if layer.n_out == 0:
        return 0
    return int(round(layer_macs(layer) * n_positions / layer.n_out))


# ---------------------------------------------------------------------------
# Tracing helpers: build LayerSpecs from a functional layer description.
# ---------------------------------------------------------------------------

def trace_sequential(spec: Sequence[dict], input_shape: Shape3,
                     rng: np.random.Generator | None = None) -> ReinterpretedModel:
    """Build a ReinterpretedModel from a declarative op list.

    Each dict: {kind, out_channels?, kernel?, stride?, padding?, features?,
    activation?, save_as?, residual_from?}.  Weights are taken from 'weight'/
    'bias' keys if present, else randomly initialized (He) via ``rng`` —
    mirrors the paper's offline trace of a pre-trained network.
    """
    rng = rng or np.random.default_rng(0)
    layers: list[LayerSpec] = []
    cur = tuple(input_shape)
    for i, op in enumerate(spec):
        kind = op["kind"]
        name = op.get("name", f"L{i}_{kind}")
        if kind == "conv":
            cout = op["out_channels"]
            k = tuple(op.get("kernel", (3, 3)))
            s = tuple(op.get("stride", (1, 1)))
            p = tuple(op.get("padding", (k[0] // 2, k[1] // 2)))
            oh, ow = conv_out_hw(cur[1:], k, s, p)
            w = op.get("weight")
            if w is None:
                fan_in = cur[0] * k[0] * k[1]
                w = rng.standard_normal((cout, cur[0], *k)).astype(np.float32)
                w *= np.sqrt(2.0 / fan_in)
            b = op.get("bias")
            if b is None:
                b = np.zeros((cout,), np.float32)
            layers.append(LayerSpec(name, "conv", cur, (cout, oh, ow), w, b,
                                    stride=s, padding=p,
                                    activation=op.get("activation"),
                                    save_as=op.get("save_as"),
                                    residual_from=op.get("residual_from")))
            cur = (cout, oh, ow)
        elif kind == "dwconv":
            c = cur[0]
            k = tuple(op.get("kernel", (3, 3)))
            s = tuple(op.get("stride", (1, 1)))
            p = tuple(op.get("padding", (k[0] // 2, k[1] // 2)))
            oh, ow = conv_out_hw(cur[1:], k, s, p)
            w = op.get("weight")
            if w is None:
                w = rng.standard_normal((c, 1, *k)).astype(np.float32)
                w *= np.sqrt(2.0 / (k[0] * k[1]))
            b = op.get("bias")
            if b is None:
                b = np.zeros((c,), np.float32)
            layers.append(LayerSpec(name, "dwconv", cur, (c, oh, ow), w, b,
                                    stride=s, padding=p,
                                    activation=op.get("activation"),
                                    save_as=op.get("save_as"),
                                    residual_from=op.get("residual_from")))
            cur = (c, oh, ow)
        elif kind == "linear":
            fin = cur[0] * cur[1] * cur[2]
            fout = op["features"]
            w = op.get("weight")
            if w is None:
                w = rng.standard_normal((fin, fout)).astype(np.float32)
                w *= np.sqrt(2.0 / fin)
            b = op.get("bias")
            if b is None:
                b = np.zeros((fout,), np.float32)
            layers.append(LayerSpec(name, "linear", (fin, 1, 1), (fout, 1, 1),
                                    w, b, activation=op.get("activation")))
            cur = (fout, 1, 1)
        elif kind == "avgpool":
            layers.append(LayerSpec(name, "avgpool", cur, (cur[0], 1, 1)))
            cur = (cur[0], 1, 1)
        elif kind == "flatten":
            # Flatten is implicit: CHW row-major flat order is preserved, so a
            # downstream linear simply declares in_shape (C*H*W, 1, 1).
            cur = (cur[0] * cur[1] * cur[2], 1, 1)
        else:
            raise ValueError(f"unknown op kind {kind!r}")
    return ReinterpretedModel(layers=list(layers), input_shape=tuple(input_shape))
