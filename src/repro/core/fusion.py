"""Layer fusion (paper §V.D): fold BatchNorm into conv weights/bias and fuse
the activation in-place, so conv+BN+ReLU becomes one composite operation.

With BN parameters (gamma, beta, mean, var, eps):
    y = gamma * (conv(x, W) + b - mean) / sqrt(var + eps) + beta
      = conv(x, W * s[c]) + (b - mean) * s[c] + beta,   s = gamma / sqrt(var+eps)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchNormParams:
    gamma: np.ndarray
    beta: np.ndarray
    mean: np.ndarray
    var: np.ndarray
    eps: float = 1e-5


def fold_batchnorm(weight: np.ndarray, bias: np.ndarray | None,
                   bn: BatchNormParams) -> tuple[np.ndarray, np.ndarray]:
    """Fold BN into a conv/dwconv weight (Cout, Cin_g, kh, kw) and bias."""
    s = bn.gamma / np.sqrt(bn.var + bn.eps)
    w = weight * s[:, None, None, None]
    b = np.zeros(weight.shape[0], weight.dtype) if bias is None else bias
    b = (b - bn.mean) * s + bn.beta
    return w.astype(weight.dtype), b.astype(np.float32)


def fold_batchnorm_linear(weight: np.ndarray, bias: np.ndarray | None,
                          bn: BatchNormParams) -> tuple[np.ndarray, np.ndarray]:
    """Same folding for a linear weight (in_features, out_features)."""
    s = bn.gamma / np.sqrt(bn.var + bn.eps)
    w = weight * s[None, :]
    b = np.zeros(weight.shape[1], weight.dtype) if bias is None else bias
    b = (b - bn.mean) * s + bn.beta
    return w.astype(weight.dtype), b.astype(np.float32)


def apply_activation(x, activation: str | None):
    """In-place-style fused activation (works for numpy and jax arrays)."""
    if activation is None:
        return x
    if activation == "relu":
        return x * (x > 0)
    if activation == "relu6":
        return (x * (x > 0)).clip(max=6.0)
    raise ValueError(f"unknown activation {activation!r}")
