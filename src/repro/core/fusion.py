"""Layer fusion (paper §V.D): fold BatchNorm into conv weights/bias and fuse
the activation in-place, so conv+BN+ReLU becomes one composite operation.

With BN parameters (gamma, beta, mean, var, eps):
    y = gamma * (conv(x, W) + b - mean) / sqrt(var + eps) + beta
      = conv(x, W * s[c]) + (b - mean) * s[c] + beta,   s = gamma / sqrt(var+eps)

Beyond the per-op folding, :func:`group_blocks` groups consecutive layers into
*fused execution blocks* — MobileNetV2's inverted residuals
(expand 1x1 -> dwconv -> project 1x1, or dwconv -> project for t=1) — used by
the spatial partitioning mode (MCUNetV2-style patch inference): a worker runs
a whole block on its output-height band so the expanded hidden activation only
ever exists at band size, never at full resolution.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchNormParams:
    gamma: np.ndarray
    beta: np.ndarray
    mean: np.ndarray
    var: np.ndarray
    eps: float = 1e-5


def fold_batchnorm(weight: np.ndarray, bias: np.ndarray | None,
                   bn: BatchNormParams) -> tuple[np.ndarray, np.ndarray]:
    """Fold BN into a conv/dwconv weight (Cout, Cin_g, kh, kw) and bias."""
    s = bn.gamma / np.sqrt(bn.var + bn.eps)
    w = weight * s[:, None, None, None]
    b = np.zeros(weight.shape[0], weight.dtype) if bias is None else bias
    b = (b - bn.mean) * s + bn.beta
    return w.astype(weight.dtype), b.astype(np.float32)


def fold_batchnorm_linear(weight: np.ndarray, bias: np.ndarray | None,
                          bn: BatchNormParams) -> tuple[np.ndarray, np.ndarray]:
    """Same folding for a linear weight (in_features, out_features)."""
    s = bn.gamma / np.sqrt(bn.var + bn.eps)
    w = weight * s[None, :]
    b = np.zeros(weight.shape[1], weight.dtype) if bias is None else bias
    b = (b - bn.mean) * s + bn.beta
    return w.astype(weight.dtype), b.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class FusedBlock:
    """Consecutive layer indices executed as one fused unit per spatial band.

    Only the first layer's input is routed from the coordinator and only the
    last layer's output is aggregated; every intermediate activation stays
    worker-local at band size.  Singleton blocks degrade to plain per-layer
    execution.
    """

    indices: tuple[int, ...]

    @property
    def first(self) -> int:
        return self.indices[0]

    @property
    def last(self) -> int:
        return self.indices[-1]

    def __len__(self) -> int:
        return len(self.indices)


def _is_pointwise(layer) -> bool:
    return (layer.kind == "conv" and layer.kernel == (1, 1)
            and layer.stride == (1, 1) and layer.padding == (0, 0))


def _fusable_interior(layer) -> bool:
    """A layer may sit before the end of a fused block only if nothing else
    needs its full output materialized: no residual stash, no residual add."""
    return layer.save_as is None and layer.residual_from is None


def group_blocks(model) -> list[FusedBlock]:
    """Group a reinterpreted model into fused execution blocks.

    Recognized patterns (MobileNetV2 inverted residuals, §V.D):

    * ``conv1x1(s=1) -> dwconv -> conv1x1(s=1)``  (expand / dw / project)
    * ``dwconv -> conv1x1(s=1)``                  (t=1 block, no expansion)

    Interior layers must carry no ``save_as``/``residual_from`` bookkeeping
    (those are coordinator-side and require the full tensor).  ``save_as`` /
    ``residual_from`` on the *last* layer of a block is fine — the block
    output is aggregated exactly like an unfused layer's.  Everything else
    (stem conv, head conv, avgpool, linear) becomes a singleton block.
    """
    layers = model.layers
    blocks: list[FusedBlock] = []
    i = 0
    while i < len(layers):
        if (i + 2 < len(layers)
                and _is_pointwise(layers[i])
                and layers[i + 1].kind == "dwconv"
                and _is_pointwise(layers[i + 2])
                and _fusable_interior(layers[i])
                and _fusable_interior(layers[i + 1])
                and layers[i].out_shape == layers[i + 1].in_shape
                and layers[i + 1].out_shape == layers[i + 2].in_shape):
            blocks.append(FusedBlock((i, i + 1, i + 2)))
            i += 3
            continue
        if (i + 1 < len(layers)
                and layers[i].kind == "dwconv"
                and _is_pointwise(layers[i + 1])
                and _fusable_interior(layers[i])
                and layers[i].out_shape == layers[i + 1].in_shape):
            blocks.append(FusedBlock((i, i + 1)))
            i += 2
            continue
        blocks.append(FusedBlock((i,)))
        i += 1
    return blocks


def apply_activation(x, activation: str | None):
    """In-place-style fused activation (works for numpy and jax arrays)."""
    if activation is None:
        return x
    if activation == "relu":
        return x * (x > 0)
    if activation == "relu6":
        return (x * (x > 0)).clip(max=6.0)
    raise ValueError(f"unknown activation {activation!r}")
