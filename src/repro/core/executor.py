"""Split inference execution (paper §IV.D, Algorithm 4).

Layer-by-layer protocol:
  1. the coordinator routes each worker the input activations its assigned
     output neurons need (RouteM / worker_input_regions);
  2. each worker computes its assigned flat output range from its *local*
     weight fragments only;
  3. partial outputs return to the coordinator, are concatenated in flat
     order (shards are contiguous ascending ranges, so concat == aggregate),
     and become the next layer's input.

Numerics are JAX (jnp) so the same executor drives float32 and int8 (W8A8,
int32 accumulation) paths.  Workers only ever touch (a) their weight
fragments and (b) the activation slice the coordinator routed them — the
per-worker bounding-box slice of the padded input.  No worker ever holds a
full layer's weights or activations, which is the paper's memory claim; the
analytic accounting lives in core/memory.py.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .fusion import apply_activation
from .mapping import worker_input_regions
from .quantize import QuantizedModel, dequantize, quantize_activation, requantize
from .reinterpret import LayerSpec
from .splitting import LayerSplit, SplitPlan, WorkerShard


def _pad_chw(x, padding):
    ph, pw = padding
    if ph == 0 and pw == 0:
        return x
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw)))


def _conv_chw(x, w, stride, int8: bool):
    """x: (Cin, H, W) padded; w: (Cout, Cin_g, kh, kw); VALID conv."""
    lhs = x[None].astype(jnp.int32 if int8 else jnp.float32)
    rhs = w.astype(jnp.int32 if int8 else jnp.float32)
    groups = 1 if w.shape[1] == x.shape[0] else x.shape[0]
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=stride, padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32 if int8 else jnp.float32)
    return out[0]


def _worker_compute(layer: LayerSpec, shard: WorkerShard, x_pad,
                    weight, bias, int8: bool):
    """Compute the shard's flat output range using only the fragment weights
    and the routed input slice.  Returns a flat vector of len n_positions
    (raw accumulator: float32 or int32; bias added; activation NOT applied)."""
    if shard.n_positions == 0:
        dt = jnp.int32 if int8 else jnp.float32
        return jnp.zeros((0,), dt)
    c_out, h_out, w_out = layer.out_shape
    hw = h_out * w_out
    s, e = shard.start, shard.stop

    if layer.kind == "linear":
        # columns [s, e): fragment = weight[:, s:e]
        frag = weight[:, s:e]
        xv = x_pad.reshape(-1)
        acc = (xv.astype(jnp.int32) @ frag.astype(jnp.int32)) if int8 else (
            xv.astype(jnp.float32) @ frag.astype(jnp.float32))
        return acc + bias[s:e]

    # conv / dwconv: channels [c_lo, c_hi], output rows [row_lo, row_hi].
    # Single-channel shards cover a row interval; multi-channel shards use the
    # full row range (the union bbox over partial first/last channels).
    c_lo, c_hi = s // hw, (e - 1) // hw
    if c_hi > c_lo:
        row_lo, row_hi = 0, h_out - 1
    else:
        row_lo = (s - c_lo * hw) // w_out
        row_hi = (e - 1 - c_lo * hw) // w_out
    sh, sw = layer.stride
    kh, kw = layer.kernel
    in_r0 = row_lo * sh
    in_r1 = row_hi * sh + kh
    x_slice = x_pad[:, in_r0:in_r1, :]
    if layer.kind == "dwconv":
        x_slice = x_slice[c_lo:c_hi + 1]
    frag_w = weight[c_lo:c_hi + 1]
    out = _conv_chw(x_slice, frag_w, layer.stride, int8)  # (nch, rows, w_out)
    out = out + bias[c_lo:c_hi + 1][:, None, None]
    # flat-select [s, e) out of the bbox
    flat = out.reshape(-1)
    offset = c_lo * hw + row_lo * w_out  # flat index of bbox origin... per-channel!
    # bbox layout: channel-major over (c_lo..c_hi, row_lo..row_hi, w). Build
    # the index map from global flat [s,e) to bbox flat.
    idx = jnp.arange(s, e)
    c = idx // hw
    rem = idx % hw
    r = rem // w_out
    col = rem % w_out
    n_rows = row_hi - row_lo + 1
    bbox_idx = (c - c_lo) * (n_rows * w_out) + (r - row_lo) * w_out + col
    return flat[bbox_idx]


class SplitExecutor:
    """Runs Algorithm 4 over a SplitPlan.

    ``mode``: "float" (fp32) or "int8" (W8A8, requires a QuantizedModel).
    """

    def __init__(self, plan: SplitPlan, qmodel: QuantizedModel | None = None):
        self.plan = plan
        self.qmodel = qmodel

    # -- single-layer worker pass -----------------------------------------
    def _run_layer_float(self, layer: LayerSpec, split: LayerSplit, x):
        if layer.kind == "avgpool":   # coordinator-side (§IV.D aggregation)
            return jnp.mean(x, axis=(1, 2), keepdims=True)
        x_pad = _pad_chw(x, layer.padding) if layer.kind != "linear" else x
        w = jnp.asarray(layer.weight)
        b = jnp.asarray(layer.bias if layer.bias is not None
                        else np.zeros(layer.out_shape[0], np.float32))
        parts = [
            _worker_compute(layer, sh, x_pad, w, b, int8=False)
            for sh in split.shards
        ]
        y = jnp.concatenate(parts).reshape(layer.out_shape)
        return apply_activation(y, layer.activation)

    def _run_layer_int8(self, i: int, layer: LayerSpec, split: LayerSplit, x_q):
        ql = self.qmodel.layers[i]
        if layer.kind == "avgpool":
            # coordinator-side in real domain, then requantize
            xf = dequantize(np.asarray(x_q), ql.in_scale)
            y = xf.mean(axis=(1, 2), keepdims=True)
            return jnp.asarray(quantize_activation(y, ql.out_scale))
        x_pad = _pad_chw(x_q, layer.padding) if layer.kind != "linear" else x_q
        w = jnp.asarray(ql.w_q)
        b = jnp.asarray(ql.b_q.astype(np.int32))
        parts = [
            _worker_compute(layer, sh, x_pad, w, b, int8=True)
            for sh in split.shards
        ]
        acc = np.asarray(jnp.concatenate(parts))  # int32 flat
        c_of = (np.arange(layer.n_out) // (layer.out_shape[1] * layer.out_shape[2])
                if layer.kind != "linear" else np.arange(layer.n_out))
        y_q = requantize(acc, ql.in_scale, ql.w_scale, ql.out_scale,
                         layer.activation, channel_of=c_of)
        return jnp.asarray(y_q.reshape(layer.out_shape))

    # -- full-model execution ----------------------------------------------
    def run(self, x: np.ndarray, mode: str = "float",
            collect_activations: bool = False):
        """x: (C, H, W) input sample.  Returns final output (and per-layer
        activations if requested — used for calibration)."""
        model = self.plan.model
        stash: dict[str, jnp.ndarray] = {}
        acts = []
        if mode == "int8":
            if self.qmodel is None:
                raise ValueError("int8 mode requires a QuantizedModel")
            cur = jnp.asarray(quantize_activation(np.asarray(x), self.qmodel.input_scale))
        else:
            cur = jnp.asarray(x, dtype=jnp.float32)
        for i, (layer, split) in enumerate(zip(model.layers, self.plan.splits)):
            cur = cur.reshape(layer.in_shape)
            if mode == "int8":
                cur = self._run_layer_int8(i, layer, split, cur)
            else:
                cur = self._run_layer_float(layer, split, cur)
            # coordinator-side residual bookkeeping (Alg. 4 line 9)
            if layer.residual_from is not None:
                other = stash[layer.residual_from]
                if mode == "int8":
                    ql = self.qmodel.layers[i]
                    oth_scale, oth_idx = other
                    yf = dequantize(np.asarray(cur), ql.out_scale) + \
                        dequantize(np.asarray(oth_idx), oth_scale)
                    cur = jnp.asarray(quantize_activation(yf, ql.out_scale))
                else:
                    cur = cur + other
            if layer.save_as is not None:
                if mode == "int8":
                    stash[layer.save_as] = (self.qmodel.layers[i].out_scale, cur)
                else:
                    stash[layer.save_as] = cur
            if collect_activations:
                acts.append(np.asarray(cur))
        if collect_activations:
            return np.asarray(cur), acts
        return np.asarray(cur)


def reference_forward(model, x: np.ndarray, collect_activations: bool = False):
    """Monolithic single-device forward (the infeasible-on-MCU baseline the
    split execution must match numerically)."""
    stash = {}
    acts = []
    cur = jnp.asarray(x, dtype=jnp.float32)
    for layer in model.layers:
        cur = cur.reshape(layer.in_shape)
        if layer.kind == "avgpool":
            cur = jnp.mean(cur, axis=(1, 2), keepdims=True)
        elif layer.kind == "linear":
            cur = cur.reshape(-1) @ jnp.asarray(layer.weight) + jnp.asarray(layer.bias)
            cur = cur.reshape(layer.out_shape)
            cur = apply_activation(cur, layer.activation)
        else:
            x_pad = _pad_chw(cur, layer.padding)
            cur = _conv_chw(x_pad, jnp.asarray(layer.weight), layer.stride, int8=False)
            cur = cur + jnp.asarray(layer.bias)[:, None, None]
            cur = apply_activation(cur, layer.activation)
        if layer.residual_from is not None:
            cur = cur + stash[layer.residual_from]
        if layer.save_as is not None:
            stash[layer.save_as] = cur
        if collect_activations:
            acts.append(np.asarray(cur))
    if collect_activations:
        return np.asarray(cur), acts
    return np.asarray(cur)
