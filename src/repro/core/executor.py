"""Split inference execution (paper §IV.D, Algorithm 4).

Layer-by-layer protocol:
  1. the coordinator routes each worker the input activations its assigned
     output neurons need (RouteM / worker_input_regions);
  2. each worker computes its assigned flat output range from its *local*
     weight fragments only;
  3. partial outputs return to the coordinator, are concatenated in flat
     order (shards are contiguous ascending ranges, so concat == aggregate),
     and become the next layer's input.

Numerics are JAX (jnp) so the same executor drives float32 and int8 (W8A8,
int32 accumulation) paths.  Workers only ever touch (a) their weight
fragments and (b) the activation slice the coordinator routed them — the
per-worker bounding-box slice of the padded input.  No worker ever holds a
full layer's weights or activations, which is the paper's memory claim; the
analytic accounting lives in core/memory.py.

Spatial plans (``split_model(..., mode="spatial")``) change the unit of
iteration from layers to *fused blocks* (``SplitPlan.block_groups``): each
worker receives its block-input row window (band + halo), runs the whole
expand→dwconv→project chain on the band locally — the expanded hidden
activation only ever exists at band size — and only the block output is
aggregated (a static row-axis concat, since bands tile the output rows).
Residual adds and stashes stay coordinator-side at block boundaries.

Two executors share those semantics:

* :class:`SplitExecutor` — the **eager** reference oracle.  One Python-level
  dispatch per layer per shard, host sync between layers.  Faithful to the
  MCU protocol step-for-step, supports ``collect_activations`` (used for
  calibration), and is what every other path is tested against.  Use it for
  correctness work and anything that needs per-layer visibility.

* :class:`CompiledSplitExecutor` — the **compiled** engine.  At construction
  it precomputes every shard's static geometry (channel spans, bbox slices,
  routed input windows, flat index maps — :func:`mapping.compile_shard_geometry`)
  and the int8 epilogue constants, then lowers the *entire* SplitPlan into a
  single ``jax.jit``-ed function per mode: only pure jnp ops inside the
  trace, no host sync until the final output.  In int8 mode the hot ops
  route through the Pallas kernels (``kernels.dwconv`` for 3x3 depthwise,
  ``kernels.qgemm`` for conv-as-im2col and linear shards) when
  ``use_pallas`` is enabled — on by default on TPU, with a pure-jnp fallback
  elsewhere that performs the *same float32 epilogue arithmetic*, so both
  paths (and the eager oracle) agree bit-for-bit on int8.  ``run_batch``
  vmaps the traced function over a leading sample axis so serving amortizes
  compilation and dispatch across requests.  Use it for throughput: serving,
  benchmarks, batched evaluation.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import numpy as np
import jax
import jax.numpy as jnp

from .fusion import apply_activation
from .mapping import compile_shard_geometry
from .quantize import (QuantizedModel, epilogue_params,
                       quantize_activation_jnp, requantize)
from .reinterpret import LayerSpec
from .splitting import (LayerSplit, ShardGeometry, SpatialBandGeometry,
                        SplitPlan, WorkerShard, spatial_band_geometry)


def _pad_chw(x, padding):
    ph, pw = padding
    if ph == 0 and pw == 0:
        return x
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw)))


def _conv_chw(x, w, stride, int8: bool):
    """x: (Cin, H, W) padded; w: (Cout, Cin_g, kh, kw); VALID conv."""
    lhs = x[None].astype(jnp.int32 if int8 else jnp.float32)
    rhs = w.astype(jnp.int32 if int8 else jnp.float32)
    groups = 1 if w.shape[1] == x.shape[0] else x.shape[0]
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=stride, padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32 if int8 else jnp.float32)
    return out[0]


def _dwconv_bands_int32(x, w, stride):
    """Depthwise VALID conv on a band stack via kh*kw shifted int32
    products.  XLA:CPU lowers *integer* grouped convolutions to a scalar
    loop nest (seconds per call at MobileNet depths — this was the whole
    spatial int8 hot-path regression); the shifted-product form is pure
    vectorized elementwise work and bit-identical, since both accumulate
    the same int32 sum.  Mirrors the Pallas kernel's ``_accum3x3`` but for
    any kernel size, so the jnp fallback keeps the same trace shape."""
    b, c, rows, wp = x.shape
    kh, kw = w.shape[2], w.shape[3]
    sh, sw = stride
    oh = (rows - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    xi = x.astype(jnp.int32)
    wi = w.astype(jnp.int32)
    acc = jnp.zeros((b, c, oh, ow), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            win = jax.lax.slice(
                xi, (0, 0, i, j),
                (b, c, i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1),
                (1, 1, sh, sw))
            acc = acc + win * wi[:, 0, i, j][None, :, None, None]
    return acc


def _conv_bands(x, w, stride, int8: bool):
    """x: (bands, Cin, R, Wp) padded band windows; w: (Cout, Cin_g, kh, kw);
    VALID conv with the band stack as the conv batch axis — one XLA
    convolution (or shifted-product accumulation for int8 depthwise) for
    every band of a fused spatial block."""
    depthwise = w.shape[1] != x.shape[1]
    if int8 and depthwise:
        return _dwconv_bands_int32(x, w, stride)
    lhs = x.astype(jnp.int32 if int8 else jnp.float32)
    rhs = w.astype(jnp.int32 if int8 else jnp.float32)
    groups = x.shape[1] if depthwise else 1
    return jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=stride, padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32 if int8 else jnp.float32)


def _avgpool_int8(x_q, in_scale: float, out_scale: float):
    """Coordinator-side global average pool, requantized.  The spatial sum is
    exact int32; the mean + rescale collapse into a single f32 multiply so
    eager and jitted execution round identically (see quantize.epilogue_params
    for the no-float-adds contract shared by both executors)."""
    hw = x_q.shape[-2] * x_q.shape[-1]
    factor = float(in_scale) / (hw * float(out_scale))
    s = jnp.sum(x_q.astype(jnp.int32), axis=(-2, -1), keepdims=True)
    return jnp.clip(jnp.round(s.astype(jnp.float32) * factor),
                    -127, 127).astype(jnp.int8)


def _residual_add_int8(cur_q, cur_scale: float, other_q, other_scale: float):
    """Coordinator-side residual add (Alg. 4 line 9): the stashed activation
    is requantized to ``cur_scale`` (one f32 multiply + round), then added in
    exact int32.  Shared by both executors — bit-identical eager vs jitted."""
    ratio = float(other_scale) / float(cur_scale)
    r = jnp.round(other_q.astype(jnp.float32) * ratio).astype(jnp.int32)
    return jnp.clip(cur_q.astype(jnp.int32) + r, -127, 127).astype(jnp.int8)


def _spatial_stage_acc(layer: LayerSpec, geom: SpatialBandGeometry, band_in,
                       weight, bias, int8: bool):
    """One spatial-band stage: VALID conv over the explicitly padded input
    window (interior bands carry halo rows instead of padding; bands touching
    the tensor edge get real zero rows — both precomputed in ``geom``), plus
    bias.  Returns the raw accumulator (C_out, n_rows, w_out): float32, or
    exact int32 with the int32 bias already added."""
    _, pw = layer.padding
    x = jnp.pad(band_in, ((0, 0), (geom.pad_top, geom.pad_bot), (pw, pw)))
    acc = _conv_chw(x, weight, layer.stride, int8)
    return acc + bias[:, None, None]


def _worker_compute(layer: LayerSpec, shard: WorkerShard, x_pad,
                    weight, bias, int8: bool):
    """Compute the shard's flat output range using only the fragment weights
    and the routed input slice.  Returns a flat vector of len n_positions
    (raw accumulator: float32, or int32 with the int32 bias ``b_q`` already
    added — exact; activation NOT applied)."""
    if shard.n_positions == 0:
        dt = jnp.int32 if int8 else jnp.float32
        return jnp.zeros((0,), dt)
    c_out, h_out, w_out = layer.out_shape
    hw = h_out * w_out
    s, e = shard.start, shard.stop

    if layer.kind == "linear":
        # columns [s, e): fragment = weight[:, s:e]
        frag = weight[:, s:e]
        xv = x_pad.reshape(-1)
        acc = (xv.astype(jnp.int32) @ frag.astype(jnp.int32)) if int8 else (
            xv.astype(jnp.float32) @ frag.astype(jnp.float32))
        return acc + bias[s:e]

    # conv / dwconv: channels [c_lo, c_hi], output rows [row_lo, row_hi].
    # Single-channel shards cover a row interval; multi-channel shards use the
    # full row range (the union bbox over partial first/last channels).
    c_lo, c_hi = s // hw, (e - 1) // hw
    if c_hi > c_lo:
        row_lo, row_hi = 0, h_out - 1
    else:
        row_lo = (s - c_lo * hw) // w_out
        row_hi = (e - 1 - c_lo * hw) // w_out
    sh, sw = layer.stride
    kh, kw = layer.kernel
    in_r0 = row_lo * sh
    in_r1 = row_hi * sh + kh
    x_slice = x_pad[:, in_r0:in_r1, :]
    if layer.kind == "dwconv":
        x_slice = x_slice[c_lo:c_hi + 1]
    frag_w = weight[c_lo:c_hi + 1]
    out = _conv_chw(x_slice, frag_w, layer.stride, int8)  # (nch, rows, w_out)
    out = out + bias[c_lo:c_hi + 1][:, None, None]
    # flat-select [s, e) out of the bbox
    flat = out.reshape(-1)
    # bbox layout: channel-major over (c_lo..c_hi, row_lo..row_hi, w). Build
    # the index map from global flat [s,e) to bbox flat.
    idx = jnp.arange(s, e)
    c = idx // hw
    rem = idx % hw
    r = rem // w_out
    col = rem % w_out
    n_rows = row_hi - row_lo + 1
    bbox_idx = (c - c_lo) * (n_rows * w_out) + (r - row_lo) * w_out + col
    return flat[bbox_idx]


class SplitExecutor:
    """Runs Algorithm 4 over a SplitPlan, eagerly (the reference oracle).

    ``mode``: "float" (fp32) or "int8" (W8A8, requires a QuantizedModel).
    See the module docstring for when to prefer :class:`CompiledSplitExecutor`.
    """

    def __init__(self, plan: SplitPlan, qmodel: QuantizedModel | None = None):
        self.plan = plan
        self.qmodel = qmodel
        self._epilogues: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._band_geoms: dict[int, list[SpatialBandGeometry | None]] = {}

    def _epilogue(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        if i not in self._epilogues:
            self._epilogues[i] = epilogue_params(self.qmodel.layers[i])
        return self._epilogues[i]

    def _band_geometry(self, i: int) -> list[SpatialBandGeometry | None]:
        if i not in self._band_geoms:
            sp = self.plan.splits[i]
            self._band_geoms[i] = spatial_band_geometry(sp.layer, sp)
        return self._band_geoms[i]

    # -- fused spatial block (band + halo per worker) ----------------------
    def _run_block_spatial(self, idxs: tuple[int, ...], x, mode: str):
        """Run one fused block: each worker receives its block-input window
        (band + halo), executes every stage on the band locally (intermediate
        activations never materialize at full resolution), and the block
        output bands are concatenated along the row axis (bands partition the
        output rows, so concat == aggregate)."""
        model = self.plan.model
        geoms = [self._band_geometry(i) for i in idxs]
        # per-layer constants hoisted out of the worker loop (spatial mode
        # replicates full weights, so materialize each tensor once per layer,
        # not once per worker per stage)
        consts = []
        for i in idxs:
            layer = model.layers[i]
            if mode == "int8":
                ql = self.qmodel.layers[i]
                scale, b_q = self._epilogue(i)
                consts.append((jnp.asarray(ql.w_q),
                               jnp.asarray(scale)[:, None, None],
                               jnp.asarray(b_q), float(ql.out_scale)))
            else:
                b = jnp.asarray(layer.bias if layer.bias is not None
                                else np.zeros(layer.out_shape[0], np.float32))
                consts.append((jnp.asarray(layer.weight), b))
        parts = []
        for w in range(self.plan.n_workers):
            g_last = geoms[-1][w]
            if g_last is None:
                continue
            band = None
            for li, i in enumerate(idxs):
                layer = model.layers[i]
                g = geoms[li][w]
                if g is None:
                    # degenerate interior stage: downstream rows come entirely
                    # from padding, so this stage's band is empty — emit a
                    # zero-height band for the next stage to pad against.
                    c_out, _, w_out = layer.out_shape
                    dt = jnp.int8 if mode == "int8" else jnp.float32
                    band = jnp.zeros((c_out, 0, w_out), dt)
                    continue
                if li == 0:
                    # the coordinator routes the block-input window only
                    band = x[:, g.in_lo:g.in_hi, :]
                if mode == "int8":
                    w_q, scale_b, b_j, out_scale = consts[li]
                    acc = _spatial_stage_acc(layer, g, band, w_q, b_j,
                                             int8=True)
                    band = requantize(acc, scale_b, out_scale,
                                      layer.activation)
                else:
                    wt, b = consts[li]
                    acc = _spatial_stage_acc(layer, g, band, wt, b,
                                             int8=False)
                    band = apply_activation(acc, layer.activation)
            parts.append(band)
        return jnp.concatenate(parts, axis=1)

    # -- single-layer worker pass -----------------------------------------
    def _run_layer_float(self, layer: LayerSpec, split: LayerSplit, x):
        if layer.kind == "avgpool":   # coordinator-side (§IV.D aggregation)
            return jnp.mean(x, axis=(1, 2), keepdims=True)
        x_pad = _pad_chw(x, layer.padding) if layer.kind != "linear" else x
        w = jnp.asarray(layer.weight)
        b = jnp.asarray(layer.bias if layer.bias is not None
                        else np.zeros(layer.out_shape[0], np.float32))
        parts = [
            _worker_compute(layer, sh, x_pad, w, b, int8=False)
            for sh in split.shards
        ]
        y = jnp.concatenate(parts).reshape(layer.out_shape)
        return apply_activation(y, layer.activation)

    def _run_layer_int8(self, i: int, layer: LayerSpec, split: LayerSplit, x_q):
        ql = self.qmodel.layers[i]
        if layer.kind == "avgpool":
            return _avgpool_int8(x_q, ql.in_scale, ql.out_scale)
        x_pad = _pad_chw(x_q, layer.padding) if layer.kind != "linear" else x_q
        w = jnp.asarray(ql.w_q)
        scale, b_q = self._epilogue(i)
        b = jnp.asarray(b_q)
        parts = [
            _worker_compute(layer, sh, x_pad, w, b, int8=True)
            for sh in split.shards
        ]
        acc = jnp.concatenate(parts)  # int32 flat, bias included (exact)
        if layer.kind != "linear":
            hw = layer.out_shape[1] * layer.out_shape[2]
            scale = scale[np.arange(layer.n_out) // hw]
        y_q = requantize(acc, jnp.asarray(scale), float(ql.out_scale),
                         layer.activation)
        return y_q.reshape(layer.out_shape)

    # -- full-model execution ----------------------------------------------
    def run(self, x: np.ndarray, mode: str = "float",
            collect_activations: bool = False):
        """x: (C, H, W) input sample.  Returns final output (and per-layer
        activations if requested — used for calibration)."""
        if mode not in ("float", "int8"):
            raise ValueError(f"unknown mode {mode!r} (want 'float' or 'int8')")
        if collect_activations and any(sp.mode == "spatial"
                                       for sp in self.plan.splits):
            raise ValueError(
                "collect_activations is unsupported with spatial(-assigned) "
                "blocks (fused interior activations never materialize); "
                "calibrate with reference_forward or a flat-mode plan")
        model = self.plan.model
        stash: dict[str, jnp.ndarray] = {}
        acts = []
        if mode == "int8":
            if self.qmodel is None:
                raise ValueError("int8 mode requires a QuantizedModel")
            cur = quantize_activation_jnp(jnp.asarray(x),
                                          self.qmodel.input_scale)
        else:
            cur = jnp.asarray(x, dtype=jnp.float32)
        for idxs in self.plan.block_groups:
            i = idxs[-1]
            layer = model.layers[i]
            cur = cur.reshape(model.layers[idxs[0]].in_shape)
            if self.plan.splits[idxs[0]].mode == "spatial":
                cur = self._run_block_spatial(idxs, cur, mode)
            elif mode == "int8":
                cur = self._run_layer_int8(i, layer, self.plan.splits[i], cur)
            else:
                cur = self._run_layer_float(layer, self.plan.splits[i], cur)
            # coordinator-side residual bookkeeping (Alg. 4 line 9) — fused
            # blocks carry it only on their output layer (fusion.group_blocks)
            if layer.residual_from is not None:
                other = stash[layer.residual_from]
                if mode == "int8":
                    ql = self.qmodel.layers[i]
                    oth_scale, oth_q = other
                    cur = _residual_add_int8(cur, ql.out_scale, oth_q, oth_scale)
                else:
                    cur = cur + other
            if layer.save_as is not None:
                if mode == "int8":
                    stash[layer.save_as] = (self.qmodel.layers[i].out_scale, cur)
                else:
                    stash[layer.save_as] = cur
            if collect_activations:
                acts.append(np.asarray(cur))
        if collect_activations:
            return np.asarray(cur), acts
        return np.asarray(cur)


# ---------------------------------------------------------------------------
# Compiled engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BandedStage:
    """Static row-gather geometry of one stage of a fused spatial block in
    the batched-band layout (all host-side numpy, computed once per block).

    ``src_rows[b, t]`` is the source row feeding window row ``t`` of band
    ``b`` — a *global* input row for the block's first stage (the one
    host-side gather per block boundary), a band-local row of the previous
    stage's output otherwise.  ``mask`` marks which window rows carry real
    data: everything else (explicit zero padding at the tensor edge, and the
    fill that equalizes heterogeneous band heights to the common window
    height) is zeroed in one ``where``.  Rows a band does not own come out of
    the stage as garbage and are dropped by the next gather (or the final
    output gather), so a single uniform grid covers every band height."""

    index: int                      # layer index in the model
    src_rows: np.ndarray            # (bands, R_win) int32, masked-safe
    mask: np.ndarray                # (bands, 1, R_win, 1) bool
    r_out: int                      # conv output rows at the common height


@dataclasses.dataclass(frozen=True)
class _BandedBlock:
    """One fused spatial block compiled to the batched-band schedule: the
    active band order (concat order == ascending worker id), the per-stage
    gather geometry, and the static map from global output rows to
    (band, local row) realizing the final row-axis aggregation as one take."""

    idxs: tuple[int, ...]
    bands: tuple[int, ...]          # active worker ids, band-stack order
    stages: tuple[_BandedStage, ...]
    out_flat: np.ndarray            # (H_out,) int: band * r_out_last + row


def _compile_banded_block(model, idxs: tuple[int, ...],
                          geoms: list[list[SpatialBandGeometry | None]],
                          ) -> _BandedBlock:
    """Lower one fused spatial block's per-band geometry into the static
    batched-band schedule (see :class:`_BandedStage`).  Pure host-side numpy;
    the traced executor consumes the result as constants."""
    active = [w for w in range(len(geoms[-1])) if geoms[-1][w] is not None]
    n_bands = len(active)
    stages: list[_BandedStage] = []
    for li, i in enumerate(idxs):
        layer = model.layers[i]
        kh, _ = layer.kernel
        sh, _ = layer.stride
        win: list[tuple[int, int, int, int]] = []
        for wk in active:
            g = geoms[li][wk]
            if g is None:
                win.append((0, 0, 0, 0))
            else:
                n_src = g.in_hi - g.in_lo
                win.append((g.pad_top, n_src,
                            g.pad_top + n_src + g.pad_bot, g.in_lo))
        # common window height; >= kh so the batched VALID conv is always
        # well-formed even when every band of an interior stage is empty
        r_win = max(max((t[2] for t in win), default=0), kh)
        src = np.zeros((n_bands, r_win), np.int32)
        mask = np.zeros((n_bands, 1, r_win, 1), bool)
        for b, (pad_top, n_src, _, in_lo) in enumerate(win):
            if n_src <= 0:
                continue
            t = np.arange(pad_top, pad_top + n_src)
            # first stage gathers from the block input (global rows); later
            # stages gather band-local rows of the previous stage's output
            src[b, t] = (in_lo if li == 0 else 0) + np.arange(n_src)
            mask[b, 0, t, 0] = True
        stages.append(_BandedStage(i, src, mask, (r_win - kh) // sh + 1))
    last = model.layers[idxs[-1]]
    h_out = last.out_shape[1]
    out_flat = np.zeros(h_out, np.int32)
    r_out_last = stages[-1].r_out
    for b, wk in enumerate(active):
        g = geoms[-1][wk]
        out_flat[g.row_lo:g.row_hi] = b * r_out_last + np.arange(g.n_rows)
    return _BandedBlock(tuple(idxs), tuple(active), tuple(stages), out_flat)


def _plan_fingerprint(plan: SplitPlan, qmodel: QuantizedModel | None) -> str:
    """Content digest of a plan's compiled identity: layer structure, weights
    (plus quantized constants when present), shard geometry per split, and
    the fused-block grouping.  Plans with equal fingerprints lower to
    identical traced functions, so compiled executables can be shared across
    executor instances (``CompiledSplitExecutor._fn_cache``) — e.g. across a
    re-plan that reproduced the same :class:`ShardGeometry`."""
    h = hashlib.sha256()

    def _arr(a) -> None:
        if a is None:
            h.update(b"\x00none")
        else:
            a = np.ascontiguousarray(a)
            h.update(str((a.dtype.str, a.shape)).encode())
            h.update(a.tobytes())

    for lyr in plan.model.layers:
        h.update(repr((lyr.kind, lyr.in_shape, lyr.out_shape, lyr.kernel,
                       lyr.stride, lyr.padding, lyr.activation, lyr.save_as,
                       lyr.residual_from)).encode())
        _arr(lyr.weight)
        _arr(lyr.bias)
    if qmodel is not None:
        h.update(repr(float(qmodel.input_scale)).encode())
        for ql in qmodel.layers:
            _arr(ql.w_q)
            _arr(ql.b_q)
            _arr(ql.w_scale)
            h.update(repr((float(ql.in_scale), float(ql.out_scale))).encode())
    h.update(repr((plan.mode, plan.block_groups, plan.group_modes)).encode())
    for sp in plan.splits:
        if sp.mode == "spatial":
            h.update(repr([(s.row_lo, s.row_hi, s.in_lo, s.in_hi)
                           for s in sp.shards]).encode())
        else:
            h.update(repr([(s.start, s.stop) for s in sp.shards]).encode())
    return h.hexdigest()


def _kernel_eligible_dwconv(layer: LayerSpec) -> bool:
    """The Pallas dwconv kernel covers exactly MobileNet-style depthwise
    convs: 3x3, SAME padding 1, square stride."""
    return (layer.kind == "dwconv" and layer.kernel == (3, 3)
            and layer.padding == (1, 1)
            and layer.stride[0] == layer.stride[1])


class CompiledSplitExecutor:
    """Lowers a whole :class:`SplitPlan` into one jitted function per mode.

    All shard geometry (channel spans, routed input windows, bbox offsets)
    is precomputed host-side via :func:`mapping.compile_shard_geometry`; the
    traced function contains only static slices and pure jnp/Pallas ops, so
    a full forward pass is a single XLA dispatch with no host round-trips.

    Parameters
    ----------
    plan, qmodel:
        As for :class:`SplitExecutor`.
    use_pallas:
        Route int8 dwconv/conv/linear shards through the Pallas kernels
        (``kernels.dwconv``, ``kernels.qgemm``).  ``None`` auto-detects:
        enabled on TPU, disabled elsewhere (where the pure-jnp fallback is
        faster than interpret-mode Pallas but computes the identical result).
    interpret:
        Forwarded to the kernels when ``use_pallas`` is active (``None``
        auto-detects; pass ``True`` to exercise the kernel path on CPU).

    ``run``/``run_batch`` accept float inputs in both modes; int8 mode
    quantizes on-device inside the trace.  ``collect_activations`` is not
    supported — use the eager :class:`SplitExecutor` for calibration.
    """

    def __init__(self, plan: SplitPlan, qmodel: QuantizedModel | None = None,
                 *, use_pallas: bool | None = None,
                 interpret: bool | None = None):
        self.plan = plan
        self.qmodel = qmodel
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        self._geometry: list[list[ShardGeometry | None]] = [
            compile_shard_geometry(sp.layer, sp) for sp in plan.splits]
        self._band_geometry: dict[int, list[SpatialBandGeometry | None]] = {
            i: spatial_band_geometry(sp.layer, sp)
            for i, sp in enumerate(plan.splits) if sp.mode == "spatial"}
        self._int8_cache: dict[int, tuple] = {}
        self._banded_cache: dict[tuple[int, ...], _BandedBlock] = {}
        self._fingerprint_cache: str | None = None
        self._save_scale: dict[str, float] = {}
        if qmodel is not None:
            for i, layer in enumerate(plan.model.layers):
                if layer.save_as is not None:
                    self._save_scale[layer.save_as] = float(
                        qmodel.layers[i].out_scale)
        self._fns: dict[str, callable] = {}
        self._batch_fns: dict[str, callable] = {}

    # -- traced per-layer bodies ------------------------------------------
    def _layer_float(self, i: int, layer: LayerSpec, split: LayerSplit, cur):
        if layer.kind == "avgpool":
            return jnp.mean(cur, axis=(1, 2), keepdims=True)
        if layer.kind == "linear":
            w = jnp.asarray(layer.weight)
            b = jnp.asarray(layer.bias if layer.bias is not None
                            else np.zeros(layer.out_shape[0], np.float32))
            xv = cur.reshape(-1).astype(jnp.float32)
            parts = [xv @ w[:, sh.start:sh.stop] + b[sh.start:sh.stop]
                     for sh in split.shards if sh.n_positions]
            y = jnp.concatenate(parts).reshape(layer.out_shape)
            return apply_activation(y, layer.activation)
        w = jnp.asarray(layer.weight)
        b = jnp.asarray(layer.bias if layer.bias is not None
                        else np.zeros(layer.out_shape[0], np.float32))
        x_pad = _pad_chw(cur, layer.padding)
        parts = []
        for g in self._geometry[i]:
            if g is None:
                continue
            x_s = x_pad[:, g.in_r0:g.in_r1, :]
            if layer.kind == "dwconv":
                x_s = x_s[g.c_lo:g.c_hi + 1]
            out = _conv_chw(x_s, w[g.c_lo:g.c_hi + 1], layer.stride,
                            int8=False)
            out = out + b[g.c_lo:g.c_hi + 1][:, None, None]
            flat = out.reshape(-1)
            parts.append(flat[g.bbox_start:g.bbox_start + g.n_positions])
        y = jnp.concatenate(parts).reshape(layer.out_shape)
        return apply_activation(y, layer.activation)

    def _layer_int8(self, i: int, layer: LayerSpec, split: LayerSplit, cur):
        ql = self.qmodel.layers[i]
        if layer.kind == "avgpool":
            return _avgpool_int8(cur, ql.in_scale, ql.out_scale)
        scale, b_q = epilogue_params(ql)
        scale_j, b_j = jnp.asarray(scale), jnp.asarray(b_q)
        out_scale = float(ql.out_scale)
        w_q = jnp.asarray(ql.w_q)

        if layer.kind == "linear":
            xv = cur.reshape(-1)
            parts = []
            for sh in split.shards:
                if not sh.n_positions:
                    continue
                s, e = sh.start, sh.stop
                if self.use_pallas:
                    from ..kernels.qgemm.ops import qgemm_padded
                    y = qgemm_padded(xv[None, :], w_q[:, s:e], scale_j[s:e],
                                     b_j[s:e], activation=layer.activation,
                                     out_scale=out_scale,
                                     interpret=self.interpret)[0]
                else:
                    acc = xv.astype(jnp.int32) @ w_q[:, s:e].astype(jnp.int32)
                    y = requantize(acc + b_j[s:e], scale_j[s:e], out_scale,
                                   layer.activation)
                parts.append(y)
            return jnp.concatenate(parts).reshape(layer.out_shape)

        c_out, h_out, w_out = layer.out_shape
        hw = h_out * w_out
        geoms = [g for g in self._geometry[i] if g is not None]

        if self.use_pallas and _kernel_eligible_dwconv(layer):
            from ..kernels.dwconv.ops import dwconv
            parts = []
            for g in geoms:
                y = dwconv(cur[g.c_lo:g.c_hi + 1],
                           w_q[g.c_lo:g.c_hi + 1, 0],
                           scale_j[g.c_lo:g.c_hi + 1],
                           b_j[g.c_lo:g.c_hi + 1],
                           stride=layer.stride[0],
                           activation=layer.activation, out_scale=out_scale,
                           interpret=self.interpret)
                # the kernel computes the fragment's full rows: the shard's
                # flat range starts at g.start - c_lo*hw in the fragment
                flat = y.reshape(-1)
                off = g.start - g.c_lo * hw
                parts.append(flat[off:off + g.n_positions])
            return jnp.concatenate(parts).reshape(layer.out_shape)

        if self.use_pallas and layer.kind == "conv":
            from ..kernels.qgemm.ops import im2col, qgemm_padded
            patches, _ = im2col(cur, layer.kernel, layer.stride, layer.padding)
            w2 = w_q.reshape(c_out, -1).T         # (Cin*kh*kw, Cout) int8
            parts = []
            for g in geoms:
                y = qgemm_padded(patches, w2[:, g.c_lo:g.c_hi + 1],
                                 scale_j[g.c_lo:g.c_hi + 1],
                                 b_j[g.c_lo:g.c_hi + 1],
                                 activation=layer.activation,
                                 out_scale=out_scale,
                                 interpret=self.interpret)
                flat = y.T.reshape(-1)            # fragment full rows, CHW
                off = g.start - g.c_lo * hw
                parts.append(flat[off:off + g.n_positions])
            return jnp.concatenate(parts).reshape(layer.out_shape)

        # pure-jnp fallback: same int32 accumulation (bias included, exact)
        # + float32 multiply-only epilogue as the kernels — bit-identical
        x_pad = _pad_chw(cur, layer.padding)
        parts = []
        for g in geoms:
            x_s = x_pad[:, g.in_r0:g.in_r1, :]
            if layer.kind == "dwconv":
                x_s = x_s[g.c_lo:g.c_hi + 1]
            acc = _conv_chw(x_s, w_q[g.c_lo:g.c_hi + 1], layer.stride,
                            int8=True)
            acc = acc + b_j[g.c_lo:g.c_hi + 1][:, None, None]
            flat = acc.reshape(-1)
            parts.append(flat[g.bbox_start:g.bbox_start + g.n_positions])
        acc = jnp.concatenate(parts)
        c_of = np.arange(layer.n_out) // hw
        y = requantize(acc, jnp.asarray(scale[c_of]), out_scale,
                       layer.activation)
        return y.reshape(layer.out_shape)

    # -- traced fused spatial block ----------------------------------------
    def _int8_consts(self, i: int):
        """Per-layer int8 constants (replicated weights, epilogue scale/bias).
        The cache holds only host-side numpy values: jnp conversion must
        happen freshly inside each trace, because an array materialized while
        tracing one batch shape is a tracer-backed constant that poisons the
        next shape's trace (UnexpectedTracerError on re-jit).  Callers hoist
        the returned jnp arrays per layer, so each trace still carries one
        copy per layer — not one per worker band."""
        if i not in self._int8_cache:
            ql = self.qmodel.layers[i]
            scale, b_q = epilogue_params(ql)
            self._int8_cache[i] = (ql.w_q, scale, b_q, float(ql.out_scale))
        w_q, scale, b_q, out_scale = self._int8_cache[i]
        return jnp.asarray(w_q), jnp.asarray(scale), jnp.asarray(b_q), out_scale

    def _banded_block(self, idxs: tuple[int, ...]) -> _BandedBlock:
        key = tuple(idxs)
        if key not in self._banded_cache:
            geoms = [self._band_geometry[i] for i in idxs]
            self._banded_cache[key] = _compile_banded_block(
                self.plan.model, key, geoms)
        return self._banded_cache[key]

    def _banded_stage_int8(self, layer: LayerSpec, xw, consts):
        """One batched-band int8 stage over the gathered windows ``xw``
        ((bands, C_in, R, W + 2*pw), zero rows in place): the Pallas kernels
        when enabled — ``dwconv3x3_bands`` puts the band index on the kernel
        grid; conv stages fold bands into the qgemm M axis via
        ``im2col_bands`` — else one batched-conv jnp fallback.  Identical
        int32 accumulation and multiply-only epilogue on every path, so all
        agree bit-for-bit with the eager oracle."""
        w_q, scale_j, b_j, out_scale = consts
        c_out, _, w_out = layer.out_shape
        if self.use_pallas and _kernel_eligible_dwconv(layer):
            from ..kernels.dwconv.ops import dwconv_bands
            return dwconv_bands(xw, w_q[:, 0], scale_j, b_j,
                                stride=layer.stride[0],
                                activation=layer.activation,
                                out_scale=out_scale,
                                interpret=self.interpret)
        if self.use_pallas and layer.kind == "conv":
            from ..kernels.qgemm.ops import im2col_bands, qgemm_padded
            patches, (oh, ow) = im2col_bands(xw, layer.kernel, layer.stride)
            w2 = w_q.reshape(c_out, -1).T
            y = qgemm_padded(patches, w2, scale_j, b_j,
                             activation=layer.activation, out_scale=out_scale,
                             interpret=self.interpret)
            return y.reshape(xw.shape[0], oh, ow, c_out).transpose(0, 3, 1, 2)
        acc = _conv_bands(xw, w_q, layer.stride, int8=True)
        acc = acc + b_j[:, None, None]
        return requantize(acc, scale_j[:, None, None], out_scale,
                          layer.activation)

    def _block_spatial(self, idxs: tuple[int, ...], cur, mode: str):
        """Fused spatial block inside the trace, batched over bands: every
        stage executes ALL workers' bands as one kernel/conv invocation on a
        (bands, C, rows, W) stack (heterogeneous band heights zero-filled to
        the common window height; the expanded hidden still only exists at
        band size).  The block-boundary halo gather happens once, against the
        block input; interior stages re-gather band-locally from the previous
        stage's stack.  One static take aggregates the output rows."""
        model = self.plan.model
        bb = self._banded_block(idxs)
        n_bands = len(bb.bands)
        x = None
        for li, st in enumerate(bb.stages):
            layer = model.layers[st.index]
            _, pw = layer.padding
            if mode == "int8":
                consts = self._int8_consts(st.index)
            else:
                lyr = layer
                consts = (jnp.asarray(lyr.weight),
                          jnp.asarray(lyr.bias if lyr.bias is not None
                                      else np.zeros(lyr.out_shape[0],
                                                    np.float32)))
            src = jnp.asarray(st.src_rows)
            mask = jnp.asarray(st.mask)
            if li == 0:
                # the one host-side halo gather per block boundary: band +
                # halo windows of every worker, straight from the block input
                xw = jnp.take(cur, src.reshape(-1), axis=1)
                xw = xw.reshape(cur.shape[0], n_bands, -1, cur.shape[2])
                xw = xw.transpose(1, 0, 2, 3)
            else:
                xw = jnp.take_along_axis(x, src[:, None, :, None], axis=2)
            xw = jnp.where(mask, xw, jnp.zeros((), xw.dtype))
            if pw:
                xw = jnp.pad(xw, ((0, 0), (0, 0), (0, 0), (pw, pw)))
            if mode == "int8":
                x = self._banded_stage_int8(layer, xw, consts)
            else:
                wt, b = consts
                acc = _conv_bands(xw, wt, layer.stride, int8=False)
                acc = acc + b[:, None, None]
                x = apply_activation(acc, layer.activation)
        # (bands, C, r_out, W) -> one static row gather aggregates the bands
        y = x.transpose(1, 0, 2, 3).reshape(
            x.shape[1], n_bands * x.shape[2], x.shape[3])
        return jnp.take(y, jnp.asarray(bb.out_flat), axis=1)

    # -- plan lowering ------------------------------------------------------
    def _build(self, mode: str):
        if mode not in ("float", "int8"):
            raise ValueError(f"unknown mode {mode!r} (want 'float' or 'int8')")
        if mode == "int8" and self.qmodel is None:
            raise ValueError("int8 mode requires a QuantizedModel")
        model = self.plan.model

        def fn(x):
            if mode == "int8":
                cur = quantize_activation_jnp(x, self.qmodel.input_scale)
            else:
                cur = jnp.asarray(x, jnp.float32)
            stash: dict[str, jnp.ndarray] = {}
            for idxs in self.plan.block_groups:
                i = idxs[-1]
                layer = model.layers[i]
                cur = cur.reshape(model.layers[idxs[0]].in_shape)
                if self.plan.splits[idxs[0]].mode == "spatial":
                    cur = self._block_spatial(idxs, cur, mode)
                elif mode == "int8":
                    cur = self._layer_int8(i, layer, self.plan.splits[i], cur)
                else:
                    cur = self._layer_float(i, layer, self.plan.splits[i], cur)
                if layer.residual_from is not None:
                    if mode == "int8":
                        cur = _residual_add_int8(
                            cur, float(self.qmodel.layers[i].out_scale),
                            stash[layer.residual_from],
                            self._save_scale[layer.residual_from])
                    else:
                        cur = cur + stash[layer.residual_from]
                if layer.save_as is not None:
                    stash[layer.save_as] = cur
            return cur

        return fn

    # -- compiled-executable cache ------------------------------------------
    # Jitted plan functions are shared ACROSS executor instances keyed on the
    # full static identity of the computation: weights digest + shard/band
    # geometry + mode + pallas flags.  jax.jit then specializes per batch
    # bucket under each cached callable, so a re-plan (or Session.warmup)
    # with unchanged geometry skips re-tracing entirely — the hit/miss
    # counters make the saved trace cost visible to the bench.
    _fn_cache: "collections.OrderedDict[tuple, callable]" = \
        collections.OrderedDict()
    _fn_cache_max = 64
    _fn_cache_hits = 0
    _fn_cache_misses = 0

    @property
    def fingerprint(self) -> str:
        """Content digest of everything the traced function closes over:
        model weights (and quantized constants in int8 plans) plus the full
        shard/band geometry of the plan.  Two executors with equal
        fingerprints compute identical functions, so their jitted
        executables are interchangeable."""
        if self._fingerprint_cache is None:
            self._fingerprint_cache = _plan_fingerprint(self.plan, self.qmodel)
        return self._fingerprint_cache

    @classmethod
    def cache_stats(cls) -> dict[str, int]:
        return dict(size=len(cls._fn_cache), hits=cls._fn_cache_hits,
                    misses=cls._fn_cache_misses)

    @classmethod
    def cache_clear(cls) -> None:
        cls._fn_cache.clear()
        cls._fn_cache_hits = 0
        cls._fn_cache_misses = 0

    def _cached_fn(self, mode: str, batched: bool):
        key = (self.fingerprint, mode, batched,
               self.use_pallas, self.interpret)
        cls = CompiledSplitExecutor
        fn = cls._fn_cache.get(key)
        if fn is None:
            cls._fn_cache_misses += 1
            fn = self._build(mode)
            fn = jax.jit(jax.vmap(fn)) if batched else jax.jit(fn)
            cls._fn_cache[key] = fn
            while len(cls._fn_cache) > cls._fn_cache_max:
                cls._fn_cache.popitem(last=False)
        else:
            cls._fn_cache_hits += 1
            cls._fn_cache.move_to_end(key)
        return fn

    def _fn(self, mode: str):
        if mode not in self._fns:
            self._fns[mode] = self._cached_fn(mode, batched=False)
        return self._fns[mode]

    def _batch_fn(self, mode: str):
        if mode not in self._batch_fns:
            self._batch_fns[mode] = self._cached_fn(mode, batched=True)
        return self._batch_fns[mode]

    # -- public API ---------------------------------------------------------
    def run(self, x: np.ndarray, mode: str = "float") -> np.ndarray:
        """x: (C, H, W) float input sample (int8 mode quantizes on-device)."""
        return np.asarray(self._fn(mode)(jnp.asarray(x, jnp.float32)))

    def run_batch(self, xs: np.ndarray, mode: str = "float") -> np.ndarray:
        """xs: (B, C, H, W) float batch; returns (B, *out_shape).  One XLA
        dispatch for the whole batch (vmap over the traced plan)."""
        return np.asarray(self._batch_fn(mode)(jnp.asarray(xs, jnp.float32)))

    def run_batch_async(self, xs: np.ndarray, mode: str = "float"):
        """Like :meth:`run_batch` but returns the un-forced device array:
        jax dispatch is asynchronous, so the caller can overlap host work
        (forming the next micro-batch) with this batch's compute and force
        later via ``np.asarray``.  The continuous-batching serving layer's
        in-flight dispatch seam."""
        return self._batch_fn(mode)(jnp.asarray(xs, jnp.float32))

    def warmup(self, input_shape=None, batch: int | None = None,
               mode: str = "float") -> None:
        """Force compilation ahead of serving (zeros input)."""
        shape = tuple(input_shape or self.plan.model.input_shape)
        if batch is None:
            self.run(np.zeros(shape, np.float32), mode)
        else:
            self.run_batch(np.zeros((batch, *shape), np.float32), mode)


def reference_forward(model, x: np.ndarray, collect_activations: bool = False):
    """Monolithic single-device forward (the infeasible-on-MCU baseline the
    split execution must match numerically)."""
    stash = {}
    acts = []
    cur = jnp.asarray(x, dtype=jnp.float32)
    for layer in model.layers:
        cur = cur.reshape(layer.in_shape)
        if layer.kind == "avgpool":
            cur = jnp.mean(cur, axis=(1, 2), keepdims=True)
        elif layer.kind == "linear":
            cur = cur.reshape(-1) @ jnp.asarray(layer.weight) + jnp.asarray(layer.bias)
            cur = cur.reshape(layer.out_shape)
            cur = apply_activation(cur, layer.activation)
        else:
            x_pad = _pad_chw(cur, layer.padding)
            cur = _conv_chw(x_pad, jnp.asarray(layer.weight), layer.stride, int8=False)
            cur = cur + jnp.asarray(layer.bias)[:, None, None]
            cur = apply_activation(cur, layer.activation)
        if layer.residual_from is not None:
            cur = cur + stash[layer.residual_from]
        if layer.save_as is not None:
            stash[layer.save_as] = cur
        if collect_activations:
            acts.append(np.asarray(cur))
    if collect_activations:
        return np.asarray(cur), acts
    return np.asarray(cur)
