"""Shared cost-model layer for the plan search: memoized candidate evaluation.

Every axis of the planner's search space — mode × fusion × worker subset ×
transport, plus the per-block mixing DP — bottoms out in the same analytic
cost model: build the split geometry, run :func:`simulator.simulate` for the
timing decomposition, :func:`memory.peak_ram_per_worker` for the RAM gate.
That evaluation is pure in (model, worker parameters, ratings, mode, fusion,
caps, sim config), so this module hoists it behind a :class:`CostCache`:

* **across candidates** — the beam search revisits subsets the prefix
  ladder already costed; a cache hit skips geometry + simulate entirely;
* **across objectives** — uniform-mode evaluations are independent of
  ``Objective.minimize`` (the score is recomputed from cached metrics), so
  a ``comm_bytes`` search reuses a ``latency`` search's table;
* **across successive replans** — keys fingerprint worker *parameters*,
  not cluster indices, so an :class:`~repro.runtime.elastic.ElasticCluster`
  that loses one worker re-plans over survivor subsets it has already
  costed (the warm-replan path measured by the churn drill and the
  ``search`` bench section).

One :func:`simulate` call covers both transports (a pipelined
:class:`~repro.core.simulator.SimResult` always carries the serial Eq. 5-6
decomposition in its ``layer_*`` arrays), so a cached evaluation serves any
``Objective.transports`` subset byte-identically.

:class:`SearchStats` is the per-search telemetry (candidates evaluated,
cache hit rate, wall) surfaced on :meth:`repro.api.Plan.report`,
``SessionStats`` and the elastic transition reports.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from .allocation import WorkerParams, redistribute_overflow
from .memory import peak_ram_per_worker
from .mixed import MixedInfeasible, search_mixed_assignment
from .simulator import SimConfig, simulate

__all__ = ["CostCache", "SearchStats", "CandidateEval", "EvalVariant",
           "evaluate_candidate", "worker_fingerprint", "subset_fingerprint",
           "config_fingerprint", "prefix_subset_grid"]


def worker_fingerprint(w: WorkerParams) -> tuple:
    """A worker's cost-model identity: its parameters, not its index.
    Two physically distinct workers with equal parameters are
    interchangeable to the analytic model, and replans over survivor
    subsets must hit entries cached under the full cluster."""
    return (float(w.f_mhz), float(w.d_s_per_kb), float(w.b_kb_s),
            int(w.ram_bytes), int(w.flash_bytes))


def subset_fingerprint(workers) -> tuple:
    return tuple(worker_fingerprint(w) for w in workers)


def config_fingerprint(cfg: SimConfig) -> tuple:
    """SimConfig identity *excluding transport*: one evaluation covers both
    transports (see module docstring), so transport must not split keys."""
    return (float(cfg.cycles_per_mac), float(cfg.flash_ns_per_mac),
            int(cfg.itemsize), bool(cfg.overlap),
            float(cfg.coordinator_bw_kb_s))


def _model_token(model) -> tuple:
    # id() is stable for the lifetime of the model object — the unit a
    # cache is scoped to (a Planner or an ElasticCluster holds one model).
    # The structural extras guard against id reuse after collection.
    return (id(model), len(model.layers), int(model.total_macs()))


class CostCache:
    """LRU memo for candidate evaluations (and the mixing DP's block-cost
    tables / per-subset Kc coefficients that feed them).

    Deliberately dumb: a bounded ``OrderedDict`` with cumulative hit/miss
    counters.  Per-search deltas are tracked by the caller
    (:class:`SearchStats`), so one persistent cache can serve many searches
    — the ElasticCluster keeps a single instance across replans.
    """

    def __init__(self, max_entries: int = 4096):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._data: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key):
        """The cached value, or None (cached values are never None)."""
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)

    def get_or(self, key, builder):
        value = self.get(key)
        if value is None:
            value = builder()
            self.put(key, value)
        return value

    def clear(self) -> None:
        self._data.clear()


@dataclasses.dataclass
class SearchStats:
    """Telemetry of one plan search (or replan).

    ``candidates_evaluated`` counts (subset × mode × fusion) cost-model
    evaluations *requested*; ``cache_hits`` of those were served from the
    :class:`CostCache` without rebuilding geometry or simulating
    (``cache_misses`` ran the full model).  ``subsets_explored`` counts
    distinct worker subsets (ladder prefixes + beam-discovered).
    """

    candidates_evaluated: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    subsets_explored: int = 0
    beam_width: int | None = None
    search_wall_s: float = 0.0

    @property
    def cache_hit_rate(self) -> float:
        if self.candidates_evaluated == 0:
            return 0.0
        return self.cache_hits / self.candidates_evaluated

    def to_dict(self) -> dict:
        return {"candidates_evaluated": self.candidates_evaluated,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_hit_rate": round(self.cache_hit_rate, 6),
                "subsets_explored": self.subsets_explored,
                "beam_width": self.beam_width,
                "search_wall_s": round(self.search_wall_s, 6)}


@dataclasses.dataclass(frozen=True)
class EvalVariant:
    """One concrete assembled split of a feasible candidate.  Uniform
    candidates have exactly one; a mixed candidate may carry two when the
    serial-surrogate and transport-aware DP disagree on the assignment
    (the planner re-ranks them under the exact simulated metrics)."""

    ratings: np.ndarray             # post-Eq.7 ratings the split was built on
    split: object                   # core SplitPlan
    peak: np.ndarray                # per-worker analytic peak (int8 gate)
    weights: np.ndarray             # per-worker weight bytes
    assignment: tuple | None        # mixed: per-block mode vector
    block_workers: tuple | None     # mixed: per-block worker subsets
    total_bytes: int
    # transport -> (latency_s, comp_s, comm_s, overlap_saved_s); both
    # transports always present (derived from one pipelined simulate)
    metrics: dict


@dataclasses.dataclass(frozen=True)
class CandidateEval:
    """Cached result of one (subset, mode, fusion) evaluation."""

    feasible: bool
    reason: str | None = None
    variants: tuple = ()
    max_peak_ram: int = 0
    max_weight_bytes: int = 0
    # infeasible mixed candidates: the DP's best cap-ignoring assignment and
    # which block's cap bound it (surfaced in InfeasibleError.details)
    assignment: tuple | None = None
    detail: dict | None = None


def prefix_subset_grid(n: int, extra: int | None) -> tuple:
    """Per-block worker-subset choices for the mixing DP: ``None`` (all
    workers) plus up to ``extra`` rating-prefix subsets — the top-1 worker
    first, then geometrically growing prefixes (1, 2, 4, ...).  The DP's
    ratings order the prefix; here the choices are expressed as sizes and
    resolved against the rating order by the DP itself."""
    if not extra or n <= 1:
        return (None,)
    sizes = []
    s = 1
    while s < n and len(sizes) < extra:
        sizes.append(s)
        s *= 2
    return (None,) + tuple(sizes)


def _simulate_metrics(model, workers, ratings, split, cfg: SimConfig):
    """One pipelined simulate; both transports' (latency, comp, comm, saved)
    derived from it — byte-identical to simulating each separately, because
    a pipelined SimResult's layer_* arrays *are* the serial decomposition."""
    pcfg = dataclasses.replace(cfg, transport="pipelined")
    res = simulate(model, workers, ratings, pcfg, plan=split,
                   compute_peak=False)
    serial_total = res.serial_total_time
    serial_comp = float(res.layer_comp.sum())
    metrics = {
        "pipelined": (res.total_time, res.comp_time, res.comm_time,
                      res.overlap_saved_s),
        "serial": (serial_total, serial_comp, serial_total - serial_comp,
                   0.0),
    }
    return metrics, res.total_bytes


def evaluate_candidate(model, workers, base_ratings: np.ndarray, mode: str,
                       fusion: str, *, ram_caps: np.ndarray,
                       flash_caps: np.ndarray, model_bytes: float,
                       cfg: SimConfig, minimize: str = "latency",
                       mixed_subsets: int | None = None,
                       mixed_transport_dp: bool = False,
                       cache: CostCache | None = None,
                       stats: SearchStats | None = None) -> CandidateEval:
    """Evaluate one (subset, mode, fusion) point of the search space.

    This is the planner's former ``_score_one`` cost-model body, hoisted so
    it can be memoized: the result depends only on the arguments (worker
    *parameters*, not identities), never on the Objective's transports or —
    for uniform modes — its ``minimize``.  ``build_split_plan`` is bypassed
    on a cache hit; scoring against a particular objective stays with the
    caller, reading the cached per-transport metrics.
    """
    from ..api.plan import build_split_plan

    if stats is not None:
        stats.candidates_evaluated += 1
    key = None
    if cache is not None:
        key = ("cand", _model_token(model), subset_fingerprint(workers),
               tuple(float(r) for r in np.asarray(base_ratings)),
               mode, fusion,
               tuple(float(c) for c in np.asarray(ram_caps)),
               tuple(float(c) for c in np.asarray(flash_caps)),
               config_fingerprint(cfg),
               (minimize, mixed_subsets, mixed_transport_dp)
               if mode == "mixed" else None)
        hit = cache.get(key)
        if hit is not None:
            if stats is not None:
                stats.cache_hits += 1
            return hit
    if stats is not None:
        stats.cache_misses += 1

    def _done(ev: CandidateEval) -> CandidateEval:
        if cache is not None:
            cache.put(key, ev)
        return ev

    ratings = base_ratings
    if mode in ("neuron", "kernel"):
        # Eq. 7: shift rating mass away from storage-overflowed workers
        # (weights are split in these modes, so shares track ratings)
        if flash_caps.sum() < model_bytes:
            return _done(CandidateEval(
                feasible=False,
                reason=(f"flash_cap: total capacity {flash_caps.sum():.0f} B"
                        f" < model {model_bytes:.0f} B")))
    searches = [(None, None)]            # (assignment, block_workers)
    try:
        if mode in ("neuron", "kernel"):
            ratings = redistribute_overflow(base_ratings, flash_caps,
                                            model_bytes)
        if mode == "mixed":
            # DP over block boundaries (core.mixed), exact for the serial
            # cost model; optionally a second pass under the pipelined-seam
            # surrogate — when the two disagree, both assignments become
            # variants and the caller's exact simulated metrics re-rank.
            grid = prefix_subset_grid(len(workers), mixed_subsets)
            s0 = search_mixed_assignment(
                model, workers, ratings, cfg, minimize=minimize,
                ram_caps=ram_caps, subset_choices=grid, cache=cache)
            searches = [(s0.assignment, s0.block_workers)]
            if mixed_transport_dp:
                s1 = search_mixed_assignment(
                    model, workers, ratings, cfg, minimize=minimize,
                    ram_caps=ram_caps, subset_choices=grid, cache=cache,
                    transport="pipelined")
                if (s1.assignment, s1.block_workers) not in searches:
                    searches.append((s1.assignment, s1.block_workers))
    except MixedInfeasible as e:
        return _done(CandidateEval(
            feasible=False,
            reason=(f"ram_cap: mixed block {e.block} "
                    f"(layers {list(e.block_indices)}) peak {e.peak_bytes} B"
                    f" > cap {e.cap_bytes} B on worker {e.worker}"),
            max_peak_ram=int(e.peak_bytes),
            assignment=e.best_assignment,
            detail={"block": e.block,
                    "block_layers": list(e.block_indices),
                    "worker": e.worker,
                    "peak_bytes": int(e.peak_bytes),
                    "cap_bytes": int(e.cap_bytes),
                    "best_infeasible_assignment":
                        list(e.best_assignment) if e.best_assignment else None}))
    except (ValueError, RuntimeError) as e:
        return _done(CandidateEval(
            feasible=False,
            reason=f"split_error: {type(e).__name__}: {e}"))

    variants = []
    worst_peak, worst_weight, reasons = 0, 0, []
    for assignment, block_workers in searches:
        try:
            split = build_split_plan(model, ratings, mode, fusion,
                                     assignment=assignment,
                                     block_workers=block_workers)
            peak = peak_ram_per_worker(split)
        except (ValueError, RuntimeError) as e:
            # a mode that cannot even build a split for these workers is an
            # explicit infeasible candidate, not a search-aborting crash
            reasons.append(f"split_error: {type(e).__name__}: {e}")
            continue
        weights = np.array([split.worker_weight_bytes(w)
                            for w in range(split.n_workers)], dtype=np.int64)
        worst_peak = max(worst_peak, int(peak.max()))
        worst_weight = max(worst_weight, int(weights.max()))
        over_ram = peak > ram_caps
        over_flash = weights > flash_caps
        if over_ram.any() or over_flash.any():
            terms = []
            if over_ram.any():
                w = int(np.argmax(peak / ram_caps))
                terms.append(f"ram_cap: worker {w} peak {int(peak[w])} B "
                             f"> cap {int(ram_caps[w])} B")
            if over_flash.any():
                w = int(np.argmax(weights / flash_caps))
                terms.append(f"flash_cap: worker {w} weights "
                             f"{int(weights[w])} B > cap "
                             f"{int(flash_caps[w])} B")
            reasons.append("; ".join(terms))
            continue
        metrics, total_bytes = _simulate_metrics(model, workers, ratings,
                                                 split, cfg)
        variants.append(EvalVariant(
            ratings=ratings, split=split, peak=peak, weights=weights,
            assignment=assignment, block_workers=block_workers,
            total_bytes=total_bytes, metrics=metrics))
    if not variants:
        return _done(CandidateEval(
            feasible=False, reason="; ".join(reasons) or "split_error: empty",
            max_peak_ram=worst_peak, max_weight_bytes=worst_weight,
            assignment=searches[0][0]))
    return _done(CandidateEval(feasible=True, variants=tuple(variants)))
