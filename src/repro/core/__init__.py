"""The paper's contribution: fine-grained split CNN inference for networked
MCUs — reinterpretation, sub-layer splitting, cross-layer activation mapping,
resource-aware allocation, split execution, and the scaling simulator."""
from .allocation import (WorkerParams, allocate, band_bounds, band_heights,
                         capability_rating, execution_time,
                         proportional_allocation, ratings_evenly, ratings_for,
                         ratings_freq_only, redistribute_overflow)
from .executor import CompiledSplitExecutor, SplitExecutor, reference_forward
from .fusion import (BatchNormParams, FusedBlock, apply_activation,
                     fold_batchnorm, group_blocks)
from .mapping import (assignm_bruteforce, comm_volume, compile_shard_geometry,
                      routem_bruteforce, worker_input_regions)
from .memory import layerwise_peak, peak_ram_per_worker, plan_memory, single_device_peak
from .quantize import (QuantizedModel, calibrate_scales, epilogue_params,
                       quantize_model, requantize)
from .reinterpret import LayerSpec, ReinterpretedModel, layer_macs, trace_sequential
from .simulator import (ModeReport, SimConfig, SimResult, compare_modes,
                        measured_kc, simulate, simulated_k1)
from .splitting import (LayerSplit, ShardGeometry, SpatialBandGeometry,
                        SpatialShard, SplitPlan, WorkerShard, partition_bounds,
                        spatial_band_geometry, split_layer, split_model)

__all__ = [n for n in dir() if not n.startswith("_")]
