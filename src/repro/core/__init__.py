"""The paper's contribution: fine-grained split CNN inference for networked
MCUs — reinterpretation, sub-layer splitting, cross-layer activation mapping,
resource-aware allocation, split execution, and the scaling simulator.

These free functions are the underlying engine; the supported entry point
for planning + serving is the coordinator facade in :mod:`repro.api`
(``Cluster`` / ``Planner`` / ``Session``).  Hand-wiring the pipeline
(``simulated_k1`` → ``measured_kc`` → ``ratings_for`` → ``split_model`` →
``peak_ram_per_worker`` → executor → ``simulate``) still works but is
considered deprecated for application code — ``Planner.plan`` runs the same
pipeline, adds feasibility checking, and returns a serializable plan."""
from .allocation import (WorkerParams, allocate, band_bounds, band_heights,
                         capability_rating, execution_time,
                         proportional_allocation, ratings_evenly, ratings_for,
                         ratings_freq_only, redistribute_overflow)
from .executor import CompiledSplitExecutor, SplitExecutor, reference_forward
from .fusion import (BatchNormParams, FusedBlock, apply_activation,
                     fold_batchnorm, group_blocks)
from .mapping import (assignm_bruteforce, comm_volume, compile_shard_geometry,
                      routem_bruteforce, worker_input_regions)
from .memory import (layerwise_peak, peak_ram_per_worker, plan_memory,
                     single_device_peak, split_memory)
from .mixed import MixedInfeasible, MixedSearch, search_mixed_assignment
from .quantize import (QuantizedModel, calibrate_scales, epilogue_params,
                       quantize_model, requantize)
from .reinterpret import LayerSpec, ReinterpretedModel, layer_macs, trace_sequential
from .search import (CandidateEval, CostCache, EvalVariant, SearchStats,
                     evaluate_candidate)
from .simulator import (TRANSPORTS, ModeReport, SimConfig, SimResult,
                        Timeline, TimelineEvent, compare_modes, measured_kc,
                        simulate, simulated_k1)
from .splitting import (LayerSplit, ShardGeometry, SpatialBandGeometry,
                        SpatialShard, SplitPlan, WorkerShard, partition_bounds,
                        spatial_band_geometry, split_layer, split_model,
                        split_model_mixed)

# Explicit public API only — a computed dir()-based __all__ also exported
# the imported submodule objects (allocation, executor, ...), polluting
# `from repro.core import *` and shadowing same-named locals downstream.
__all__ = [
    # allocation (paper §V, Eq. 1-7)
    "WorkerParams",
    "allocate",
    "band_bounds",
    "band_heights",
    "capability_rating",
    "execution_time",
    "proportional_allocation",
    "ratings_evenly",
    "ratings_for",
    "ratings_freq_only",
    "redistribute_overflow",
    # executors (Alg. 4)
    "CompiledSplitExecutor",
    "SplitExecutor",
    "reference_forward",
    # fusion (§V.D)
    "BatchNormParams",
    "FusedBlock",
    "apply_activation",
    "fold_batchnorm",
    "group_blocks",
    # cross-layer activation mapping (Alg. 3)
    "assignm_bruteforce",
    "comm_volume",
    "compile_shard_geometry",
    "routem_bruteforce",
    "worker_input_regions",
    # memory model (§IV.B, Fig. 8/12)
    "layerwise_peak",
    "peak_ram_per_worker",
    "plan_memory",
    "single_device_peak",
    "split_memory",
    # per-block mode-mixing search (DP over block boundaries)
    "MixedInfeasible",
    "MixedSearch",
    "search_mixed_assignment",
    # shared cost-model/search layer (memoized candidate evaluation)
    "CandidateEval",
    "CostCache",
    "EvalVariant",
    "SearchStats",
    "evaluate_candidate",
    # quantization (§V.D)
    "QuantizedModel",
    "calibrate_scales",
    "epilogue_params",
    "quantize_model",
    "requantize",
    # reinterpretation (§IV.A)
    "LayerSpec",
    "ReinterpretedModel",
    "layer_macs",
    "trace_sequential",
    # simulator (§VII.D + async transport)
    "TRANSPORTS",
    "ModeReport",
    "SimConfig",
    "SimResult",
    "Timeline",
    "TimelineEvent",
    "compare_modes",
    "measured_kc",
    "simulate",
    "simulated_k1",
    # splitting (Alg. 1/2 + spatial bands)
    "LayerSplit",
    "ShardGeometry",
    "SpatialBandGeometry",
    "SpatialShard",
    "SplitPlan",
    "WorkerShard",
    "partition_bounds",
    "spatial_band_geometry",
    "split_layer",
    "split_model",
    "split_model_mixed",
]
