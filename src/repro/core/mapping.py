"""Cross-layer activation mapping (paper §IV.C, Algorithm 3).

Two implementations, tested against each other:

* :func:`assignm_bruteforce` / :func:`routem_bruteforce` — the *literal*
  Algorithm 3: iterate every output position of layer ``i+1``, trace its
  receptive field with ``get_input()``, OR worker bits into ``AssignM``;
  then walk layer ``i``'s producer shards and emit ``RouteM`` entries.
  O(total MACs) — used for small layers and as the test oracle.

* :func:`worker_input_regions` — the scalable closed form.  Because shards
  are contiguous flat ranges (Alg. 1), the union of receptive fields of a
  shard decomposes into, per touched channel-group, per output row, one input
  column interval.  This gives identical point sets to brute force (property
  tested) at O(rows) cost instead of O(neurons·k²·Cin).

Byte accounting derived from these mappings drives both the simulator's
communication model (Eq. 1's f(W)) and the peak-RAM model (paper Fig. 8).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .reinterpret import LayerSpec
from .splitting import LayerSplit, ShardGeometry, SpatialShard


# ---------------------------------------------------------------------------
# Literal Algorithm 3 (test oracle; small layers)
# ---------------------------------------------------------------------------

def assignm_bruteforce(layer: LayerSpec, split: LayerSplit) -> np.ndarray:
    """Stage 1 of Alg. 3: bitmask over *input* positions of ``layer`` marking
    which workers (computing ``layer``'s outputs) need each input activation."""
    if split.mode == "spatial":
        raise ValueError("assignm_bruteforce operates on flat-range shards; "
                         "spatial bands are covered by worker_input_regions")
    ci, hi, wi = layer.in_shape
    assign_m = np.zeros((ci, hi, wi), dtype=np.int64)
    c_out, h_out, w_out = layer.out_shape
    hw = h_out * w_out
    for shard in split.shards:
        bit = np.int64(1) << np.int64(shard.worker)
        for j in range(shard.start, shard.stop):
            c = j // hw
            h = (j % hw) // w_out
            w = j % w_out
            for (cc, hh, ww) in layer.get_input(c, h, w):
                assign_m[cc, hh, ww] |= bit
    return assign_m


def routem_bruteforce(prev_split: LayerSplit, assign_m: np.ndarray) -> list[tuple[int, int]]:
    """Stage 2 of Alg. 3: for each producer worker of the previous layer, the
    (producer, consumer-bitmask) pairs for every activation it produced."""
    flat = assign_m.reshape(-1)
    route_m: list[tuple[int, int]] = []
    for shard in prev_split.shards:
        for j in range(shard.start, shard.stop):
            route_m.append((shard.worker, int(flat[j])))
    return route_m


# ---------------------------------------------------------------------------
# Scalable region form
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputRegion:
    """Input activations a worker needs: per channel-interval, per input row,
    a list of disjoint column intervals.  Channels half-open [c_lo, c_hi)."""

    c_lo: int
    c_hi: int
    # row -> list of (col_lo, col_hi) disjoint, sorted, half-open intervals
    row_intervals: dict[int, list[tuple[int, int]]]

    @property
    def n_points(self) -> int:
        per_ch = sum(hi - lo for ivs in self.row_intervals.values()
                     for (lo, hi) in ivs)
        return int((self.c_hi - self.c_lo) * per_ch)

    def bounding_slices(self) -> tuple[slice, slice, slice]:
        """Channel/row/col bounding box (used by the executor to slice the
        activation tensor it is routed — a contiguous buffer, as an MCU would
        receive).

        **Over-approximation contract:** the bbox is the smallest *contiguous*
        window covering the region, not the region itself.  For layers with
        ``stride > kernel`` the receptive rows/cols of a shard have gaps, and
        the bbox silently includes the gap rows — its volume can exceed
        :attr:`n_points`.  Byte accounting (``comm_volume``, ``plan_memory``)
        must therefore always use :attr:`n_points` (exact) and never the bbox
        volume; the bbox is only a slicing convenience for code paths that
        tolerate routing a superset (see ``bbox_points`` and the
        gap-regression tests in ``tests/test_mixed.py``)."""
        rows = sorted(self.row_intervals)
        lo = min(iv[0] for ivs in self.row_intervals.values() for iv in ivs)
        hi = max(iv[1] for ivs in self.row_intervals.values() for iv in ivs)
        return (slice(self.c_lo, self.c_hi),
                slice(rows[0], rows[-1] + 1), slice(lo, hi))

    @property
    def bbox_points(self) -> int:
        """Volume of :meth:`bounding_slices` — ``>= n_points``, with strict
        inequality whenever the region has row/col gaps (stride > kernel).
        Kept distinct from ``n_points`` so no caller can conflate the routed
        superset with the exact byte count."""
        cs, rs, ws = self.bounding_slices()
        return ((cs.stop - cs.start) * (rs.stop - rs.start)
                * (ws.stop - ws.start))

    def point_set(self) -> set[tuple[int, int, int]]:
        pts = set()
        for c in range(self.c_lo, self.c_hi):
            for r, ivs in self.row_intervals.items():
                for (lo, hi) in ivs:
                    for w in range(int(lo), int(hi)):
                        pts.add((c, int(r), w))
        return pts


def _merge_intervals(ivs: list[tuple[int, int]]) -> list[tuple[int, int]]:
    ivs = sorted(ivs)
    out: list[tuple[int, int]] = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _rows_cols_for_flat_range(layer: LayerSpec, start: int, stop: int) -> list[tuple[int, int, int, int]]:
    """Decompose flat output range [start, stop) into per-channel
    (channel, h_lo, h_hi, full_row_mask) pieces, then to (h, w_lo, w_hi)
    output spans.  Returns list of (out_row, out_col_lo, out_col_hi, channel).
    """
    c_out, h_out, w_out = layer.out_shape
    hw = h_out * w_out
    spans: list[tuple[int, int, int, int]] = []
    j = start
    while j < stop:
        c = j // hw
        within = j - c * hw
        row = within // w_out
        col = within % w_out
        # how far can we run within this row?
        row_end_flat = c * hw + (row + 1) * w_out
        run_end = min(stop, row_end_flat)
        spans.append((row, col, col + (run_end - j), c))
        j = run_end
    return spans


def worker_input_regions(layer: LayerSpec, split: LayerSplit) -> list[list[InputRegion]]:
    """For every worker computing ``layer``, the exact input regions required
    (union of receptive fields of its assigned output positions)."""
    ci, hi_in, wi_in = layer.in_shape
    out: list[list[InputRegion]] = []
    for shard in split.shards:
        regions: list[InputRegion] = []
        if isinstance(shard, SpatialShard):
            # spatial band: all input channels x the band's receptive-field
            # row window (band + halo) x full width.  For fused interior
            # layers this window is produced locally rather than routed, but
            # it is resident worker RAM either way — and it is where the halo
            # duplication shows up in the peak-RAM accounting.
            if shard.n_positions > 0 and shard.in_hi > shard.in_lo:
                regions.append(InputRegion(
                    0, ci,
                    {r: [(0, wi_in)]
                     for r in range(shard.in_lo, shard.in_hi)}))
            out.append(regions)
            continue
        if shard.n_positions > 0:
            if layer.kind in ("linear", "avgpool"):
                regions.append(InputRegion(
                    0, ci, {r: [(0, wi_in)] for r in range(hi_in)}))
            else:
                # group output spans: per-channel for dwconv (channel-local
                # receptive field), all-channel for dense conv.
                spans = _rows_cols_for_flat_range(layer, shard.start, shard.stop)
                per_key: dict[tuple[int, int], list[tuple[int, int, int]]] = {}
                for (row, w_lo, w_hi, c) in spans:
                    key = (c, c + 1) if layer.kind == "dwconv" else (0, ci)
                    per_key.setdefault(key, []).append((row, w_lo, w_hi))
                _, sw = layer.stride
                _, kw = layer.kernel
                for (c_lo, c_hi), row_spans in per_key.items():
                    col_map: dict[int, list[tuple[int, int]]] = {}
                    for (row, w_lo, w_hi) in row_spans:
                        r_lo, r_hi = layer.input_rows_for_output_rows(row, row)
                        if sw > kw:
                            # stride gaps: footprints of adjacent output cols
                            # are disjoint — one interval per output column
                            ivs = [layer.input_cols_for_output_cols(j, j)
                                   for j in range(w_lo, w_hi)]
                        else:
                            ivs = [layer.input_cols_for_output_cols(w_lo, w_hi - 1)]
                        for r in range(r_lo, r_hi):
                            col_map.setdefault(r, []).extend(ivs)
                    col_map = {r: _merge_intervals(ivs)
                               for r, ivs in col_map.items()}
                    regions.append(InputRegion(c_lo, c_hi, col_map))
        out.append(regions)
    return out


def compile_shard_geometry(layer: LayerSpec,
                           split: LayerSplit) -> list[ShardGeometry | None]:
    """Precompute each conv/dwconv shard's static geometry (paper Alg. 3
    made static): channel span, output-row interval, routed padded-input row
    window, and the flat map from the global output range into the shard's
    bounding box.  Entries are ``None`` for empty shards and for layer kinds
    whose shards carry no spatial geometry (linear / avgpool).

    This is the host-side half of the compiled executor: everything here is
    data-independent, so the traced function consumes only the resulting
    Python ints (static slices) and constant index arrays.

    Spatial-mode splits carry banded geometry instead — see
    :func:`splitting.spatial_band_geometry`; entries here are ``None``.
    """
    if layer.kind not in ("conv", "dwconv") or split.mode == "spatial":
        return [None] * len(split.shards)
    c_out, h_out, w_out = layer.out_shape
    hw = h_out * w_out
    sh, _ = layer.stride
    kh, _ = layer.kernel
    out: list[ShardGeometry | None] = []
    for shard in split.shards:
        if shard.n_positions == 0:
            out.append(None)
            continue
        s, e = shard.start, shard.stop
        c_lo, c_hi = s // hw, (e - 1) // hw
        if c_hi > c_lo:
            # union bbox over partial first/last channels spans all rows
            row_lo, row_hi = 0, h_out - 1
        else:
            row_lo = (s - c_lo * hw) // w_out
            row_hi = (e - 1 - c_lo * hw) // w_out
        in_r0 = row_lo * sh
        in_r1 = row_hi * sh + kh
        idx = np.arange(s, e)
        c = idx // hw
        rem = idx % hw
        r = rem // w_out
        col = rem % w_out
        n_rows = row_hi - row_lo + 1
        bbox_index = (c - c_lo) * (n_rows * w_out) + (r - row_lo) * w_out + col
        # shards are contiguous ascending ranges, so the bbox map is a
        # contiguous run (ShardGeometry.bbox_start relies on this)
        assert np.array_equal(bbox_index,
                              np.arange(len(bbox_index)) + bbox_index[0])
        out.append(ShardGeometry(shard.worker, s, e, int(c_lo), int(c_hi),
                                 int(row_lo), int(row_hi), int(in_r0),
                                 int(in_r1), bbox_index))
    return out


@dataclasses.dataclass(frozen=True)
class CommVolume:
    """Bytes moved between layers (through the coordinator, §VI.B).

    ``upload_bytes`` is indexed by *producer* worker id (length = the
    previous split's worker count); ``download_bytes`` by *consumer* worker
    id (length = this split's worker count).  The two arrays may differ in
    length when adjacent splits cover different worker sets — mixed plans
    with per-block subsets are the common case."""

    upload_bytes: np.ndarray       # per producer worker: outputs sent up
    download_bytes: np.ndarray     # per consumer worker: inputs sent down
    duplication: float             # Σ download / unique activation bytes

    @property
    def total_bytes(self) -> int:
        return int(self.upload_bytes.sum() + self.download_bytes.sum())


def comm_volume(prev_split: LayerSplit | None, layer: LayerSpec,
                split: LayerSplit, itemsize: int = 1) -> CommVolume:
    """Coordinator-routed traffic for one layer boundary.

    * upload: each producer sends each of its outputs once to the coordinator
      (layer ``i`` outputs). For the first layer (prev_split None) upload=0.
    * download: each consumer receives exactly its input region (AssignM-
      driven); overlap across consumers is duplicated traffic — the effect
      that makes communication dominate at higher worker counts (Fig. 9/10).

    Fused spatial blocks only exchange at block boundaries: a layer that is
    not ``block_first`` downloads nothing (its input band is produced
    locally by the previous fused stage) and a producer that is not
    ``block_last`` uploads nothing (its output never leaves the worker).

    ``upload_bytes`` is sized by the *producer* split's worker count and
    ``download_bytes`` by the *consumer* split's — adjacent splits may cover
    worker sets of different sizes (per-block subsets in mixed plans), and
    sizing the upload array by the consumer would index producer worker ids
    out of (or silently into the wrong slot of) a consumer-sized array.
    """
    # no producer for the first layer: keep consumer width so the all-zero
    # upload row still broadcasts into per-worker accumulators
    up = np.zeros(len(prev_split.shards) if prev_split is not None
                  else len(split.shards), dtype=np.int64)
    if prev_split is not None and prev_split.block_last:
        for shard in prev_split.shards:
            up[shard.worker] += shard.n_positions * itemsize
    down = np.zeros(len(split.shards), dtype=np.int64)
    if split.block_first:
        regions = worker_input_regions(layer, split)
        for wkr, regs in enumerate(regions):
            down[wkr] = sum(r.n_points for r in regs) * itemsize
    unique = layer.n_in * itemsize
    dup = float(down.sum()) / unique if unique else 0.0
    return CommVolume(up, down, dup)
