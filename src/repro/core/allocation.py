"""Resource-aware workload allocation (paper §V, Eq. 1-7).

Capability ratings combine computation speed and communication overhead
(Eq. 5); workload is allocated proportionally (Eq. 6); storage overflow is
redistributed iteratively while preserving the rating sum (Eq. 7).

Units follow the paper: ``f`` in MHz, workload ``W`` in Mcycles, ``d`` in
seconds/KB, ``B`` in KB/s, ``K1`` in KB/Mcycle.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkerParams:
    """Measured characteristics of one worker MCU (collected at deployment
    initialization, §III Pipeline)."""

    f_mhz: float = 600.0          # clock frequency
    d_s_per_kb: float = 0.0       # per-KB communication delay
    b_kb_s: float = 11500.0       # bandwidth (KB/s); Teensy 4.1 ~100 Mbps
    ram_bytes: int = 512 * 1024   # usable RAM budget (peak constraint)
    flash_bytes: int = 8 * 1024 * 1024  # weight-fragment storage limit


def execution_time(w_mcycles: float, p: WorkerParams, k1: float, kc: float) -> float:
    """Eq. 1: t = W/f + (d + 1/B) * f(W), with f(W) = K1*Kc*W (Eq. 2)."""
    comm_kb = k1 * kc * w_mcycles
    return w_mcycles / p.f_mhz + (p.d_s_per_kb + 1.0 / p.b_kb_s) * comm_kb


def capability_rating(p: WorkerParams, k1: float, kc: float) -> float:
    """Eq. 5: R = f*K1 / ((d + 1/B) * f * K1 * Kc + 1).

    R is the KB of output data the MCU can produce per second, accounting for
    the communication it must perform to do so.  kc=0 (no communication)
    degenerates to pure compute throughput f*K1.
    """
    fk1 = p.f_mhz * k1
    return fk1 / ((p.d_s_per_kb + 1.0 / p.b_kb_s) * fk1 * kc + 1.0)


def ratings_for(workers: list[WorkerParams], k1: float,
                kc: float | np.ndarray) -> np.ndarray:
    kcs = np.broadcast_to(np.asarray(kc, dtype=np.float64), (len(workers),))
    return np.array([capability_rating(p, k1, float(k)) for p, k in zip(workers, kcs)])


def proportional_allocation(ratings: np.ndarray, total_size: float) -> np.ndarray:
    """Eq. 6: S_i = R_i * S_m / sum(R)."""
    ratings = np.asarray(ratings, dtype=np.float64)
    return ratings * total_size / ratings.sum()


def redistribute_overflow(ratings: np.ndarray, capacities: np.ndarray,
                          total_size: float, max_iter: int = 1000) -> np.ndarray:
    """Eq. 7: iteratively move overflowed rating mass to workers with spare
    storage, preserving sum(R).

    For an over-capacity worker: R_io = (S_i - S_it) * sum(R) / S_m; the
    overflow is redistributed *evenly* among workers with remaining capacity
    (paper: "to avoid excessive load imbalance").  Repeats until all weight
    fragments fit.  Raises if total capacity < total_size (infeasible).
    """
    ratings = np.asarray(ratings, dtype=np.float64).copy()
    capacities = np.asarray(capacities, dtype=np.float64)
    if capacities.sum() < total_size:
        raise ValueError(
            f"infeasible: total capacity {capacities.sum():.0f} B < model {total_size:.0f} B")
    total_r = ratings.sum()
    for _ in range(max_iter):
        sizes = proportional_allocation(ratings, total_size)
        over = sizes > capacities + 1e-9
        if not over.any():
            break
        overflow_r = np.where(over, (sizes - capacities) * total_r / total_size, 0.0)
        ratings -= overflow_r
        # redistribute evenly among workers with remaining storage capacity
        has_room = ~over & (sizes < capacities - 1e-9)
        if not has_room.any():
            # every worker is at/over capacity but the sum fits: pin each
            # over-capacity worker exactly at capacity and give the rest
            # proportionally to the remainder.
            has_room = ~over
            if not has_room.any():
                raise RuntimeError("redistribution failed to converge")
        ratings[has_room] += overflow_r.sum() / has_room.sum()
    else:
        raise RuntimeError("redistribution failed to converge")
    assert abs(ratings.sum() - total_r) < 1e-6 * max(total_r, 1.0), "rating sum not preserved"
    return ratings


def allocate(workers: list[WorkerParams], k1: float, kc: float | np.ndarray,
             model_bytes: float) -> tuple[np.ndarray, np.ndarray]:
    """Full §V pipeline: ratings -> proportional sizes -> overflow fix.

    Returns (adjusted_ratings, per_worker_bytes).
    """
    r = ratings_for(workers, k1, kc)
    caps = np.array([p.flash_bytes for p in workers], dtype=np.float64)
    r = redistribute_overflow(r, caps, model_bytes)
    return r, proportional_allocation(r, model_bytes)


# Spatial (patch) partitioning ------------------------------------------------

def band_bounds(ratings: np.ndarray, n_rows: int) -> np.ndarray:
    """Contiguous output-row bands proportional to capability ratings — Eq. 6
    applied to the spatial axis instead of the neuron axis (the allocation
    half of ``mode="spatial"``; splitting.py turns these bounds into per-layer
    banded shards with halos).

    Returns ``bounds`` of length N+1 with bounds[0]=0, bounds[-1]=n_rows,
    within one unit of the exact proportional share.  This cumulative
    rounding is the single partition rule for every axis —
    ``splitting.partition_bounds`` delegates here for flat neuron/kernel
    ranges too.
    """
    ratings = np.asarray(ratings, dtype=np.float64)
    if np.any(ratings < 0):
        raise ValueError("ratings must be non-negative")
    s = ratings.sum()
    if s <= 0:
        raise ValueError("at least one rating must be positive")
    cum = np.cumsum(ratings) / s
    bounds = np.concatenate([[0], np.round(cum * n_rows).astype(np.int64)])
    bounds[-1] = n_rows
    return np.maximum.accumulate(bounds)


def band_heights(ratings: np.ndarray, n_rows: int) -> np.ndarray:
    """Per-worker band heights (rows) from capability ratings."""
    return np.diff(band_bounds(ratings, n_rows))


# Baselines used in Table II --------------------------------------------------

def ratings_evenly(workers: list[WorkerParams]) -> np.ndarray:
    """'Evenly' baseline: uniform split."""
    return np.ones(len(workers), dtype=np.float64)


def ratings_freq_only(workers: list[WorkerParams]) -> np.ndarray:
    """'Freq.-only' baseline: proportional to clock frequency."""
    return np.array([p.f_mhz for p in workers], dtype=np.float64)
