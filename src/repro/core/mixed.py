"""Per-block mode-mixing search: dynamic programming over fused-block
boundaries.

The compare-modes data (``simulator.compare_modes``) shows spatial
partitioning winning the early high-resolution stages while the channel
modes (kernel/neuron) win the late channel-heavy stages — the regime split
MCUNetV2 exploits by running only the initial stage patch-based.  A
heterogeneous :class:`~repro.core.splitting.SplitPlan`
(:func:`~repro.core.splitting.split_model_mixed`) lets every fused block
pick its own mode; this module picks the assignment.

The search is exact for the serial (Eq. 5-6) cost model because that model
decomposes over block boundaries: a layer's download time depends only on
its own block's mode, its compute on its own block's mode, and the upload
it overlaps with only on the *previous* block's mode.  So the optimal
assignment is a shortest path over states ``(block, mode, subset)`` with
transition cost

    boundary(b, s' -> s) = t_down(first layer of b under s)
                           + combine(max_comp(first layer under s),
                                     t_up(last layer of b-1 under s'))
    intra(b, s)          = Σ interior-layer serial totals under s

(``combine`` = max under §V.D eager-upload overlap, sum without), exactly
the per-layer arithmetic of :func:`simulator.simulate` — the DP's predicted
latency equals ``simulate(plan=mixed_plan).serial_total_time`` bit-for-bit
(property-tested).  ``comm_bytes`` and ``peak_ram`` objectives use the same
skeleton with sum/max accumulation; both are separable per block, so the DP
degenerates to a per-block argmin there.

Two optional state extensions (both off by default, keeping the default
call byte-identical to the original serial DP):

* ``subset_choices`` widens each block's states with rating-prefix worker
  subsets: a late channel-heavy block may run on the top-1 or top-2 workers
  only, trading parallel compute for less boundary traffic.  The boundary
  arithmetic stays exactly decomposable because ``comm_volume`` download
  bytes depend only on the consumer split and upload bytes only on the
  producer split (excluded workers hold empty shards — the
  ``split_model_mixed(block_workers=...)`` mechanism).

* ``transport="pipelined"`` swaps the DP objective's coordinator-serialized
  link sums for per-link maxima — a surrogate for the pipelined transport,
  where links drain in parallel (``simulator._pipelined_timeline``).  The
  surrogate ranks assignments for pipelined deployment; it is *not* the
  exact makespan (cross-boundary overlap is global), so callers re-rank the
  returned assignment against the serial DP's under the exact simulator
  (``core.search.evaluate_candidate`` does).  ``predicted_latency_s`` is
  always the exact serial total of the chosen assignment.

Per-worker RAM caps prune the state space: a state whose analytic per-worker
peak exceeds any cap is never entered, so the returned assignment is
peak-feasible by construction (flash feasibility — a *sum* across blocks per
worker — is checked by the caller on the assembled plan).  When some block
has no cap-feasible state at all, :class:`MixedInfeasible` (a ``ValueError``)
reports which block's cap bound the search and the best cap-ignoring
assignment, so the planner's ``InfeasibleError`` can name the binding
constraint with real numbers instead of a bare message.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import WorkerParams
from .fusion import group_blocks
from .mapping import comm_volume
from .memory import split_memory
from .reinterpret import ReinterpretedModel, macs_for_positions
from .simulator import SimConfig, _comp_seconds
from .splitting import (MODES, LayerSplit, split_block_spatial, split_layer)

MINIMIZE_TARGETS = ("latency", "comm_bytes", "peak_ram")
DP_TRANSPORTS = ("serial", "pipelined")


class MixedInfeasible(ValueError):
    """Some fused block has no cap-feasible (mode, subset) state.

    Carries the binding block's identity and numbers plus the DP's best
    *cap-ignoring* assignment, so the planner can report what the search
    would have chosen and which block's cap bound it.
    """

    def __init__(self, message: str, *, block: int,
                 block_indices: tuple[int, ...],
                 best_assignment: tuple[str, ...] | None,
                 peak_bytes: int, cap_bytes: int, worker: int):
        super().__init__(message)
        self.block = block
        self.block_indices = block_indices
        self.best_assignment = best_assignment
        self.peak_bytes = peak_bytes
        self.cap_bytes = cap_bytes
        self.worker = worker


@dataclasses.dataclass(frozen=True)
class _BlockCost:
    """Analytic cost pieces of one (fused block, mode, subset) state.

    ``peak_per_worker`` is counted at itemsize=1 (int8) regardless of
    ``cfg.itemsize`` — the planner's RAM-cap gate
    (:func:`memory.peak_ram_per_worker` with defaults) holds that
    convention, and the DP's pruning must agree with the gate the
    assembled plan will face.

    The ``*_pipe`` fields are the pipelined-surrogate counterparts of the
    serialized link times: per-link maxima instead of coordinator sums
    (links drain in parallel under the async transport)."""

    mode: str                       # requested mode
    down0_s: float                  # serialized download time, first layer
    down0_bytes: int
    comp0_max_s: float              # compute critical path, first layer
    intra_s: float                  # Σ serial totals of interior layers
    intra_bytes: int
    up_out_s: float                 # serialized upload time of the block's
    up_out_bytes: int               # final outputs (paid at the next block)
    peak_per_worker: np.ndarray     # per-worker analytic peak bytes
    weight_per_worker: np.ndarray   # per-worker weight-fragment bytes
    down0_pipe_s: float = 0.0       # per-link max variants (pipelined DP)
    intra_pipe_s: float = 0.0
    up_out_pipe_s: float = 0.0

    @property
    def peak_max(self) -> int:
        return int(self.peak_per_worker.max())


@dataclasses.dataclass(frozen=True)
class MixedSearch:
    """Result of :func:`search_mixed_assignment`: the chosen per-block mode
    vector (plus per-block worker subsets when searched) and the metrics
    predicted for it.  ``predicted_latency_s`` is always the exact Eq. 5-6
    serial total of the chosen assignment; under ``transport="pipelined"``
    ``predicted_score`` is the pipelined-seam surrogate the DP minimized
    (callers obtain exact pipelined makespans by simulating the assembled
    plan).  The peak follows the planner's int8 gate convention —
    itemsize=1, see :class:`_BlockCost`."""

    assignment: tuple[str, ...]
    predicted_score: float
    predicted_latency_s: float
    predicted_comm_bytes: int
    predicted_peak_ram: int
    # per-block worker subsets (original worker indices), None = all — only
    # non-None when subset_choices beyond the full set were searched and won
    block_workers: tuple | None = None
    transport: str = "serial"

    @property
    def n_blocks(self) -> int:
        return len(self.assignment)


def _block_splits(model: ReinterpretedModel, indices: tuple[int, ...],
                  ratings: np.ndarray, mode: str) -> list[LayerSplit]:
    """The block's splits under one requested mode — byte-identical to what
    :func:`splitting.split_model_mixed` assembles for this block, so the DP
    costs exactly the plan the caller will build."""
    layers = [model.layers[i] for i in indices]
    if mode == "spatial" and all(lyr.kind in ("conv", "dwconv")
                                 for lyr in layers):
        return split_block_spatial(layers, ratings)
    eff = mode if mode != "spatial" else "neuron"
    return [split_layer(lyr, ratings, eff) for lyr in layers]


def _block_cost(model: ReinterpretedModel, indices: tuple[int, ...],
                ratings: np.ndarray, mode: str, f_mhz: np.ndarray,
                link_s_per_kb: np.ndarray, cfg: SimConfig) -> _BlockCost:
    splits = _block_splits(model, indices, ratings, mode)
    n = len(ratings)
    comp = []
    for sp in splits:
        macs = np.array([macs_for_positions(sp.layer,
                                            sp.shard_of(w).n_positions)
                         for w in range(n)], dtype=np.float64)
        comp.append(_comp_seconds(macs, f_mhz, cfg))
    vol0 = comm_volume(None, splits[0].layer, splits[0],
                       itemsize=cfg.itemsize)
    down0 = link_s_per_kb * vol0.download_bytes / 1024.0
    down0_s = float(down0.sum())
    intra_s, intra_pipe_s, intra_bytes = 0.0, 0.0, 0
    for j in range(1, len(splits)):
        vol = comm_volume(splits[j - 1], splits[j].layer, splits[j],
                          itemsize=cfg.itemsize)
        per_down = link_s_per_kb * vol.download_bytes / 1024.0
        per_up = link_s_per_kb * vol.upload_bytes / 1024.0
        t_down, t_up = float(per_down.sum()), float(per_up.sum())
        max_comp = float(comp[j].max())
        if cfg.overlap:
            intra_s += t_down + max(max_comp, t_up)
            intra_pipe_s += float(per_down.max()) + max(max_comp,
                                                        float(per_up.max()))
        else:
            intra_s += t_down + max_comp + t_up
            intra_pipe_s += (float(per_down.max()) + max_comp
                             + float(per_up.max()))
        intra_bytes += vol.total_bytes
    last = splits[-1]
    up_out = np.zeros(n, dtype=np.int64)
    if last.block_last:
        for shard in last.shards:
            up_out[shard.worker] += shard.n_positions * cfg.itemsize
    up_out_t = link_s_per_kb * up_out / 1024.0
    # itemsize=1: match the planner's RAM gate (see _BlockCost docstring)
    peak = np.max(np.stack([split_memory(sp).per_worker_peak
                            for sp in splits]), axis=0)
    weights = np.array([sum(sp.shard_of(w).weight_bytes for sp in splits)
                        for w in range(n)], dtype=np.int64)
    return _BlockCost(
        mode=mode, down0_s=down0_s,
        down0_bytes=int(vol0.download_bytes.sum()),
        comp0_max_s=float(comp[0].max()), intra_s=intra_s,
        intra_bytes=intra_bytes,
        up_out_s=float(up_out_t.sum()),
        up_out_bytes=int(up_out.sum()),
        peak_per_worker=peak, weight_per_worker=weights,
        down0_pipe_s=float(down0.max()),
        intra_pipe_s=intra_pipe_s,
        up_out_pipe_s=float(up_out_t.max()))


def _combine_first(down0_s: float, comp0_max_s: float, up_s: float,
                   overlap: bool) -> float:
    """Serial total of a block's first layer given the upstream upload it
    overlaps with — simulate's per-layer arithmetic."""
    if overlap:
        return down0_s + max(comp0_max_s, up_s)
    return down0_s + comp0_max_s + up_s


def _assignment_metrics(table: list[dict], states: list,
                        overlap: bool) -> tuple[float, int, int]:
    """(exact serial latency, comm bytes, max peak) of one state sequence —
    summed from the DP tables with the serial boundary arithmetic (the
    transport surrogate never changes these reported metrics)."""
    latency, nbytes, peak = 0.0, 0, 0
    prev: _BlockCost | None = None
    for b, s in enumerate(states):
        c = table[b][s]
        up_s = prev.up_out_s if prev is not None else 0.0
        up_bytes = prev.up_out_bytes if prev is not None else 0
        latency += _combine_first(c.down0_s, c.comp0_max_s, up_s,
                                  overlap) + c.intra_s
        nbytes += up_bytes + c.down0_bytes + c.intra_bytes
        peak = max(peak, c.peak_max)
        prev = c
    return latency, nbytes, peak


def _resolve_subsets(ratings: np.ndarray, subset_choices) -> list:
    """Turn ``subset_choices`` (None = all workers, or a rating-prefix
    *size*) into concrete worker-index tuples, deduplicated in choice
    order.  A prefix covering every positive-rating worker duplicates the
    full set and is dropped."""
    n_pos = int(np.count_nonzero(np.asarray(ratings) > 0))
    order = np.lexsort((np.arange(len(ratings)), -np.asarray(ratings)))
    out, seen = [], set()
    for choice in subset_choices:
        if choice is None:
            key = None
        else:
            size = int(choice)
            if size < 1:
                raise ValueError(f"subset size must be >= 1, got {choice!r}")
            if size >= n_pos:
                continue                      # duplicate of the full set
            key = tuple(sorted(int(i) for i in order[:size]))
        if key in seen:
            continue
        seen.add(key)
        out.append(key)
    if None not in seen:
        out.insert(0, None)
    return out


def search_mixed_assignment(model: ReinterpretedModel,
                            workers: list[WorkerParams],
                            ratings: np.ndarray | None = None,
                            cfg: SimConfig | None = None,
                            minimize: str = "latency",
                            modes: tuple[str, ...] = MODES,
                            ram_caps: np.ndarray | None = None,
                            transport: str = "serial",
                            subset_choices=(None,),
                            cache=None,
                            ) -> MixedSearch:
    """Pick the per-fused-block (mode, worker subset) assignment minimizing
    ``minimize``.

    ``ratings`` default to uniform; ``ram_caps`` (per-worker bytes) prunes
    states whose analytic peak exceeds any worker's cap.  Raises
    :class:`MixedInfeasible` (a ``ValueError``) when some block has no
    cap-feasible state, or ``ValueError`` when ``minimize``/``modes``/
    ``transport`` are invalid.  ``subset_choices`` widens the per-block
    state space with rating-prefix worker subsets (entries are ``None`` for
    all workers or a prefix *size*); the default searches the full set only
    — today's fixed-worker-set DP, byte-identical.  ``transport`` picks the
    DP objective's link model (see module docstring); ``cache`` (a
    :class:`~repro.core.search.CostCache` or anything with ``get``/``put``)
    memoizes the block-cost tables across calls — the tables are
    cap-independent, so one table serves both transports, every
    ``minimize`` and every RAM-cap objective.
    """
    if minimize not in MINIMIZE_TARGETS:
        raise ValueError(f"unknown minimize {minimize!r} "
                         f"(want one of {MINIMIZE_TARGETS})")
    modes = tuple(modes)
    for m in modes:
        if m not in MODES:
            raise ValueError(f"unknown mode {m!r} (want one of {MODES})")
    if not modes:
        raise ValueError("need at least one mode to assign")
    if transport not in DP_TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r} "
                         f"(want one of {DP_TRANSPORTS})")
    cfg = cfg or SimConfig()
    n = len(workers)
    ratings = (np.ones(n) if ratings is None
               else np.asarray(ratings, dtype=np.float64))
    if len(ratings) != n:
        raise ValueError(f"{len(ratings)} ratings for {n} workers")
    f_mhz = np.array([p.f_mhz for p in workers])
    link_s_per_kb = np.array([p.d_s_per_kb + 1.0 / p.b_kb_s for p in workers])
    grouping = group_blocks(model)
    subsets = _resolve_subsets(ratings, subset_choices)

    # cap-independent full state tables: rows keyed (mode, subset), cached
    # across transports/objectives/replans when a cache is supplied
    def build_tables() -> list[dict]:
        tables: list[dict] = []
        for block in grouping:
            row: dict = {}
            conv_only = all(model.layers[i].kind in ("conv", "dwconv")
                            for i in block.indices)
            for sub in subsets:
                r_b = ratings if sub is None else np.where(
                    np.isin(np.arange(n), sub), ratings, 0.0)
                for m in modes:
                    if m == "spatial" and not conv_only and "neuron" in modes:
                        # the spatial state falls back to the flat neuron
                        # split on non-conv blocks (_block_splits) — an
                        # exact duplicate of the neuron state; skip it
                        # rather than cost it twice
                        continue
                    row[(m, sub)] = _block_cost(
                        model, tuple(block.indices), r_b, m,
                        f_mhz, link_s_per_kb, cfg)
            tables.append(row)
        return tables

    if cache is not None:
        key = ("mixed_table",
               (id(model), len(model.layers)),
               tuple((float(p.f_mhz), float(p.d_s_per_kb), float(p.b_kb_s),
                      int(p.ram_bytes), int(p.flash_bytes))
                     for p in workers),
               tuple(float(r) for r in ratings),
               (float(cfg.cycles_per_mac), float(cfg.flash_ns_per_mac),
                int(cfg.itemsize), bool(cfg.overlap)),
               modes, tuple(subsets))
        full_table = cache.get(key)
        if full_table is None:
            full_table = build_tables()
            cache.put(key, full_table)
    else:
        full_table = build_tables()

    caps = None if ram_caps is None else np.asarray(ram_caps)
    table: list[dict] = []
    binding: tuple[int, dict] | None = None
    for b, full_row in enumerate(full_table):
        if caps is None:
            table.append(full_row)
            continue
        row = {s: c for s, c in full_row.items()
               if not (c.peak_per_worker > caps).any()}
        if not row and binding is None:
            binding = (b, full_row)
        table.append(row)

    mode_rank = {m: i for i, m in enumerate(modes)}
    sub_rank = {s: i for i, s in enumerate(subsets)}

    def state_rank(s) -> tuple[int, int]:
        # ties break toward the earlier mode, then the earlier subset
        # choice (the full set first) — deterministic, and preferring
        # uniform full-width plans when mixing/subsetting buys nothing
        return (mode_rank[s[0]], sub_rank[s[1]])

    pipe = transport == "pipelined"

    def first_parts(c: _BlockCost) -> tuple[float, float]:
        return ((c.down0_pipe_s, c.comp0_max_s) if pipe
                else (c.down0_s, c.comp0_max_s))

    def up_of(c: _BlockCost | None) -> float:
        if c is None:
            return 0.0
        return c.up_out_pipe_s if pipe else c.up_out_s

    def block_score(c: _BlockCost, up_s: float) -> float:
        if minimize == "latency":
            down0, comp0 = first_parts(c)
            intra = c.intra_pipe_s if pipe else c.intra_s
            return _combine_first(down0, comp0, up_s, cfg.overlap) + intra
        if minimize == "comm_bytes":
            return float(c.down0_bytes + c.intra_bytes)
        return float(c.peak_max)

    def accumulate(prev_score: float, c: _BlockCost, prev: _BlockCost | None
                   ) -> float:
        if minimize == "peak_ram":
            return max(prev_score, block_score(c, 0.0))
        up_s = up_of(prev)
        extra = (prev.up_out_bytes if prev is not None else 0) \
            if minimize == "comm_bytes" else 0.0
        return prev_score + block_score(c, up_s) + float(extra)

    def run_dp(tbl: list[dict]) -> tuple[float, list]:
        """Shortest path over (block, mode, subset); back-pointers give the
        argmin state sequence.  Ties break by :func:`state_rank` for both
        the current and predecessor state."""
        best: dict = {}
        back: list[dict] = []
        for s, c in tbl[0].items():
            best[s] = accumulate(0.0 if minimize != "peak_ram" else -np.inf,
                                 c, None)
        back.append({s: None for s in tbl[0]})
        for b in range(1, len(tbl)):
            nxt: dict = {}
            ptr: dict = {}
            for s, c in tbl[b].items():
                cand = [(accumulate(best[sp], c, tbl[b - 1][sp]),
                         state_rank(sp), sp) for sp in best]
                score, _, sp = min(cand)
                nxt[s], ptr[s] = score, sp
            best = nxt
            back.append(ptr)
        final_score, _, s_last = min(
            (best[s], state_rank(s), s) for s in best)
        rev = [s_last]
        for b in range(len(tbl) - 1, 0, -1):
            rev.append(back[b][rev[-1]])
        return final_score, list(reversed(rev))

    if binding is not None:
        b, full_row = binding
        # best cap-ignoring assignment: what the DP would have chosen with
        # no RAM caps — real numbers for the planner's binding-constraint
        # report
        _, free_states = run_dp(full_table)
        best_assignment = tuple(s[0] for s in free_states)
        c_min = min(full_row.values(), key=lambda c: c.peak_max)
        if caps is not None:
            worker = int(np.argmax(c_min.peak_per_worker / caps))
        else:                                 # pragma: no cover — caps set
            worker = int(np.argmax(c_min.peak_per_worker))
        raise MixedInfeasible(
            f"no cap-feasible mode for fused block "
            f"{tuple(grouping[b].indices)}"
            f" (every candidate peak exceeds a worker's RAM cap)",
            block=b, block_indices=tuple(grouping[b].indices),
            best_assignment=best_assignment,
            peak_bytes=int(c_min.peak_per_worker[worker]),
            cap_bytes=int(caps[worker]) if caps is not None else 0,
            worker=worker)

    final_score, states = run_dp(table)
    assignment = tuple(s[0] for s in states)
    block_workers = tuple(s[1] for s in states)
    if all(s is None for s in block_workers):
        block_workers = None

    latency, nbytes, peak = _assignment_metrics(table, states, cfg.overlap)
    if minimize == "latency" and not pipe:
        score = latency
    elif minimize == "latency":
        score = final_score                   # pipelined-seam surrogate
    else:
        score = {"comm_bytes": float(nbytes),
                 "peak_ram": float(peak)}[minimize]
    return MixedSearch(assignment=assignment, predicted_score=score,
                       predicted_latency_s=latency,
                       predicted_comm_bytes=nbytes, predicted_peak_ram=peak,
                       block_workers=block_workers, transport=transport)
