"""Per-block mode-mixing search: dynamic programming over fused-block
boundaries.

The compare-modes data (``simulator.compare_modes``) shows spatial
partitioning winning the early high-resolution stages while the channel
modes (kernel/neuron) win the late channel-heavy stages — the regime split
MCUNetV2 exploits by running only the initial stage patch-based.  A
heterogeneous :class:`~repro.core.splitting.SplitPlan`
(:func:`~repro.core.splitting.split_model_mixed`) lets every fused block
pick its own mode; this module picks the assignment.

The search is exact for the serial (Eq. 5-6) cost model because that model
decomposes over block boundaries: a layer's download time depends only on
its own block's mode, its compute on its own block's mode, and the upload
it overlaps with only on the *previous* block's mode.  So the optimal
assignment is a shortest path over states ``(block, mode)`` with transition
cost

    boundary(b, m' -> m) = t_down(first layer of b under m)
                           + combine(max_comp(first layer under m),
                                     t_up(last layer of b-1 under m'))
    intra(b, m)          = Σ interior-layer serial totals under m

(``combine`` = max under §V.D eager-upload overlap, sum without), exactly
the per-layer arithmetic of :func:`simulator.simulate` — the DP's predicted
latency equals ``simulate(plan=mixed_plan).serial_total_time`` bit-for-bit
(property-tested).  ``comm_bytes`` and ``peak_ram`` objectives use the same
skeleton with sum/max accumulation; both are separable per block, so the DP
degenerates to a per-block argmin there.

Per-worker RAM caps prune the state space: a ``(block, mode)`` whose
analytic per-worker peak exceeds any cap is never entered, so the returned
assignment is peak-feasible by construction (flash feasibility — a *sum*
across blocks per worker — is checked by the caller on the assembled plan).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import WorkerParams
from .fusion import group_blocks
from .mapping import comm_volume
from .memory import split_memory
from .reinterpret import ReinterpretedModel, macs_for_positions
from .simulator import SimConfig, _comp_seconds
from .splitting import (MODES, LayerSplit, split_block_spatial, split_layer)

MINIMIZE_TARGETS = ("latency", "comm_bytes", "peak_ram")


@dataclasses.dataclass(frozen=True)
class _BlockCost:
    """Analytic cost pieces of one (fused block, mode) state.

    ``peak_per_worker`` is counted at itemsize=1 (int8) regardless of
    ``cfg.itemsize`` — the planner's RAM-cap gate
    (:func:`memory.peak_ram_per_worker` with defaults) holds that
    convention, and the DP's pruning must agree with the gate the
    assembled plan will face."""

    mode: str                       # requested mode
    down0_s: float                  # serialized download time, first layer
    down0_bytes: int
    comp0_max_s: float              # compute critical path, first layer
    intra_s: float                  # Σ serial totals of interior layers
    intra_bytes: int
    up_out_s: float                 # serialized upload time of the block's
    up_out_bytes: int               # final outputs (paid at the next block)
    peak_per_worker: np.ndarray     # per-worker analytic peak bytes
    weight_per_worker: np.ndarray   # per-worker weight-fragment bytes

    @property
    def peak_max(self) -> int:
        return int(self.peak_per_worker.max())


@dataclasses.dataclass(frozen=True)
class MixedSearch:
    """Result of :func:`search_mixed_assignment`: the chosen per-block mode
    vector plus the serial-model metrics predicted for it (the latency is
    the Eq. 5-6 serial total; pipelined makespans are obtained by simulating
    the assembled plan; the peak follows the planner's int8 gate convention
    — itemsize=1, see :class:`_BlockCost`)."""

    assignment: tuple[str, ...]
    predicted_score: float
    predicted_latency_s: float
    predicted_comm_bytes: int
    predicted_peak_ram: int

    @property
    def n_blocks(self) -> int:
        return len(self.assignment)


def _block_splits(model: ReinterpretedModel, indices: tuple[int, ...],
                  ratings: np.ndarray, mode: str) -> list[LayerSplit]:
    """The block's splits under one requested mode — byte-identical to what
    :func:`splitting.split_model_mixed` assembles for this block, so the DP
    costs exactly the plan the caller will build."""
    layers = [model.layers[i] for i in indices]
    if mode == "spatial" and all(lyr.kind in ("conv", "dwconv")
                                 for lyr in layers):
        return split_block_spatial(layers, ratings)
    eff = mode if mode != "spatial" else "neuron"
    return [split_layer(lyr, ratings, eff) for lyr in layers]


def _block_cost(model: ReinterpretedModel, indices: tuple[int, ...],
                ratings: np.ndarray, mode: str, f_mhz: np.ndarray,
                link_s_per_kb: np.ndarray, cfg: SimConfig) -> _BlockCost:
    splits = _block_splits(model, indices, ratings, mode)
    n = len(ratings)
    comp = []
    for sp in splits:
        macs = np.array([macs_for_positions(sp.layer,
                                            sp.shard_of(w).n_positions)
                         for w in range(n)], dtype=np.float64)
        comp.append(_comp_seconds(macs, f_mhz, cfg))
    vol0 = comm_volume(None, splits[0].layer, splits[0],
                       itemsize=cfg.itemsize)
    down0_s = float((link_s_per_kb * vol0.download_bytes / 1024.0).sum())
    intra_s, intra_bytes = 0.0, 0
    for j in range(1, len(splits)):
        vol = comm_volume(splits[j - 1], splits[j].layer, splits[j],
                          itemsize=cfg.itemsize)
        t_down = float((link_s_per_kb * vol.download_bytes / 1024.0).sum())
        t_up = float((link_s_per_kb * vol.upload_bytes / 1024.0).sum())
        max_comp = float(comp[j].max())
        if cfg.overlap:
            intra_s += t_down + max(max_comp, t_up)
        else:
            intra_s += t_down + max_comp + t_up
        intra_bytes += vol.total_bytes
    last = splits[-1]
    up_out = np.zeros(n, dtype=np.int64)
    if last.block_last:
        for shard in last.shards:
            up_out[shard.worker] += shard.n_positions * cfg.itemsize
    # itemsize=1: match the planner's RAM gate (see _BlockCost docstring)
    peak = np.max(np.stack([split_memory(sp).per_worker_peak
                            for sp in splits]), axis=0)
    weights = np.array([sum(sp.shard_of(w).weight_bytes for sp in splits)
                        for w in range(n)], dtype=np.int64)
    return _BlockCost(
        mode=mode, down0_s=down0_s,
        down0_bytes=int(vol0.download_bytes.sum()),
        comp0_max_s=float(comp[0].max()), intra_s=intra_s,
        intra_bytes=intra_bytes,
        up_out_s=float((link_s_per_kb * up_out / 1024.0).sum()),
        up_out_bytes=int(up_out.sum()),
        peak_per_worker=peak, weight_per_worker=weights)


def _combine_first(c: _BlockCost, up_s: float, overlap: bool) -> float:
    """Serial total of a block's first layer given the upstream upload it
    overlaps with — simulate's per-layer arithmetic."""
    if overlap:
        return c.down0_s + max(c.comp0_max_s, up_s)
    return c.down0_s + c.comp0_max_s + up_s


def _assignment_metrics(table: list[dict[str, _BlockCost]],
                        assignment: tuple[str, ...],
                        overlap: bool) -> tuple[float, int, int]:
    """(serial latency, comm bytes, max peak) of one assignment — summed
    from the DP tables with the same boundary arithmetic as the DP itself."""
    latency, nbytes, peak = 0.0, 0, 0
    prev: _BlockCost | None = None
    for b, m in enumerate(assignment):
        c = table[b][m]
        up_s = prev.up_out_s if prev is not None else 0.0
        up_bytes = prev.up_out_bytes if prev is not None else 0
        latency += _combine_first(c, up_s, overlap) + c.intra_s
        nbytes += up_bytes + c.down0_bytes + c.intra_bytes
        peak = max(peak, c.peak_max)
        prev = c
    return latency, nbytes, peak


def search_mixed_assignment(model: ReinterpretedModel,
                            workers: list[WorkerParams],
                            ratings: np.ndarray | None = None,
                            cfg: SimConfig | None = None,
                            minimize: str = "latency",
                            modes: tuple[str, ...] = MODES,
                            ram_caps: np.ndarray | None = None,
                            ) -> MixedSearch:
    """Pick the per-fused-block mode assignment minimizing ``minimize``.

    ``ratings`` default to uniform; ``ram_caps`` (per-worker bytes) prunes
    block-mode states whose analytic peak exceeds any worker's cap.  Raises
    ``ValueError`` when some block has no cap-feasible mode, or when
    ``minimize``/``modes`` are invalid.  The same ratings vector is used for
    every block (per-block worker subsets are expressible in
    ``split_model_mixed`` but not searched here — the subset ladder is the
    planner's axis).
    """
    if minimize not in MINIMIZE_TARGETS:
        raise ValueError(f"unknown minimize {minimize!r} "
                         f"(want one of {MINIMIZE_TARGETS})")
    modes = tuple(modes)
    for m in modes:
        if m not in MODES:
            raise ValueError(f"unknown mode {m!r} (want one of {MODES})")
    if not modes:
        raise ValueError("need at least one mode to assign")
    cfg = cfg or SimConfig()
    n = len(workers)
    ratings = (np.ones(n) if ratings is None
               else np.asarray(ratings, dtype=np.float64))
    if len(ratings) != n:
        raise ValueError(f"{len(ratings)} ratings for {n} workers")
    f_mhz = np.array([p.f_mhz for p in workers])
    link_s_per_kb = np.array([p.d_s_per_kb + 1.0 / p.b_kb_s for p in workers])
    grouping = group_blocks(model)

    table: list[dict[str, _BlockCost]] = []
    for block in grouping:
        row: dict[str, _BlockCost] = {}
        conv_only = all(model.layers[i].kind in ("conv", "dwconv")
                        for i in block.indices)
        for m in modes:
            if m == "spatial" and not conv_only and "neuron" in modes:
                # the spatial state falls back to the flat neuron split on
                # non-conv blocks (_block_splits) — an exact duplicate of
                # the neuron state; skip it rather than cost it twice
                continue
            c = _block_cost(model, tuple(block.indices), ratings, m,
                            f_mhz, link_s_per_kb, cfg)
            if ram_caps is not None and (c.peak_per_worker
                                         > np.asarray(ram_caps)).any():
                continue
            row[m] = c
        if not row:
            raise ValueError(
                f"no cap-feasible mode for fused block {tuple(block.indices)}"
                f" (every candidate peak exceeds a worker's RAM cap)")
        table.append(row)

    mode_rank = {m: i for i, m in enumerate(modes)}

    def block_score(c: _BlockCost, up_s: float) -> float:
        if minimize == "latency":
            return _combine_first(c, up_s, cfg.overlap) + c.intra_s
        if minimize == "comm_bytes":
            return float(c.down0_bytes + c.intra_bytes)
        return float(c.peak_max)

    def accumulate(prev_score: float, c: _BlockCost, prev: _BlockCost | None
                   ) -> float:
        if minimize == "peak_ram":
            return max(prev_score, block_score(c, 0.0))
        up_s = prev.up_out_s if prev is not None else 0.0
        extra = (prev.up_out_bytes if prev is not None else 0) \
            if minimize == "comm_bytes" else 0.0
        return prev_score + block_score(c, up_s) + float(extra)

    # DP over (block, mode); back-pointers give the argmin assignment.
    # Ties break toward the earlier mode in ``modes`` (both for the current
    # and the predecessor state), keeping the result deterministic and
    # preferring uniform plans when mixing buys nothing.
    best: dict[str, float] = {}
    back: list[dict[str, str | None]] = []
    for m, c in table[0].items():
        best[m] = accumulate(0.0 if minimize != "peak_ram" else -np.inf,
                             c, None)
    back.append({m: None for m in table[0]})
    for b in range(1, len(table)):
        nxt: dict[str, float] = {}
        ptr: dict[str, str | None] = {}
        for m, c in table[b].items():
            cand = [(accumulate(best[mp], c, table[b - 1][mp]),
                     mode_rank[mp], mp) for mp in best]
            score, _, mp = min(cand)
            nxt[m], ptr[m] = score, mp
        best = nxt
        back.append(ptr)

    final_score, _, m_last = min((best[m], mode_rank[m], m) for m in best)
    rev = [m_last]
    for b in range(len(table) - 1, 0, -1):
        rev.append(back[b][rev[-1]])
    assignment = tuple(reversed(rev))

    latency, nbytes, peak = _assignment_metrics(table, assignment,
                                                cfg.overlap)
    score = {"latency": latency, "comm_bytes": float(nbytes),
             "peak_ram": float(peak)}[minimize]
    return MixedSearch(assignment=assignment, predicted_score=score,
                       predicted_latency_s=latency,
                       predicted_comm_bytes=nbytes, predicted_peak_ram=peak)
