"""Peak-RAM accounting (paper §IV.B: peak memory = input activations +
weight parameters + output activations; evaluated per layer per worker).

This is the analytic counterpart of the paper's on-device heap probe
(§VII.A Metrics): per worker per layer we count the routed input bytes
(exact region sizes from the cross-layer mapping), the local weight-fragment
bytes, and the assigned output bytes.  Weight fragments live in flash on the
real system, but during computation the active kernel is staged in RAM, so
the paper's peak includes all three terms.

Spatial mode: a banded shard's input term is its band's receptive-field row
window (band + halo) across all channels — halo rows are therefore counted
once per worker that holds them (halo duplication).  For layers inside a
fused block the window is produced locally rather than routed, but it is
resident worker RAM all the same, and the weight term is the *full* layer
(spatial mode replicates weights instead of splitting them).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .mapping import worker_input_regions
from .splitting import LayerSplit, SplitPlan


@dataclasses.dataclass(frozen=True)
class LayerMemory:
    layer_name: str
    per_worker_in: np.ndarray       # routed input activation bytes
    per_worker_weight: np.ndarray   # weight fragment bytes
    per_worker_out: np.ndarray      # assigned output bytes

    @property
    def per_worker_peak(self) -> np.ndarray:
        return self.per_worker_in + self.per_worker_weight + self.per_worker_out


def split_memory(split: LayerSplit, itemsize: int = 1,
                 weight_itemsize: int | None = None) -> LayerMemory:
    """The three per-worker memory terms of one layer's split — the single
    source of the (in + weight + out) accounting, shared by
    :func:`plan_memory` and the mode-mixing DP (``core.mixed``)."""
    weight_itemsize = itemsize if weight_itemsize is None else weight_itemsize
    n = len(split.shards)
    regions = worker_input_regions(split.layer, split)
    m_in = np.array([sum(r.n_points for r in regs) * itemsize
                     for regs in regions], dtype=np.int64)
    m_w = np.array([split.shard_of(w).weight_bytes * weight_itemsize
                    for w in range(n)], dtype=np.int64)
    m_out = np.array([split.shard_of(w).n_positions * itemsize
                      for w in range(n)], dtype=np.int64)
    return LayerMemory(split.layer.name, m_in, m_w, m_out)


def plan_memory(plan: SplitPlan, itemsize: int = 1,
                weight_itemsize: int | None = None) -> list[LayerMemory]:
    """Per-layer, per-worker memory terms (itemsize=1 → int8 activations)."""
    return [split_memory(split, itemsize, weight_itemsize)
            for split in plan.splits]


def peak_ram_per_worker(plan: SplitPlan, itemsize: int = 1,
                        weight_itemsize: int | None = None) -> np.ndarray:
    """max over layers of (in + weight + out) per worker — Fig. 12's metric.

    ``weight_itemsize`` defaults to ``itemsize`` (the ``plan_memory``
    contract), so a float-weights/int8-activations peak query is
    ``peak_ram_per_worker(plan, itemsize=1, weight_itemsize=4)``."""
    mems = plan_memory(plan, itemsize, weight_itemsize)
    return np.max(np.stack([m.per_worker_peak for m in mems]), axis=0)


def layerwise_peak(plan: SplitPlan, itemsize: int = 1,
                   weight_itemsize: int | None = None) -> np.ndarray:
    """(n_layers, n_workers) peak bytes — Fig. 8's metric.  ``weight_itemsize``
    as in :func:`peak_ram_per_worker`."""
    mems = plan_memory(plan, itemsize, weight_itemsize)
    return np.stack([m.per_worker_peak for m in mems])


def single_device_peak(model, itemsize: int = 1) -> int:
    """Monolithic per-layer peak (full in + full weights + full out) — the
    'infeasible on a single MCU' baseline (§VII.B.1)."""
    return max((lyr.n_in + lyr.n_out) * itemsize + lyr.weight_bytes(itemsize)
               for lyr in model.layers)
