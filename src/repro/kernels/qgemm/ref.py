"""Pure-jnp oracle for the qgemm kernel (int8 W8A8 GEMM + dequant epilogue)."""
from __future__ import annotations

import jax.numpy as jnp


def qgemm_ref(x_q, w_q, scale, bias, *, activation: str | None = None,
              out_scale: float | None = None):
    """x_q: (M, K) int8; w_q: (K, N) int8; scale: (N,) f32; bias: (N,) f32
    (real-domain) or int32 (``b_q``, added to the int32 accumulator)."""
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    if jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer):
        y = (acc + bias[None, :]).astype(jnp.float32) * scale[None, :]
    else:
        y = acc.astype(jnp.float32) * scale[None, :] + bias[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    if out_scale is not None:
        return jnp.clip(jnp.round(y * (1.0 / out_scale)),
                        -127, 127).astype(jnp.int8)
    return y
