"""Pallas TPU kernel: int8 x int8 -> int32 GEMM with fused dequant epilogue.

This is the paper's §V.D stack (int8 W8A8 quantization + conv/BN/ReLU fusion)
as a single MXU kernel: the BN scale/bias are folded into the per-output-
channel dequant scale and bias, and the activation + requantization happen in
VMEM before the tile is written back — no intermediate HBM round-trips.

TPU adaptation (DESIGN.md §2): the MCU runtime fuses at the operator level;
on TPU the win is keeping the int32 accumulator tile resident in VMEM across
the K loop (grid-innermost), with (bm, bn) output tiles aligned to the
128x128 MXU.  Conv layers reach this kernel in im2col form (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..backend import resolve_interpret


def _qgemm_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref,
                  *, n_k: int, activation: str | None, out_scale: float | None,
                  int_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 tiles -> int32 MXU accumulation
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        if int_bias:
            # b_q added in exact int32; float steps are multiplies only so
            # the result is bit-identical to the executors' jnp epilogue
            # (no FMA-contraction sensitivity — see core.quantize).
            acc = acc_ref[...] + bias_ref[...][None, :]
            y = acc.astype(jnp.float32) * scale_ref[...][None, :]
        else:
            acc = acc_ref[...].astype(jnp.float32)
            y = acc * scale_ref[...][None, :] + bias_ref[...][None, :]
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        if out_scale is not None:
            y = jnp.clip(jnp.round(y * (1.0 / out_scale)), -127, 127)
            o_ref[...] = y.astype(jnp.int8)
        else:
            o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "out_scale",
                                             "block_m", "block_n", "block_k",
                                             "interpret"))
def qgemm(x_q, w_q, scale, bias, *, activation: str | None = None,
          out_scale: float | None = None, block_m: int = 128,
          block_n: int = 128, block_k: int = 128,
          interpret: bool | None = None):
    """x_q: (M, K) int8; w_q: (K, N) int8; scale: (N,) f32.

    ``bias``: (N,) float32 (BN-folded real-domain bias, added in the f32
    epilogue) **or** int32 (the quantized ``b_q`` at accumulator scale,
    added in exact int32 before dequant — the bit-exact path the split
    executors use).

    Returns (M, N): int8 (requantized at ``out_scale``) or f32.
    Shapes must be multiples of the block sizes (ops.py pads).
    ``interpret=None`` auto-detects the backend: the compiled kernel on TPU,
    interpret mode (kernel body as plain jax ops) everywhere else.
    """
    interpret = resolve_interpret(interpret)
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    int_bias = jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer)
    kernel = functools.partial(_qgemm_kernel, n_k=n_k, activation=activation,
                               out_scale=out_scale, int_bias=int_bias)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, scale, bias)
