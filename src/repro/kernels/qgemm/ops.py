"""jit'd public wrappers around the qgemm Pallas kernel: padding to block
multiples, plus the im2col path that lowers the paper's quantized conv +
folded-BN + ReLU6 onto the GEMM kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .qgemm import qgemm
from .ref import qgemm_ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qgemm_padded(x_q, w_q, scale, bias, *, activation=None, out_scale=None,
                 block_m=128, block_n=128, block_k=128, interpret=None):
    """qgemm on arbitrary shapes (pads to block multiples, slices back)."""
    m, k = x_q.shape
    n = w_q.shape[1]
    xp = _pad_to(_pad_to(x_q, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w_q, block_k, 0), block_n, 1)
    sp = _pad_to(scale, block_n, 0)
    bp = _pad_to(bias, block_n, 0)
    out = qgemm(xp, wp, sp, bp, activation=activation, out_scale=out_scale,
                block_m=block_m, block_n=block_n, block_k=block_k,
                interpret=interpret)
    return out[:m, :n]


def im2col(x_q, kernel_hw, stride, padding):
    """x_q: (C, H, W) int8 -> (out_h*out_w, C*kh*kw) patches (CHW order,
    matching core/reinterpret's flat-index convention)."""
    c, h, w = x_q.shape
    kh, kw = kernel_hw
    sh, sw = stride
    ph, pw = padding
    xp = jnp.pad(x_q, ((0, 0), (ph, ph), (pw, pw)))
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    idx_h = (jnp.arange(oh) * sh)[:, None] + jnp.arange(kh)[None, :]
    idx_w = (jnp.arange(ow) * sw)[:, None] + jnp.arange(kw)[None, :]
    patches = xp[:, idx_h[:, None, :, None], idx_w[None, :, None, :]]
    # (C, oh, ow, kh, kw) -> (oh*ow, C*kh*kw)
    patches = patches.transpose(1, 2, 0, 3, 4).reshape(oh * ow, c * kh * kw)
    return patches, (oh, ow)


def im2col_bands(x_q, kernel_hw, stride):
    """Batched-band im2col: (bands, C, R, W) pre-padded windows ->
    (bands*oh*ow, C*kh*kw) patches, band-major.  Folding the band axis into
    the GEMM M dimension makes the band index part of the qgemm grid — a
    fused spatial block's conv stage is ONE kernel call for every band, with
    the shared per-output-channel scale/bias epilogue indexed by the N-tile
    ``program_id`` exactly as in the single-sample path."""
    bsz, c, h, w = x_q.shape
    kh, kw = kernel_hw
    sh, sw = stride
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    idx_h = (jnp.arange(oh) * sh)[:, None] + jnp.arange(kh)[None, :]
    idx_w = (jnp.arange(ow) * sw)[:, None] + jnp.arange(kw)[None, :]
    patches = x_q[:, :, idx_h[:, None, :, None], idx_w[None, :, None, :]]
    # (B, C, oh, ow, kh, kw) -> (B, oh, ow, C, kh, kw) -> (B*oh*ow, C*kh*kw)
    patches = patches.transpose(0, 2, 3, 1, 4, 5).reshape(
        bsz * oh * ow, c * kh * kw)
    return patches, (oh, ow)


def qconv2d(x_q, w_q, scale, bias, *, stride=(1, 1), padding=(0, 0),
            activation=None, out_scale=None, interpret=None):
    """Quantized conv via im2col + qgemm (paper's conv+BN+ReLU6 fused op).

    x_q: (C, H, W) int8; w_q: (Cout, Cin, kh, kw) int8;
    scale/bias: (Cout,) f32 (BN folded).  Returns (Cout, oh, ow).
    """
    cout, cin, kh, kw = w_q.shape
    patches, (oh, ow) = im2col(x_q, (kh, kw), stride, padding)
    w2 = w_q.reshape(cout, cin * kh * kw).T          # (C*kh*kw, Cout)
    y = qgemm_padded(patches, w2, scale, bias, activation=activation,
                     out_scale=out_scale, interpret=interpret)
    return y.T.reshape(cout, oh, ow)


def qconv2d_ref(x_q, w_q, scale, bias, *, stride=(1, 1), padding=(0, 0),
                activation=None, out_scale=None):
    """Oracle for qconv2d built on the qgemm oracle."""
    cout, cin, kh, kw = w_q.shape
    patches, (oh, ow) = im2col(x_q, (kh, kw), stride, padding)
    w2 = w_q.reshape(cout, cin * kh * kw).T
    y = qgemm_ref(patches, w2, scale, bias, activation=activation,
                  out_scale=out_scale)
    return y.T.reshape(cout, oh, ow)
