"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attn_ref(q, k, v, lengths):
    """q: (B, K, G, hd); k, v: (B, K, S, hd); lengths: (B,)."""
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bkgh,bksh->bkgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    s = k.shape[2]
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
