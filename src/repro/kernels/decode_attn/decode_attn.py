"""Pallas TPU kernel: GQA flash-decode (one query token vs a long KV cache).

The serving hot-spot of the assigned LM shapes (decode_32k / long_500k):
memory-bound streaming of the KV cache through VMEM with an online-softmax
accumulator.  Grid = (batch, kv_heads, S // block_s) with the innermost
dimension streaming cache blocks; (m, l, acc) scratch stays VMEM-resident
per (b, k) so the cache is read exactly once from HBM.

Per-(b,k) block work: logits (G, bs) = q (G, hd) @ k_blk^T (hd, bs) — G and
hd are MXU-aligned multiples for the assigned archs (G*hd >= 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..backend import resolve_interpret
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_s: int, n_s: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                  # (G, hd)
    k = k_ref[0, 0]                                  # (bs, hd)
    v = v_ref[0, 0]                                  # (bs, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bs)
    # mask cache slots beyond the valid length
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(pos < len_ref[0], logits, NEG_INF)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                      # (G, bs)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attn(q, k, v, lengths, *, block_s: int = 512,
                interpret: bool | None = None):
    """q: (B, K, G, hd); k, v: (B, K, S, hd); lengths: (B,) int32 valid
    cache lengths.  Returns (B, K, G, hd) in q.dtype.
    ``interpret=None`` auto-detects the backend (see kernels.backend)."""
    interpret = resolve_interpret(interpret)
    b, kh, g, hd = q.shape
    s = k.shape[2]
    assert s % block_s == 0, (s, block_s)
    n_s = s // block_s
    kernel = functools.partial(_decode_attn_kernel, block_s=block_s, n_s=n_s)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda bi, ki, si: (bi, ki, si, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda bi, ki, si: (bi, ki, si, 0)),
            pl.BlockSpec((1,), lambda bi, ki, si: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
