"""jit'd wrapper for flash-decode: accepts model-layout tensors
(q (B,1,K,G,hd), cache (B,S,K,hd)) and pads S to the block multiple."""
from __future__ import annotations

import jax.numpy as jnp

from .decode_attn import decode_attn
from .ref import decode_attn_ref


def flash_decode(q, cache_k, cache_v, lengths, *, block_s: int = 512,
                 interpret: bool | None = None):
    """q: (B, 1, K, G, hd); cache_k/v: (B, S, K, hd); lengths: (B,).
    Returns (B, 1, K, G, hd)."""
    qk = q[:, 0]                                     # (B, K, G, hd)
    k = cache_k.transpose(0, 2, 1, 3)                # (B, K, S, hd)
    v = cache_v.transpose(0, 2, 1, 3)
    pad = (-k.shape[2]) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = decode_attn(qk, k, v, lengths, block_s=block_s, interpret=interpret)
    return out[:, None]


def flash_decode_ref(q, cache_k, cache_v, lengths):
    qk = q[:, 0]
    k = cache_k.transpose(0, 2, 1, 3)
    v = cache_v.transpose(0, 2, 1, 3)
    return decode_attn_ref(qk, k, v, lengths)[:, None]
