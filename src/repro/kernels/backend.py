"""Backend detection for the Pallas kernels.

The kernels target TPU; everywhere else they must run in Pallas interpret
mode (the kernel body traced as plain jax ops) so CPU CI and laptops still
work.  Historically the kernels hardcoded ``interpret=True``, which silently
kept TPUs on the slow path — callers now pass ``interpret=None`` ("auto")
and we resolve it here from the actual jax backend.

Override order: explicit argument > ``REPRO_PALLAS_INTERPRET`` env var
("0"/"1") > auto-detection.
"""
from __future__ import annotations

import os

import jax

_PALLAS_NATIVE_BACKENDS = ("tpu",)


def default_interpret() -> bool:
    """True when the Pallas kernels must run in interpret mode (no TPU)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return jax.default_backend() not in _PALLAS_NATIVE_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret`` kwarg: None means auto-detect."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)
