"""Pallas TPU kernels for the paper's compute hot-spots (+ the serving
decode path).  Each kernel ships with ops.py (jit wrapper) and ref.py
(pure-jnp oracle); validated with interpret=True on CPU, TPU is the target.
"""
