"""Pallas TPU kernel: int8 3x3 depthwise convolution with fused folded-BN +
ReLU6 + requantization (MobileNetV2's hot-spot op, §VI).

Depthwise conv has no reduction over channels, so it is VPU (not MXU) work:
each grid step loads a (block_c, H+2, W+2) pre-padded input tile into VMEM
and accumulates the 9 shifted element-wise products in int32 — the whole
channel tile's activations stay VMEM-resident through the epilogue.
Channels are independent ("kernel-wise" in the paper's splitting), so the
channel grid dimension is also the natural TP/split axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..backend import resolve_interpret


def _dwconv_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref,
                   *, stride: int, activation: str | None,
                   out_scale: float | None, int_bias: bool):
    x = x_ref[...].astype(jnp.int32)              # (bc, H+2, W+2)
    w = w_ref[...].astype(jnp.int32)              # (bc, 3, 3)
    oh, ow = o_ref.shape[1], o_ref.shape[2]
    acc = jnp.zeros((x.shape[0], oh, ow), jnp.int32)
    for i in range(3):
        for j in range(3):
            window = jax.lax.slice(
                x, (0, i, j), (x.shape[0], i + (oh - 1) * stride + 1,
                               j + (ow - 1) * stride + 1),
                (1, stride, stride))
            acc += window * w[:, i, j][:, None, None]
    if int_bias:
        # b_q added in exact int32; float steps are multiplies only so the
        # result is bit-identical to the executors' jnp epilogue (no
        # FMA-contraction sensitivity — see core.quantize).
        acc = acc + bias_ref[...][:, None, None]
        y = acc.astype(jnp.float32) * scale_ref[...][:, None, None]
    else:
        y = acc.astype(jnp.float32) * scale_ref[...][:, None, None] \
            + bias_ref[...][:, None, None]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    if out_scale is not None:
        o_ref[...] = jnp.clip(jnp.round(y * (1.0 / out_scale)),
                              -127, 127).astype(jnp.int8)
    else:
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "activation",
                                             "out_scale", "block_c",
                                             "interpret"))
def dwconv3x3(x_pad, w, scale, bias, *, stride: int = 1,
              activation: str | None = None, out_scale: float | None = None,
              block_c: int = 8, interpret: bool | None = None):
    """x_pad: (C, H+2, W+2) int8 (pre-padded by 1); w: (C, 3, 3) int8;
    scale: (C,) f32; bias: (C,) f32 (real-domain, f32 epilogue) or int32
    (quantized ``b_q``, added in exact int32 — the bit-exact executor path).
    Returns (C, oh, ow) int8 or f32.  C must be a multiple of block_c
    (ops.py pads).
    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    c, hp, wp = x_pad.shape
    assert c % block_c == 0
    oh = (hp - 3) // stride + 1
    ow = (wp - 3) // stride + 1
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    int_bias = jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer)
    kernel = functools.partial(_dwconv_kernel, stride=stride,
                               activation=activation, out_scale=out_scale,
                               int_bias=int_bias)
    return pl.pallas_call(
        kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, hp, wp), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, 3, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_c, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), out_dtype),
        interpret=interpret,
    )(x_pad, w, scale, bias)
