"""Pallas TPU kernel: int8 3x3 depthwise convolution with fused folded-BN +
ReLU6 + requantization (MobileNetV2's hot-spot op, §VI).

Depthwise conv has no reduction over channels, so it is VPU (not MXU) work:
each grid step loads a (block_c, H+2, W+2) pre-padded input tile into VMEM
and accumulates the 9 shifted element-wise products in int32 — the whole
channel tile's activations stay VMEM-resident through the epilogue.
Channels are independent ("kernel-wise" in the paper's splitting), so the
channel grid dimension is also the natural TP/split axis.

Two entry points share the kernel body:

* :func:`dwconv3x3` — one (C, H+2, W+2) sample, grid over channel tiles.
* :func:`dwconv3x3_bands` — a stack of spatial band windows
  (bands, C, R, W+2): the **band index is a grid axis**, so every band of a
  fused spatial block executes in a single ``pallas_call`` instead of one
  dispatch per band (the split-executor hot path).  Rows beyond a band's
  valid window are zero-filled by the caller and their outputs discarded, so
  heterogeneous band heights ride one uniform grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..backend import resolve_interpret


def _accum3x3(x, w, oh: int, ow: int, stride: int):
    """Sum of the 9 shifted element-wise products in int32.
    x: (bc, R, W+2) int32; w: (bc, 3, 3) int32 -> (bc, oh, ow) int32."""
    acc = jnp.zeros((x.shape[0], oh, ow), jnp.int32)
    for i in range(3):
        for j in range(3):
            window = jax.lax.slice(
                x, (0, i, j), (x.shape[0], i + (oh - 1) * stride + 1,
                               j + (ow - 1) * stride + 1),
                (1, stride, stride))
            acc += window * w[:, i, j][:, None, None]
    return acc


def _epilogue(acc, scale, bias, *, activation: str | None,
              out_scale: float | None, int_bias: bool, out_dtype):
    """Fused folded-BN + activation + requantization epilogue on a
    (bc, oh, ow) int32 accumulator (scale/bias are (bc,))."""
    if int_bias:
        # b_q added in exact int32; float steps are multiplies only so the
        # result is bit-identical to the executors' jnp epilogue (no
        # FMA-contraction sensitivity — see core.quantize).
        acc = acc + bias[:, None, None]
        y = acc.astype(jnp.float32) * scale[:, None, None]
    else:
        y = acc.astype(jnp.float32) * scale[:, None, None] \
            + bias[:, None, None]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    if out_scale is not None:
        return jnp.clip(jnp.round(y * (1.0 / out_scale)),
                        -127, 127).astype(jnp.int8)
    return y.astype(out_dtype)


def _dwconv_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref,
                   *, stride: int, activation: str | None,
                   out_scale: float | None, int_bias: bool):
    x = x_ref[...].astype(jnp.int32)              # (bc, H+2, W+2)
    w = w_ref[...].astype(jnp.int32)              # (bc, 3, 3)
    oh, ow = o_ref.shape[1], o_ref.shape[2]
    acc = _accum3x3(x, w, oh, ow, stride)
    o_ref[...] = _epilogue(acc, scale_ref[...], bias_ref[...],
                           activation=activation, out_scale=out_scale,
                           int_bias=int_bias, out_dtype=o_ref.dtype)


def _dwconv_bands_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref,
                         *, stride: int, activation: str | None,
                         out_scale: float | None, int_bias: bool):
    x = x_ref[0].astype(jnp.int32)                # (bc, R, W+2)
    w = w_ref[...].astype(jnp.int32)              # (bc, 3, 3)
    oh, ow = o_ref.shape[2], o_ref.shape[3]
    acc = _accum3x3(x, w, oh, ow, stride)
    o_ref[0] = _epilogue(acc, scale_ref[...], bias_ref[...],
                         activation=activation, out_scale=out_scale,
                         int_bias=int_bias, out_dtype=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "activation",
                                             "out_scale", "block_c",
                                             "interpret"))
def dwconv3x3(x_pad, w, scale, bias, *, stride: int = 1,
              activation: str | None = None, out_scale: float | None = None,
              block_c: int = 8, interpret: bool | None = None):
    """x_pad: (C, H+2, W+2) int8 (pre-padded by 1); w: (C, 3, 3) int8;
    scale: (C,) f32; bias: (C,) f32 (real-domain, f32 epilogue) or int32
    (quantized ``b_q``, added in exact int32 — the bit-exact executor path).
    Returns (C, oh, ow) int8 or f32.  C must be a multiple of block_c
    (ops.py pads).
    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    c, hp, wp = x_pad.shape
    assert c % block_c == 0
    oh = (hp - 3) // stride + 1
    ow = (wp - 3) // stride + 1
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    int_bias = jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer)
    kernel = functools.partial(_dwconv_kernel, stride=stride,
                               activation=activation, out_scale=out_scale,
                               int_bias=int_bias)
    return pl.pallas_call(
        kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, hp, wp), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, 3, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_c, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), out_dtype),
        interpret=interpret,
    )(x_pad, w, scale, bias)


@functools.partial(jax.jit, static_argnames=("stride", "activation",
                                             "out_scale", "block_c",
                                             "interpret"))
def dwconv3x3_bands(x_win, w, scale, bias, *, stride: int = 1,
                    activation: str | None = None,
                    out_scale: float | None = None,
                    block_c: int = 8, interpret: bool | None = None):
    """Batched-band 3x3 depthwise conv: ``x_win`` is (bands, C, R, W+2) int8
    — one pre-gathered row window per spatial band (halo/zero rows and the
    width pad already in place, shorter bands zero-filled to the common R).

    The band index is the leading **grid axis** (grid = (bands, C//block_c)),
    so a fused spatial block's depthwise stage is ONE kernel invocation for
    the whole cluster instead of one dispatch per band.  The per-channel
    scale/bias epilogue tile is selected by the channel ``program_id``,
    shared across bands (spatial mode replicates weights).  Weights/scale/
    bias are (C, 3, 3)/(C,)/(C,) — identical contract to :func:`dwconv3x3`.
    """
    interpret = resolve_interpret(interpret)
    b, c, rp, wp = x_win.shape
    assert c % block_c == 0
    oh = (rp - 3) // stride + 1
    ow = (wp - 3) // stride + 1
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    int_bias = jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer)
    kernel = functools.partial(_dwconv_bands_kernel, stride=stride,
                               activation=activation, out_scale=out_scale,
                               int_bias=int_bias)
    return pl.pallas_call(
        kernel,
        grid=(b, c // block_c),
        in_specs=[
            pl.BlockSpec((1, block_c, rp, wp), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((block_c, 3, 3), lambda bi, ci: (ci, 0, 0)),
            pl.BlockSpec((block_c,), lambda bi, ci: (ci,)),
            pl.BlockSpec((block_c,), lambda bi, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, block_c, oh, ow),
                               lambda bi, ci: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, oh, ow), out_dtype),
        interpret=interpret,
    )(x_win, w, scale, bias)
