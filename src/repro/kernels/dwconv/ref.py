"""Pure-jnp oracle for the int8 3x3 depthwise conv kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dwconv3x3_ref(x_pad, w, scale, bias, *, stride: int = 1,
                  activation: str | None = None,
                  out_scale: float | None = None):
    """x_pad: (C, H+2, W+2) int8 pre-padded; w: (C, 3, 3) int8; bias: (C,)
    f32 (real-domain) or int32 (``b_q``, added to the int32 accumulator)."""
    lhs = x_pad[None].astype(jnp.int32)
    rhs = w[:, None].astype(jnp.int32)
    acc = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=x_pad.shape[0],
        preferred_element_type=jnp.int32)[0]
    if jnp.issubdtype(jnp.asarray(bias).dtype, jnp.integer):
        y = (acc + bias[:, None, None]).astype(jnp.float32) * scale[:, None, None]
    else:
        y = acc.astype(jnp.float32) * scale[:, None, None] + bias[:, None, None]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    if out_scale is not None:
        return jnp.clip(jnp.round(y * (1.0 / out_scale)),
                        -127, 127).astype(jnp.int8)
    return y
