"""jit'd wrapper for the depthwise conv kernel: pads channels to the block
multiple and the spatial dims by 1 (SAME padding for 3x3)."""
from __future__ import annotations

import jax.numpy as jnp

from .dwconv import dwconv3x3, dwconv3x3_bands
from .ref import dwconv3x3_ref


def dwconv(x_q, w, scale, bias, *, stride: int = 1, activation=None,
           out_scale=None, block_c: int = 8, interpret: bool | None = None):
    """x_q: (C, H, W) int8 (unpadded); SAME 3x3 depthwise conv.
    ``interpret=None`` auto-detects the backend (see kernels.backend)."""
    c = x_q.shape[0]
    pad_c = (-c) % block_c
    xp = jnp.pad(x_q, ((0, pad_c), (1, 1), (1, 1)))
    wp = jnp.pad(w, ((0, pad_c), (0, 0), (0, 0)))
    sp = jnp.pad(scale, (0, pad_c))
    bp = jnp.pad(bias, (0, pad_c))
    out = dwconv3x3(xp, wp, sp, bp, stride=stride, activation=activation,
                    out_scale=out_scale, block_c=block_c, interpret=interpret)
    return out[:c]


def dwconv_window(x_win, w, scale, bias, *, stride: int = 1, activation=None,
                  out_scale=None, block_c: int = 8, interpret: bool | None = None):
    """3x3 depthwise conv over an explicitly prepared row window (spatial
    band + halo/zero rows already in place, width padded by 1): pads channels
    to the block multiple and runs the kernel VALID over the rows as given.
    ``x_win``: (C, R, W+2) with R = (out_rows-1)*stride + 3."""
    c = x_win.shape[0]
    pad_c = (-c) % block_c
    xp = jnp.pad(x_win, ((0, pad_c), (0, 0), (0, 0)))
    wp = jnp.pad(w, ((0, pad_c), (0, 0), (0, 0)))
    sp = jnp.pad(scale, (0, pad_c))
    bp = jnp.pad(bias, (0, pad_c))
    out = dwconv3x3(xp, wp, sp, bp, stride=stride, activation=activation,
                    out_scale=out_scale, block_c=block_c, interpret=interpret)
    return out[:c]


def dwconv_bands(x_win, w, scale, bias, *, stride: int = 1, activation=None,
                 out_scale=None, block_c: int = 8,
                 interpret: bool | None = None):
    """Batched-band 3x3 depthwise conv over pre-gathered band windows:
    ``x_win`` is (bands, C, R, W+2) with every band's halo/zero rows already
    materialized (shorter bands zero-filled to the common R).  Pads channels
    to the block multiple and runs :func:`dwconv3x3_bands` — the band index
    is a Pallas grid axis, so all bands execute in one kernel invocation."""
    c = x_win.shape[1]
    pad_c = (-c) % block_c
    xp = jnp.pad(x_win, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
    wp = jnp.pad(w, ((0, pad_c), (0, 0), (0, 0)))
    sp = jnp.pad(scale, (0, pad_c))
    bp = jnp.pad(bias, (0, pad_c))
    out = dwconv3x3_bands(xp, wp, sp, bp, stride=stride,
                          activation=activation, out_scale=out_scale,
                          block_c=block_c, interpret=interpret)
    return out[:, :c]


def dwconv_ref(x_q, w, scale, bias, *, stride: int = 1, activation=None,
               out_scale=None):
    xp = jnp.pad(x_q, ((0, 0), (1, 1), (1, 1)))
    return dwconv3x3_ref(xp, w, scale, bias, stride=stride,
                         activation=activation, out_scale=out_scale)
