"""Grouped-query attention (full / sliding-window / cross / decode-with-cache)
in pure JAX, with optional q-chunked streaming softmax so prefill at 32k
doesn't materialize (S, S) score tensors.

All math in fp32 accumulation regardless of activation dtype.
Shapes: q (B, Sq, H, hd); k, v (B, Sk, K, hd) with H = K * G (GQA groups).
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, kv_valid, causal: bool, local_window: int):
    """(B, Sq, Sk) additive bias: 0 where attendable, NEG_INF elsewhere."""
    m = kv_valid[:, None, :]
    if causal:
        m = m & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if local_window > 0:
        m = m & (kv_pos[:, None, :] > q_pos[:, :, None] - local_window)
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def _attend(q, k, v, bias):
    """q: (B,Sq,K,G,hd); k,v: (B,Sk,K,hd); bias: (B,Sq,Sk) -> (B,Sq,K,G,hd).

    Operands stay in their storage dtype (bf16) with f32 MXU accumulation via
    preferred_element_type — explicit f32 casts would double every backward
    collective (cotangents inherit the operand dtype)."""
    scale = jnp.float32(1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits + bias[:, None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out


def gqa_attention(q, k, v, *, q_pos, kv_pos, kv_valid=None, causal=True,
                  local_window: int = 0, chunk: int = 0):
    """q: (B, Sq, K, G, hd); k, v: (B, Sk, K, hd).  Returns (B, Sq, K, G, hd).

    The K (kv-head) dim is the tensor-parallel unit: it stays sharded through
    projection -> scores -> output with no resharding (DESIGN.md §5).
    q_pos: (B, Sq) absolute positions; kv_pos: (B, Sk); kv_valid: (B, Sk)
    bool (False for unwritten cache slots).  chunk > 0 streams the query
    dimension through lax.scan (memory O(Sk * chunk) instead of O(Sq * Sk)).
    """
    b, sq, kdim, g, hd = q.shape
    if kv_valid is None:
        kv_valid = jnp.ones(k.shape[:2], dtype=bool)

    if chunk and sq > chunk and sq % chunk == 0:
        n_chunks = sq // chunk
        qg_c = q.reshape(b, n_chunks, chunk, kdim, g, hd).transpose(1, 0, 2, 3, 4, 5)
        qp_c = q_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def step_math(qc, qp):
            bias = _mask_bias(qp, kv_pos, kv_valid, causal, local_window)
            return _attend(qc, k, v, bias)

        def step(_, qs):
            # rematerialize per-chunk probs in backward: without this the
            # scan stacks every chunk's (.., Sq_chunk, Sk) prob matrix as a
            # saved residual — 10+ GiB/device at 4k x 4k per layer.
            return None, step_math(*qs)

        _, out = jax.lax.scan(step, None, (qg_c, qp_c))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kdim, g, hd)
    else:
        bias = _mask_bias(q_pos, kv_pos, kv_valid, causal, local_window)
        out = _attend(q, k, v, bias)
    return out.astype(q.dtype)


def update_cache(cache_k, cache_v, k_new, v_new, pos: jnp.ndarray):
    """Write k_new/v_new (B, Sn, K, hd) into the cache at ``pos`` (scalar int32
    position of the first new token).  Returns updated (cache_k, cache_v)."""
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    return cache_k, cache_v
