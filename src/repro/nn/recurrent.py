"""Recurrent sequence mixers:

* RG-LRU (Griffin / RecurrentGemma, arXiv:2402.19427) — gated diagonal linear
  recurrence, parallelized over sequence with an associative scan.  Diagonal
  recurrence means channels are independent — the exact analogue of the
  paper's kernel-wise split, so the 'rnn' logical axis shards channels.
* mLSTM (xLSTM, arXiv:2405.04517) — matrix-memory LSTM with exponential
  gating; implemented in the chunkwise-parallel form (sequence chunks with
  carried (C, n, m) state) for train/prefill and a single-step form for
  decode.  Validated against the sequential reference in tests.
* sLSTM — scalar-memory LSTM with recurrent (block-diagonal per head) weights
  and exponential gating; inherently sequential -> lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import ParamDef, swish


# ---------------------------------------------------------------------------
# generic first-order linear recurrence h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def linear_scan(a, b, h0=None, axis: int = 1):
    """Associative scan for h_t = a_t h_{t-1} + b_t (all (..., S, D))."""
    if h0 is not None:
        # fold the carried state into the first step
        b0 = b.take(jnp.array(0), axis=axis) + a.take(jnp.array(0), axis=axis) * h0
        b = jax.lax.dynamic_update_index_in_dim(b, b0, 0, axis)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return h


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_defs(d_model: int, d_rnn: int, conv_width: int,
               prefix_shape=(), prefix_names=()) -> dict:
    ps, pn = prefix_shape, prefix_names
    return {
        "w_x": ParamDef(ps + (d_model, d_rnn), pn + ("embed", "rnn")),
        "w_gate": ParamDef(ps + (d_model, d_rnn), pn + ("embed", "rnn")),
        "w_out": ParamDef(ps + (d_rnn, d_model), pn + ("rnn", "embed")),
        "conv_w": ParamDef(ps + (conv_width, d_rnn), pn + (None, "rnn"),
                           scale=0.5),
        # per-channel gates computed from the recurrence branch input
        "w_a": ParamDef(ps + (d_rnn, d_rnn), pn + ("rnn", "rnn"), scale=0.02),
        "w_i": ParamDef(ps + (d_rnn, d_rnn), pn + ("rnn", "rnn"), scale=0.02),
        "lam": ParamDef(ps + (d_rnn,), pn + ("rnn",), init="ones"),
    }


def causal_conv1d(u, w, state=None):
    """u: (B, S, D); w: (W, D) depthwise causal conv.  ``state``: (B, W-1, D)
    trailing inputs from the previous segment (decode); returns (y, new_state).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)          # (B, S+W-1, D)
    y = sum(ext[:, i:i + u.shape[1], :] * w[i] for i in range(width))
    return y.astype(u.dtype), ext[:, -(width - 1):, :]


def rglru(u, p, h0=None):
    """u: (B, S, dr) post-conv recurrence-branch input.  Returns (h, h_last)."""
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u.astype(jnp.float32))
    h = linear_scan(a, gated, h0=None if h0 is None else h0.astype(jnp.float32))
    return h.astype(u.dtype), h[:, -1, :]


def rglru_block(p, x, cfg, cache=None):
    """Griffin recurrent block: gate branch * (conv -> RG-LRU) branch.
    cache: dict(h=(B,dr), conv=(B,W-1,dr)) or None (train/prefill).
    Returns (y, new_cache)."""
    gate = swish(x @ p["w_gate"])
    u = x @ p["w_x"]
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    h0 = cache["h"] if cache is not None else None
    h, h_last = rglru(u, p, h0=h0)
    y = (h * gate) @ p["w_out"]
    new_cache = {"h": h_last.astype(x.dtype), "conv": new_conv}
    return y, new_cache


# ---------------------------------------------------------------------------
# mLSTM (chunkwise-parallel) — per-head matrix memory
# ---------------------------------------------------------------------------

def mlstm_defs(cfg, prefix_shape=(), prefix_names=()) -> dict:
    d = cfg.d_model
    di = int(cfg.proj_factor * d)
    h = cfg.n_heads
    ps, pn = prefix_shape, prefix_names
    return {
        "w_up": ParamDef(ps + (d, di), pn + ("embed", "ff")),
        "w_gate": ParamDef(ps + (d, di), pn + ("embed", "ff")),
        "conv_w": ParamDef(ps + (4, di), pn + (None, "ff"), scale=0.5),
        "wq": ParamDef(ps + (di, di), pn + ("ff_in", "ff")),
        "wk": ParamDef(ps + (di, di), pn + ("ff_in", "ff")),
        "wv": ParamDef(ps + (di, di), pn + ("ff_in", "ff")),
        "w_if": ParamDef(ps + (d, 2 * h), pn + ("embed", None), scale=0.02),
        "b_if": ParamDef(ps + (2 * h,), pn + (None,), init="zeros"),
        "hnorm": ParamDef(ps + (di,), pn + ("ff",), init="ones"),
        "w_down": ParamDef(ps + (di, d), pn + ("ff_in", "embed")),
    }


def _mlstm_chunk(q, k, v, i_gate, lf, state):
    """One chunk, all heads.  q,k,v: (B, H, L, dk|dv); i_gate/lf: (B, H, L)
    (input gate pre-activation, log-sigmoid forget).  state: (C, n, m) with
    C (B,H,dk,dv), n (B,H,dk), m (B,H).  Returns (h, new_state)."""
    B, H, L, dk = q.shape
    scale = 1.0 / np.sqrt(dk)
    b_cum = jnp.cumsum(lf, axis=-1)                       # (B,H,L)
    # stabilizer: m_t = B_t + max(m_prev, max_{tau<=t}(i_tau - B_tau))
    a_run = jax.lax.cummax(i_gate - b_cum, axis=i_gate.ndim - 1)
    c_prev, n_prev, m_prev = state
    m_t = b_cum + jnp.maximum(m_prev[..., None], a_run)
    # intra-chunk decay matrix D[t,tau] = i_tau + B_t - B_tau - m_t (tau<=t)
    dmat = (i_gate[:, :, None, :] + b_cum[:, :, :, None]
            - b_cum[:, :, None, :] - m_t[..., None])
    mask = jnp.tril(jnp.ones((L, L), bool))
    dexp = jnp.where(mask, jnp.exp(dmat), 0.0)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale * dexp
    inter_decay = jnp.exp(b_cum + m_prev[..., None] - m_t)  # (B,H,L)
    num = jnp.einsum("bhts,bhsv->bhtv", s, v.astype(jnp.float32)) + \
        inter_decay[..., None] * jnp.einsum(
            "bhtd,bhdv->bhtv", q.astype(jnp.float32), c_prev) * scale
    den = s.sum(-1) + inter_decay * jnp.einsum(
        "bhtd,bhd->bht", q.astype(jnp.float32), n_prev) * scale
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # state update to end of chunk
    m_new = m_t[..., -1]
    w_tau = jnp.exp(i_gate + b_cum[..., -1:] - b_cum - m_new[..., None])
    c_new = jnp.exp(b_cum[..., -1] + m_prev - m_new)[..., None, None] * c_prev \
        + jnp.einsum("bhs,bhsd,bhsv->bhdv", w_tau, k.astype(jnp.float32),
                     v.astype(jnp.float32))
    n_new = jnp.exp(b_cum[..., -1] + m_prev - m_new)[..., None] * n_prev \
        + jnp.einsum("bhs,bhsd->bhd", w_tau, k.astype(jnp.float32))
    return h, (c_new, n_new, m_new)


def mlstm_sequence(q, k, v, i_gate, lf, state=None, chunk: int = 256):
    """Chunkwise mLSTM over a full sequence.  q,k,v: (B, S, H, dk);
    gates (B, S, H).  Returns (h (B,S,H,dv), final_state)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (jnp.zeros((B, H, dk, dv), jnp.float32),
                 jnp.zeros((B, H, dk), jnp.float32),
                 jnp.full((B, H), 0.0, jnp.float32))
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def to_chunks(x):  # (B,S,H,*) -> (nc, B, H, L, *)
        x = x.reshape(B, nc, chunk, H, -1).transpose(1, 0, 3, 2, 4)
        return x

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic = i_gate.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)
    fc = lf.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)

    def step(carry, xs):
        qx, kx, vx, ix, fx = xs
        h, new = _mlstm_chunk(qx, kx, vx, ix, fx, carry)
        return new, h

    final, hs = jax.lax.scan(step, state, (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return h.astype(q.dtype), final


def mlstm_step(q, k, v, i_gate, lf, state):
    """Single decode step.  q,k,v: (B, H, dk|dv); gates (B, H)."""
    c_prev, n_prev, m_prev = state
    dk = q.shape[-1]
    scale = 1.0 / np.sqrt(dk)
    m_new = jnp.maximum(lf + m_prev, i_gate)
    i_p = jnp.exp(i_gate - m_new)
    f_p = jnp.exp(lf + m_prev - m_new)
    c_new = f_p[..., None, None] * c_prev + i_p[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    n_new = f_p[..., None] * n_prev + i_p[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), c_new) * scale
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_new) * scale
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM — sequential scan with block-diagonal recurrent weights
# ---------------------------------------------------------------------------

def slstm_defs(cfg, prefix_shape=(), prefix_names=()) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ps, pn = prefix_shape, prefix_names
    dff = int(4 * d / 3 // 64 * 64) or d
    return {
        "w_in": ParamDef(ps + (d, 4 * d), pn + ("embed", "ff")),     # z,i,f,o
        "r": ParamDef(ps + (4, h, dh, dh), pn + (None, "heads", None, None),
                      scale=0.02),
        "b": ParamDef(ps + (4 * d,), pn + (None,), init="zeros"),
        "up": ParamDef(ps + (d, dff), pn + ("embed", "ff")),
        "down": ParamDef(ps + (dff, d), pn + ("ff_in", "embed")),
    }


def slstm_sequence(p, x, n_heads: int, state=None):
    """x: (B, S, d).  Returns (h_seq (B,S,d), final_state)."""
    B, S, d = x.shape
    dh = d // n_heads
    pre = x @ p["w_in"] + p["b"]                      # (B, S, 4d)
    if state is None:
        z0 = jnp.zeros((B, d), jnp.float32)
        state = (z0, z0 + 1e-6, z0, z0 - 10.0)        # c, n, h, m

    r = p["r"].astype(jnp.float32)                    # (4, H, dh, dh)

    def step(carry, pre_t):
        c, n, h, m = carry
        hh = h.reshape(B, n_heads, dh)
        rec = jnp.einsum("bhd,ghde->bghe", hh, r).reshape(B, 4 * d)
        g = pre_t.astype(jnp.float32) + rec
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    final, hs = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(x.dtype), final
