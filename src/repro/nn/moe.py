"""Mixture-of-Experts FFN (GShard-style capacity dispatch) with two dispatch
implementations:

* ``einsum`` — the classic one-hot dispatch/combine einsums (GShard
  [arXiv:2006.16668]).  Simple, but the dispatch einsums burn
  O(T * E * C * d) FLOPs — visible in the roofline compute term.
* ``gather`` — FLOP-free dispatch: position-in-expert via cumsum, then
  take_along_axis gathers into the capacity buffer and back.  This is the
  beyond-paper optimization evaluated in EXPERIMENTS.md §Perf.

Experts are sharded over the mesh (EP): 'experts' logical axis; token groups
shard over data.  Tokens over capacity are dropped (standard GShard), with
the residual connection preserving their activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_act
from .layers import ParamDef, swish


def moe_defs(cfg, prefix_shape=(), prefix_names=()) -> dict:
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ps, pn = prefix_shape, prefix_names
    defs = {
        "router": ParamDef(ps + (d, e), pn + ("embed", None), scale=0.02),
        "wi": ParamDef(ps + (e, d, ff), pn + ("experts", "embed", "expert_ff")),
        "wg": ParamDef(ps + (e, d, ff), pn + ("experts", "embed", "expert_ff")),
        "wo": ParamDef(ps + (e, ff, d), pn + ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared_experts:
        sff = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared_wi"] = ParamDef(ps + (d, sff), pn + ("embed", "ff"))
        defs["shared_wg"] = ParamDef(ps + (d, sff), pn + ("embed", "ff"))
        defs["shared_wo"] = ParamDef(ps + (sff, d), pn + ("ff_in", "embed"))
    return defs


def _expert_ffn(p, x):
    """x: (G, E, C, d) -> (G, E, C, d); per-expert SwiGLU."""
    h = jnp.einsum("gecd,edf->gecf", x, p["wi"])
    g = jnp.einsum("gecd,edf->gecf", x, p["wg"])
    h = swish(g) * h
    return jnp.einsum("gecf,efd->gecd", h, p["wo"])


def _shared_ffn(p, x):
    h = swish(x @ p["shared_wg"]) * (x @ p["shared_wi"])
    return h @ p["shared_wo"]


def _top_k_routing(logits, top_k):
    """Returns (weights (T,k) fp32 normalized, idx (T,k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def moe_ffn(p, x, cfg):
    """x: (B, S, d) -> (B, S, d).  Groups of ``moe_group_size`` tokens are
    dispatched independently (bounds the dispatch tensor)."""
    b, s, d = x.shape
    t = b * s
    gs = min(cfg.moe_group_size, t)
    pad = (-t) % gs
    xf = x.reshape(t, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], axis=0)
    ng = (t + pad) // gs
    xt = xf.reshape(ng, gs, d)
    xt = shard_act(xt, ("moe_groups", None, None))
    valid = (jnp.arange(t + pad) < t).reshape(ng, gs)
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(k * gs / e * cfg.capacity_factor), 1)

    logits = jnp.einsum("gsd,de->gse", xt, p["router"])
    weights, idx = _top_k_routing(logits.reshape(ng * gs, e), k)
    weights = weights.reshape(ng, gs, k) * valid[..., None]
    idx = idx.reshape(ng, gs, k)
    idx = jnp.where(valid[..., None], idx, e - 1)  # park padding on one expert

    # position of each (token, choice) within its expert: cumsum over the
    # flattened (token-major, choice-minor) order
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32) * \
        valid[..., None, None].astype(jnp.int32)             # (g, s, k, e)
    flat = onehot.reshape(ng, gs * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                        # (g, s*k, e)
    pos_tok = (pos * flat).sum(-1).reshape(ng, gs, k)         # (g, s, k)
    keep = (pos_tok < cap) & (pos_tok >= 0) & valid[..., None]
    weights = weights * keep

    if cfg.moe_impl == "einsum":
        # GShard dispatch/combine one-hot einsums (baseline)
        disp = (jax.nn.one_hot(idx, e, dtype=xt.dtype)[..., :, None]
                * jax.nn.one_hot(pos_tok, cap, dtype=xt.dtype)[..., None, :]
                * keep[..., None, None].astype(xt.dtype))     # (g,s,k,e,cap)
        disp = disp.sum(2)                                    # (g,s,e,cap)
        disp = shard_act(disp, ("moe_groups", None, "act_experts", None))
        ex_in = jnp.einsum("gsec,gsd->gecd", disp, xt)
        ex_out = _expert_ffn(p, ex_in)
        comb = jnp.einsum(
            "gske,gskc->gsec",
            jax.nn.one_hot(idx, e, dtype=jnp.float32) * weights[..., None],
            jax.nn.one_hot(pos_tok, cap, dtype=jnp.float32) * keep[..., None])
        out = jnp.einsum("gsec,gecd->gsd", comb.astype(xt.dtype), ex_out)
    else:
        # gather dispatch (optimized): build a (g, e, cap) source-token table
        # by scatter, then pure gathers — no O(T*E*C*d) dispatch FLOPs.
        tok_ids = jnp.broadcast_to(jnp.arange(gs)[None, :, None], idx.shape)
        flat_e = idx.reshape(ng, gs * k)
        flat_pos = pos_tok.reshape(ng, gs * k)
        flat_tok = tok_ids.reshape(ng, gs * k)
        flat_keep = keep.reshape(ng, gs * k)
        safe_pos = jnp.where(flat_keep, flat_pos, cap)   # overflow -> trash slot
        gidx = jnp.broadcast_to(jnp.arange(ng)[:, None], flat_e.shape)
        buf_src = jnp.zeros((ng, e, cap + 1), jnp.int32)
        buf_src = buf_src.at[gidx, flat_e, safe_pos].set(flat_tok)
        buf_src = buf_src[..., :cap]                          # (g, e, cap)
        ex_in = xt[jnp.arange(ng)[:, None, None], buf_src]    # (g, e, cap, d)
        ex_in = shard_act(ex_in, ("moe_groups", "act_experts", None, None))
        ex_out = _expert_ffn(p, ex_in)                         # (g, e, cap, d)
        # combine: gather each token's k expert outputs from the buffer
        flat_out = ex_out.reshape(ng, e * cap, d)
        slot = idx * cap + jnp.minimum(pos_tok, cap - 1)       # (g, s, k)
        gathered = flat_out[jnp.arange(ng)[:, None, None], slot]  # (g,s,k,d)
        out = (gathered * weights[..., None].astype(xt.dtype)).sum(2)

    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, xt)
    out = out.reshape(-1, d)
    if pad:
        out = out[:t]
    return out.reshape(b, s, d)
