"""Parameter definition machinery + primitive layers (pure JAX).

Models declare a nested dict of :class:`ParamDef` (shape + logical axis
names + init); the same tree drives real initialization, abstract
(ShapeDtypeStruct) initialization for the dry-run, and sharding-spec
derivation — so the dry-run never allocates parameter memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    names: tuple[str | None, ...]       # logical axes (see parallel/sharding)
    init: str = "normal"                # normal | zeros | ones
    scale: float | None = None          # stddev; None -> 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype=None):
    """Materialize a ParamDef tree into arrays (splitting the key per leaf)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def mk(d: ParamDef, k):
        dt = dtype or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        fan_in = d.shape[0] if len(d.shape) == 1 else int(np.prod(d.shape[:-1]))
        # stacked-layer params: leading 'layers' axis is not fan-in
        if len(d.shape) >= 2 and d.names[0] == "layers":
            fan_in = int(np.prod(d.shape[1:-1])) or 1
        scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def abstract_params(defs, dtype=None):
    """ShapeDtypeStruct tree — free 'initialization' for lower()/compile()."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype),
        defs, is_leaf=_is_def)


def spec_tree(defs):
    """Tree of logical-name tuples (consumed by parallel.sharding)."""
    return jax.tree.map(lambda d: tuple(d.names), defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=_is_def))


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def norm_defs(d: int, kind: str, prefix_shape: tuple[int, ...] = (),
              prefix_names: tuple[str, ...] = ()) -> dict:
    out = {"scale": ParamDef(prefix_shape + (d,), prefix_names + ("act_embed",), init="ones")}
    if kind == "layernorm":
        out["bias"] = ParamDef(prefix_shape + (d,), prefix_names + ("act_embed",), init="zeros")
    return out


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, ..., hd) with any number of head axes; positions: (B, S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B, S, hd/2)
    head_axes = (1,) * (x.ndim - 3)
    ang = ang.reshape(*ang.shape[:2], *head_axes, hd // 2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swish(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap)
