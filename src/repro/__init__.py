"""repro — Split CNN Inference on Networked Microcontrollers (JAX/Pallas).

The supported entry point is the coordinator facade in :mod:`repro.api`
(``Cluster`` / ``Planner`` / ``Session``), re-exported lazily here::

    from repro import Cluster, Objective, Planner

Lazy on purpose (PEP 562): importing ``repro`` must stay free of jax so
that ``repro.launch.dryrun`` (and the subprocess tests) can still set
``XLA_FLAGS`` at module top *before* the first jax import — jax locks the
device count on first init.
"""
from __future__ import annotations

_API_NAMES = (
    "Cluster",
    "ClusterError",
    "InfeasibleError",
    "Objective",
    "Plan",
    "PlanCandidate",
    "Planner",
    "Session",
    "SessionStats",
    "Ticket",
)

__all__ = list(_API_NAMES) + ["api", "core", "models", "serve"]


def __getattr__(name: str):
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    if name in ("api", "core", "models", "serve"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
