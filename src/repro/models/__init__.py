from . import lm
from .mobilenetv2 import mobilenet_v2, mobilenet_v2_paper, mobilenet_v2_smoke

__all__ = ["lm", "mobilenet_v2", "mobilenet_v2_paper", "mobilenet_v2_smoke"]
