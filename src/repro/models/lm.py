"""Generic LM-family model builder covering all assigned architectures.

A model is a sequence of *block stacks*; each stack is a repeating pattern of
block kinds scanned over its group axis (params stacked on a leading 'layers'
dim -> small HLO, fast multi-pod compiles):

  dense / vlm : [('attn',) x L]
  moe         : [('moe',) x L]
  hybrid      : [('rec','rec','attn') x L//3] (+ remainder stack)
  ssm         : [('mlstm' x (k-1), 'slstm') x L//k] (+ remainder)
  audio       : encoder [('enc_attn',) x Le] + decoder [('xattn',) x Ld]

Execution modes: 'train' (logits for loss), 'prefill' (logits + filled KV /
recurrent caches), 'decode' (single token against caches).  The modality
frontends of the audio/vlm archs are stubs per the assignment: inputs carry
precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..nn.attention import gqa_attention
from ..nn.layers import (ParamDef, abstract_params, apply_norm, apply_rope,
                         gelu, init_params, norm_defs, rmsnorm, spec_tree,
                         swish)
from ..nn.moe import moe_defs, moe_ffn
from ..nn.recurrent import (mlstm_defs, mlstm_sequence, mlstm_step,
                            rglru_block, rglru_defs, slstm_defs,
                            slstm_sequence)
from ..parallel.sharding import shard_act


# ---------------------------------------------------------------------------
# pattern machinery
# ---------------------------------------------------------------------------

def pattern_stacks(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(pattern, n_groups), ...] covering exactly cfg.n_layers blocks."""
    if cfg.family == "audio":
        return [(("xattn",), cfg.n_layers)]
    if cfg.family == "moe":
        return [(("moe",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(cfg.block_pattern)
        n, r = divmod(cfg.n_layers, len(pat))
        stacks = [(pat, n)] if n else []
        if r:
            stacks.append((pat[:r], 1))
        return stacks
    if cfg.family == "ssm":
        k = cfg.slstm_every or cfg.n_layers + 1
        if k > cfg.n_layers:
            return [(("mlstm",), cfg.n_layers)]
        pat = ("mlstm",) * (k - 1) + ("slstm",)
        n, r = divmod(cfg.n_layers, k)
        stacks = [(pat, n)] if n else []
        if r:
            stacks.append((("mlstm",) * r, 1))
        return stacks
    return [(("attn",), cfg.n_layers)]     # dense, vlm


def _attn_defs(cfg: ModelConfig, ng: int, cross: bool = False) -> dict:
    """Head-structured projection weights (d, K, G, hd).

    Keeping the head axes explicit lets the sharding rules split kv-heads
    (the paper's kernel-wise unit) when they divide the mesh — e.g.
    deepseek-moe's K=16 on a 16-way model axis — while the fit-to-shape rule
    falls back to FSDP-only for K=8 archs, where attention parallelism comes
    from the *sequence* dim instead (see _attn_act_names): 40 q-heads or 8
    kv-heads never divide 16 and uneven GSPMD shardings caused involuntary
    full-remat copies."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    g = h // kv
    ps, pn = (ng,), ("layers",)
    # TP axis: K (kv heads) for GQA/MHA; the G (q-group) axis for MQA (K==1).
    ax_k = "kv_heads" if kv > 1 else None
    ax_g = "heads" if kv == 1 else None
    defs = {
        "ln": norm_defs(d, cfg.norm, ps, pn),
        "wq": ParamDef(ps + (d, kv, g, hd), pn + ("embed", ax_k, ax_g, None)),
        "wk": ParamDef(ps + (d, kv, hd), pn + ("embed", ax_k, None)),
        "wv": ParamDef(ps + (d, kv, hd), pn + ("embed", ax_k, None)),
        "wo": ParamDef(ps + (kv, g, hd, d), pn + (ax_k, ax_g, None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef(ps + (kv, g, hd), pn + (ax_k, ax_g, None), init="zeros")
        defs["bk"] = ParamDef(ps + (kv, hd), pn + (ax_k, None), init="zeros")
        defs["bv"] = ParamDef(ps + (kv, hd), pn + (ax_k, None), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef(ps + (hd,), pn + (None,), init="ones")
        defs["k_norm"] = ParamDef(ps + (hd,), pn + (None,), init="ones")
    return defs


def _mlp_defs(cfg: ModelConfig, ng: int) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ps, pn = (ng,), ("layers",)
    defs = {
        "ln": norm_defs(d, cfg.norm, ps, pn),
        "wi": ParamDef(ps + (d, ff), pn + ("embed", "ff")),
        "wo": ParamDef(ps + (ff, d), pn + ("ff_in", "embed")),
    }
    if cfg.act == "swiglu":
        defs["wg"] = ParamDef(ps + (d, ff), pn + ("embed", "ff"))
    return defs


def block_defs(kind: str, cfg: ModelConfig, ng: int) -> dict:
    ps, pn = (ng,), ("layers",)
    if kind in ("attn", "enc_attn"):
        return {"attn": _attn_defs(cfg, ng), "mlp": _mlp_defs(cfg, ng)}
    if kind == "xattn":
        return {"attn": _attn_defs(cfg, ng),
                "xa": _attn_defs(cfg, ng, cross=True),
                "mlp": _mlp_defs(cfg, ng)}
    if kind == "moe":
        return {"attn": _attn_defs(cfg, ng),
                "moe_ln": norm_defs(cfg.d_model, cfg.norm, ps, pn),
                "moe": moe_defs(cfg, ps, pn)}
    if kind == "rec":
        return {"ln": norm_defs(cfg.d_model, cfg.norm, ps, pn),
                "rec": rglru_defs(cfg.d_model, cfg.d_rnn or cfg.d_model,
                                  cfg.conv_width, ps, pn),
                "mlp": _mlp_defs(cfg, ng)}
    if kind == "mlstm":
        return {"ln": norm_defs(cfg.d_model, cfg.norm, ps, pn),
                "cell": mlstm_defs(cfg, ps, pn)}
    if kind == "slstm":
        return {"ln": norm_defs(cfg.d_model, cfg.norm, ps, pn),
                "cell": slstm_defs(cfg, ps, pn)}
    raise ValueError(kind)


def model_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02),
        "out_ln": norm_defs(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.padded_vocab), ("embed", "vocab"))
    defs["stacks"] = [
        {f"{i}_{kind}": block_defs(kind, cfg, ng)
         for i, kind in enumerate(pattern)}
        for pattern, ng in pattern_stacks(cfg)
    ]
    if cfg.family == "audio":
        defs["encoder"] = {
            "stacks": [{f"0_enc_attn": block_defs("enc_attn", cfg,
                                                  cfg.n_encoder_layers)}],
            "out_ln": norm_defs(d, cfg.norm),
        }
    if cfg.family == "vlm":
        defs["mm_proj"] = ParamDef((d, d), ("embed", "act_embed"))
    return defs


def init_model(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    return init_params(model_defs(cfg), key, dtype=dt)


def abstract_model(cfg: ModelConfig):
    return abstract_params(model_defs(cfg), dtype=jnp.dtype(cfg.dtype))


def model_spec_tree(cfg: ModelConfig):
    return spec_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    mode: str                      # train | prefill | decode
    positions: jnp.ndarray         # (B, S) absolute positions
    enc_out: jnp.ndarray | None = None   # (B, F, d) encoder output (audio)
    causal: bool = True


def _sinusoid(positions, d):
    """(B, S) -> (B, S, d) fixed sinusoidal embeddings (whisper-style)."""
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_act_names(cfg: ModelConfig, mode: str):
    """Sharding names for q (5D) / kv (4D).

    Attention parallelism is *sequence-parallel*: q keeps its seq dim sharded
    through scores -> softmax -> output (all seq-local math, zero attention
    collectives), while k/v are replicated along model (they are GQA-small).
    Head-dim sharding is deliberately avoided: 40 q-heads / 8 kv-heads never
    divide a 16-way axis and uneven GSPMD shardings triggered involuntary
    full-remat copies (see DESIGN.md §5).  Decode has q_len=1, so q is
    replicated and balance comes from the seq-sharded KV cache instead."""
    if mode == "decode":
        return ("batch", None, None, None, None), ("batch", None, None, None)
    return ("batch", "seq", None, None, None), ("batch", None, None, None)


def _project_qkv(p, xn, ctx: Ctx, rope: bool = True):
    """Returns q (B, S, K, G, hd); k, v (B, S, K, hd) — K-sharded."""
    cfg = ctx.cfg
    q = jnp.einsum("bsd,dkgh->bskgh", xn, p["wq"].astype(xn.dtype))
    k = jnp.einsum("bsd,dkh->bskh", xn, p["wk"].astype(xn.dtype))
    v = jnp.einsum("bsd,dkh->bskh", xn, p["wv"].astype(xn.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, ctx.positions, cfg.rope_theta)
        k = apply_rope(k, ctx.positions, cfg.rope_theta)
    qn, kn = _attn_act_names(cfg, ctx.mode)
    q = shard_act(q, qn)
    k = shard_act(k, kn)
    v = shard_act(v, kn)
    return q, k, v


def _apply_attn(p, x, ctx: Ctx, cache, *, local_window=0, cross=False):
    """Self- or cross-attention sublayer.  Returns (x + attnout, new_cache)."""
    cfg = ctx.cfg
    b, s, d = x.shape
    xn = apply_norm(x, p["ln"], cfg.norm, 1e-6)
    new_cache = cache
    qn, kn = _attn_act_names(cfg, ctx.mode)
    if cross:
        # cross-attention: kv precomputed from encoder output (prefill) and
        # stored in cache for decode.
        q = jnp.einsum("bsd,dkgh->bskgh", xn, p["wq"].astype(xn.dtype))
        q = shard_act(q, qn)
        if cache is not None and ctx.mode == "decode":
            k, v = cache["k"], cache["v"]
        else:
            eo = ctx.enc_out.astype(xn.dtype)
            k = jnp.einsum("bfd,dkh->bfkh", eo, p["wk"].astype(xn.dtype))
            v = jnp.einsum("bfd,dkh->bfkh", eo, p["wv"].astype(xn.dtype))
            if cache is not None:
                new_cache = {"k": k.astype(cache["k"].dtype),
                             "v": v.astype(cache["v"].dtype)}
        kv_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], k.shape[:2])
        out = gqa_attention(q, k, v, q_pos=ctx.positions, kv_pos=kv_pos,
                            causal=False, chunk=cfg.attn_chunk)
    else:
        q, k, v = _project_qkv(p, xn, ctx, rope=True)
        if ctx.mode == "decode":
            w = cache["k"].shape[1]
            pos = ctx.positions[0, 0]
            slot = pos % w if local_window else jnp.minimum(pos, w - 1)
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, slot, 0, 0))
            kv_pos_store = cache["kv_pos"].at[slot].set(pos)
            new_cache = {"k": ck, "v": cv, "kv_pos": kv_pos_store}
            kv_pos = jnp.broadcast_to(kv_pos_store[None], (b, w))
            valid = (kv_pos >= 0) & (kv_pos <= pos)
            out = gqa_attention(q, ck, cv, q_pos=ctx.positions, kv_pos=kv_pos,
                                kv_valid=valid, causal=True,
                                local_window=local_window, chunk=0)
        else:
            kv_pos = ctx.positions
            out = gqa_attention(q, k, v, q_pos=ctx.positions, kv_pos=kv_pos,
                                causal=ctx.causal, local_window=local_window,
                                chunk=cfg.attn_chunk)
            if cache is not None:   # prefill: persist (window of) kv
                w = cache["k"].shape[1]
                if s >= w:
                    ks, vs, kp = k[:, s - w:], v[:, s - w:], kv_pos[0, s - w:]
                    if local_window:
                        # ring layout: position p lives at slot p % w so that
                        # decode's slot = pos % w overwrites the oldest entry.
                        order = np.argsort((s - w + np.arange(w)) % w)
                        ks, vs, kp = ks[:, order], vs[:, order], kp[order]
                else:
                    # pad at the end; position p already sits at slot p (p < w)
                    pad = w - s
                    ks = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vs = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    kp = jnp.pad(kv_pos[0], ((0, pad),), constant_values=-1)
                new_cache = {"k": ks.astype(cache["k"].dtype),
                             "v": vs.astype(cache["v"].dtype),
                             "kv_pos": kp}
    # row-parallel output projection: contract (K, G, hd); K sharded ->
    # partial sums -> all-reduce (the 'direct' routing reduce pattern)
    proj = jnp.einsum("bskgh,kghd->bsd", out.astype(x.dtype),
                      p["wo"].astype(x.dtype))
    return x + proj, new_cache


def _apply_mlp(p, x, ctx: Ctx):
    cfg = ctx.cfg
    xn = apply_norm(x, p["ln"], cfg.norm, 1e-6)
    h = xn @ p["wi"]
    if cfg.act == "swiglu":
        h = swish(xn @ p["wg"]) * h
    else:
        h = gelu(h)
    h = shard_act(h, ("batch", None, "act_ff"))
    return x + (h @ p["wo"]).astype(x.dtype)


def apply_block(kind: str, p, x, ctx: Ctx, cache):
    """Returns (x, new_cache_for_block)."""
    cfg = ctx.cfg
    if kind in ("attn", "enc_attn"):
        lw = cfg.local_window if (kind == "attn" and cfg.family == "hybrid") else 0
        x, c = _apply_attn(p["attn"], x, ctx, cache,
                           local_window=lw)
        x = _apply_mlp(p["mlp"], x, ctx)
        return x, c
    if kind == "xattn":
        x, c_self = _apply_attn(p["attn"], x, ctx,
                                None if cache is None else cache.get("self"))
        x, c_cross = _apply_attn(p["xa"], x, ctx,
                                 None if cache is None else cache.get("cross"),
                                 cross=True)
        x = _apply_mlp(p["mlp"], x, ctx)
        c = None if cache is None else {"self": c_self, "cross": c_cross}
        return x, c
    if kind == "moe":
        x, c = _apply_attn(p["attn"], x, ctx, cache)
        xn = apply_norm(x, p["moe_ln"], cfg.norm, 1e-6)
        x = x + moe_ffn(p["moe"], xn, cfg).astype(x.dtype)
        return x, c
    if kind == "rec":
        xn = apply_norm(x, p["ln"], cfg.norm, 1e-6)
        y, c = rglru_block(p["rec"], xn, cfg, cache=cache)
        x = x + y.astype(x.dtype)
        x = _apply_mlp(p["mlp"], x, ctx)
        return x, c
    if kind == "mlstm":
        xn = apply_norm(x, p["ln"], cfg.norm, 1e-6)
        cell = p["cell"]
        b, s, d = xn.shape
        di = int(cfg.proj_factor * d)
        hh = cfg.n_heads
        dk = di // hh
        u = xn @ cell["w_up"]
        z = xn @ cell["w_gate"]
        from ..nn.recurrent import causal_conv1d
        conv_state = None if cache is None else cache["conv"]
        cu, new_conv = causal_conv1d(u, cell["conv_w"], conv_state)
        cu = swish(cu)
        q = (cu @ cell["wq"]).reshape(b, s, hh, dk)
        k = (cu @ cell["wk"]).reshape(b, s, hh, dk) / np.sqrt(dk)
        v = (u @ cell["wv"]).reshape(b, s, hh, dk)
        gates = xn @ cell["w_if"] + cell["b_if"]
        i_gate = gates[..., :hh].astype(jnp.float32)
        lf = jax.nn.log_sigmoid(gates[..., hh:].astype(jnp.float32))
        state = None if cache is None else (cache["C"], cache["n"], cache["m"])
        if ctx.mode == "decode":
            h, (C, n, m) = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                      i_gate[:, 0], lf[:, 0], state)
            h = h[:, None]
        else:
            h, (C, n, m) = mlstm_sequence(q, k, v, i_gate, lf, state=state,
                                          chunk=cfg.mlstm_chunk)
        h = rmsnorm(h.reshape(b, s, di), cell["hnorm"])
        y = (h * swish(z)) @ cell["w_down"]
        new_cache = None if cache is None else {
            "C": C, "n": n, "m": m, "conv": new_conv}
        if ctx.mode == "prefill":
            new_cache = {"C": C, "n": n, "m": m, "conv": new_conv}
        return x + y.astype(x.dtype), new_cache
    if kind == "slstm":
        xn = apply_norm(x, p["ln"], cfg.norm, 1e-6)
        cell = p["cell"]
        state = None if cache is None else (cache["c"], cache["n"],
                                            cache["h"], cache["m"])
        h, (c_, n_, h_, m_) = slstm_sequence(cell, xn, cfg.n_heads, state=state)
        y = gelu(h @ cell["up"]) @ cell["down"]
        new_cache = None
        if cache is not None or ctx.mode == "prefill":
            new_cache = {"c": c_, "n": n_, "h": h_, "m": m_}
        return x + y.astype(x.dtype), new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _attn_window(cfg: ModelConfig, kind: str, max_seq: int) -> int:
    if kind == "attn" and cfg.family == "hybrid" and cfg.local_window:
        return min(cfg.local_window, max_seq)
    return max_seq


def block_cache(kind: str, cfg: ModelConfig, ng: int, batch: int,
                max_seq: int, dtype) -> dict | None:
    hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
    d = cfg.d_model

    def attn_cache(window):
        return {"k": jnp.zeros((ng, batch, window, kv, hd), dtype),
                "v": jnp.zeros((ng, batch, window, kv, hd), dtype),
                "kv_pos": jnp.full((ng, window), -1, jnp.int32)}

    if kind == "attn":
        return attn_cache(_attn_window(cfg, kind, max_seq))
    if kind == "xattn":
        return {"self": attn_cache(max_seq),
                "cross": {"k": jnp.zeros((ng, batch, cfg.n_audio_frames, kv, hd), dtype),
                          "v": jnp.zeros((ng, batch, cfg.n_audio_frames, kv, hd), dtype)}}
    if kind == "moe":
        return attn_cache(max_seq)
    if kind == "rec":
        dr = cfg.d_rnn or d
        return {"h": jnp.zeros((ng, batch, dr), dtype),
                "conv": jnp.zeros((ng, batch, cfg.conv_width - 1, dr), dtype)}
    if kind == "mlstm":
        di = int(cfg.proj_factor * d)
        dk = di // cfg.n_heads
        return {"C": jnp.zeros((ng, batch, cfg.n_heads, dk, dk), jnp.float32),
                "n": jnp.zeros((ng, batch, cfg.n_heads, dk), jnp.float32),
                "m": jnp.zeros((ng, batch, cfg.n_heads), jnp.float32),
                "conv": jnp.zeros((ng, batch, 3, di), dtype)}
    if kind == "slstm":
        z = jnp.zeros((ng, batch, d), jnp.float32)
        return {"c": z, "n": z + 1e-6, "h": z, "m": z - 10.0}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache = {"pos": jnp.zeros((), jnp.int32), "stacks": []}
    for pattern, ng in pattern_stacks(cfg):
        cache["stacks"].append({
            f"{i}_{kind}": block_cache(kind, cfg, ng, batch, max_seq, dtype)
            for i, kind in enumerate(pattern)})
    return cache


# ---------------------------------------------------------------------------
# top-level forward
# ---------------------------------------------------------------------------

def _run_stacks(params, x, ctx: Ctx, cache, cfg: ModelConfig,
                stacks=None):
    """Scan each stack over its group axis.  Returns (x, new_caches)."""
    new_caches = []
    for si, (pattern, ng) in enumerate(stacks or pattern_stacks(cfg)):
        stack_params = params["stacks"][si]
        stack_cache = None if cache is None else cache["stacks"][si]

        carry_seq = ctx.mode != "decode" and cfg.family not in ("ssm",)
        carry_names = ("batch", "seq" if carry_seq else None, "act_embed")

        def body(xc, xs, pattern=pattern, carry_names=carry_names):
            gp, gc = xs
            # constraint on the scan carry: under sequence parallelism the
            # per-layer saved residual is sharded (batch x seq), which is
            # what keeps 40-60 saved carries per stack inside HBM.  The ssm
            # family shards channels instead (recurrences are sequential in
            # seq but diagonal/head-local in channels).
            xc = shard_act(xc, carry_names)
            new_gc = {}
            for i, kind in enumerate(pattern):
                key = f"{i}_{kind}"
                bc = None if gc is None else gc[key]
                xc, nc = apply_block(kind, gp[key], xc, ctx, bc)
                new_gc[key] = nc
            return xc, (new_gc if gc is not None else 0)

        if cfg.remat:
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        x, ys = jax.lax.scan(body, x, (stack_params, stack_cache))
        new_caches.append(ys if stack_cache is not None else None)
    return x, new_caches


def forward(params, inputs: dict, cfg: ModelConfig, mode: str = "train",
            cache=None):
    """inputs: {'tokens': (B, S)} [+ 'frames' (B, F, d) | 'patches' (B, P, d)].

    train   -> logits (B, S_total, V)
    prefill -> (last-position logits (B, V), filled cache)
    decode  -> (logits (B, V), updated cache); tokens is (B, 1)
    """
    dt = jnp.dtype(cfg.dtype)
    tokens = inputs["tokens"]
    b = tokens.shape[0]
    d = cfg.d_model

    if mode == "decode":
        pos0 = cache["pos"]
        positions = jnp.full((b, 1), pos0, jnp.int32)
    else:
        positions = None  # set after frontend concat below

    x = params["embed"].astype(dt)[tokens]
    enc_out = None
    if cfg.family == "vlm" and mode != "decode":
        patches = inputs["patches"].astype(dt) @ params["mm_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.family == "audio" and mode != "decode":
        f = inputs["frames"].shape[1]
        fpos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
        xe = inputs["frames"].astype(dt) + _sinusoid(fpos, d).astype(dt)
        ectx = Ctx(cfg=cfg, mode="train", positions=fpos, causal=False)
        xe, _ = _run_stacks(params["encoder"], xe, ectx, None, cfg,
                            stacks=[(("enc_attn",), cfg.n_encoder_layers)])
        enc_out = apply_norm(xe, params["encoder"]["out_ln"], cfg.norm, 1e-6)

    if positions is None:
        s_total = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s_total)[None], (b, s_total))
    if cfg.rope_theta == 0:   # whisper: absolute sinusoidal positions
        x = x + _sinusoid(positions, d).astype(dt)
    x = shard_act(x, ("batch", "seq", "act_embed"))

    ctx = Ctx(cfg=cfg, mode=mode, positions=positions, enc_out=enc_out)
    run_cache = cache if mode in ("decode", "prefill") else None
    x, new_stack_caches = _run_stacks(params, x, ctx, run_cache, cfg)
    x = apply_norm(x, params["out_ln"], cfg.norm, 1e-6)

    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    if mode == "train":
        x = shard_act(x, ("batch", None, "act_embed"))
        logits = x @ head
        logits = shard_act(logits, ("batch", None, "vocab"))
        return logits
    if mode == "prefill":
        logits = x[:, -1, :] @ head
        new_cache = {"pos": jnp.asarray(x.shape[1], jnp.int32),
                     "stacks": new_stack_caches}
        return logits, new_cache
    # decode
    logits = x[:, 0, :] @ head
    new_cache = {"pos": cache["pos"] + 1, "stacks": new_stack_caches}
    return logits, new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch: dict, cfg: ModelConfig):
    """Next-token cross entropy (prefix positions from stub frontends and the
    final position are excluded).  batch: inputs + optional 'loss_mask'."""
    logits = forward(params, batch, cfg, mode="train")
    tokens = batch["tokens"]
    prefix = logits.shape[1] - tokens.shape[1]
    logits = logits[:, prefix:, :]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, :].astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:   # mask padded vocab columns
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        lg = jnp.where(pad_mask[None, None, :], -1e30, lg)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(nll) if mask is None else mask[:, 1:].astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
