"""MobileNetV2 (Sandler et al., CVPR'18 — the paper's evaluation model) as a
reinterpreted layer list, with conv+BN+ReLU6 pre-fused (paper §V.D: BN folded
into conv weights/bias).

The paper evaluates at input resolution 112x112x3; ``width_mult`` and
``input_hw`` allow the reduced smoke configs.  Weights are randomly
initialized (the paper's pipeline starts from a pre-trained checkpoint; the
splitting/routing/allocation machinery is weight-agnostic).
"""
from __future__ import annotations

import numpy as np

from ..core.fusion import BatchNormParams, fold_batchnorm
from ..core.reinterpret import ReinterpretedModel, trace_sequential

# (expansion t, out channels c, repeats n, stride s) — Table 2 of MobileNetV2
_INVERTED_RESIDUAL_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def _make_divisible(v: float, divisor: int = 8) -> int:
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def _fused_conv_weight(rng, cout, cin, k):
    """Random conv weight with a random BN folded in — exercises fusion.py on
    every layer exactly as the offline preprocessing does."""
    fan_in = cin * k * k
    w = rng.standard_normal((cout, cin, k, k)).astype(np.float32) * np.sqrt(2.0 / fan_in)
    bn = BatchNormParams(
        gamma=rng.uniform(0.5, 1.5, cout).astype(np.float32),
        beta=rng.uniform(-0.1, 0.1, cout).astype(np.float32),
        mean=rng.uniform(-0.1, 0.1, cout).astype(np.float32),
        var=rng.uniform(0.5, 1.5, cout).astype(np.float32))
    return fold_batchnorm(w, None, bn)


def mobilenet_v2(input_hw: tuple[int, int] = (112, 112), width_mult: float = 1.0,
                 num_classes: int = 1000, seed: int = 0,
                 cfg=None) -> ReinterpretedModel:
    rng = np.random.default_rng(seed)
    cfg = cfg or _INVERTED_RESIDUAL_CFG
    ops: list[dict] = []
    in_ch = _make_divisible(32 * width_mult)

    w, b = _fused_conv_weight(rng, in_ch, 3, 3)
    ops.append(dict(kind="conv", name="stem", out_channels=in_ch, kernel=(3, 3),
                    stride=(2, 2), padding=(1, 1), weight=w, bias=b,
                    activation="relu6"))
    block = 0
    for (t, c, n, s) in cfg:
        cout = _make_divisible(c * width_mult)
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = in_ch * t
            use_res = stride == 1 and in_ch == cout
            tag = f"b{block}"
            if t != 1:
                w, b = _fused_conv_weight(rng, hidden, in_ch, 1)
                ops.append(dict(kind="conv", name=f"{tag}_expand",
                                out_channels=hidden, kernel=(1, 1),
                                stride=(1, 1), padding=(0, 0), weight=w, bias=b,
                                activation="relu6",
                                # residual source saved at block input: stash on
                                # the *previous* op; handled below via save_as.
                                ))
            wdw = rng.standard_normal((hidden, 1, 3, 3)).astype(np.float32) * np.sqrt(2.0 / 9)
            bn = BatchNormParams(
                gamma=rng.uniform(0.5, 1.5, hidden).astype(np.float32),
                beta=rng.uniform(-0.1, 0.1, hidden).astype(np.float32),
                mean=rng.uniform(-0.1, 0.1, hidden).astype(np.float32),
                var=rng.uniform(0.5, 1.5, hidden).astype(np.float32))
            wdw, bdw = fold_batchnorm(wdw, None, bn)
            ops.append(dict(kind="dwconv", name=f"{tag}_dw", kernel=(3, 3),
                            stride=(stride, stride), padding=(1, 1),
                            weight=wdw, bias=bdw, activation="relu6"))
            w, b = _fused_conv_weight(rng, cout, hidden, 1)
            ops.append(dict(kind="conv", name=f"{tag}_project",
                            out_channels=cout, kernel=(1, 1), stride=(1, 1),
                            padding=(0, 0), weight=w, bias=b,
                            activation=None,
                            residual_from=f"{tag}_in" if use_res else None))
            if use_res:
                # the block input is produced by the op *preceding* this
                # block's first conv: 4 back with an expand conv, else 3.
                ops[-4 if t != 1 else -3]["save_as"] = f"{tag}_in"
            in_ch = cout
            block += 1

    last_ch = _make_divisible(1280 * max(1.0, width_mult))
    w, b = _fused_conv_weight(rng, last_ch, in_ch, 1)
    ops.append(dict(kind="conv", name="head_conv", out_channels=last_ch,
                    kernel=(1, 1), stride=(1, 1), padding=(0, 0), weight=w,
                    bias=b, activation="relu6"))
    ops.append(dict(kind="avgpool", name="gap"))
    wl = rng.standard_normal((last_ch, num_classes)).astype(np.float32) * np.sqrt(1.0 / last_ch)
    ops.append(dict(kind="linear", name="classifier", features=num_classes,
                    weight=wl, bias=np.zeros(num_classes, np.float32)))
    return trace_sequential(ops, (3, *input_hw), rng=rng)


def mobilenet_v2_smoke(seed: int = 0) -> ReinterpretedModel:
    """Reduced config (same family) for CPU smoke tests."""
    cfg = [(1, 8, 1, 1), (6, 16, 2, 2), (6, 24, 2, 2)]
    return mobilenet_v2(input_hw=(32, 32), width_mult=0.25, num_classes=10,
                        seed=seed, cfg=cfg)


def mobilenet_v2_paper(seed: int = 0) -> ReinterpretedModel:
    """The paper's evaluation configuration: full MobileNetV2 at 112x112x3
    (§VI) — the model the executor benchmark and serving examples target."""
    return mobilenet_v2(input_hw=(112, 112), seed=seed)
