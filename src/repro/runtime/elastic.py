"""Elastic runtime: failure handling, straggler mitigation, and re-allocation
— the paper's Eq. 7 overflow-redistribution reused as the recovery policy.

Two deployment worlds share this module:

* **MCU cluster** (the paper's): :class:`ElasticCluster` tracks per-worker
  health from heartbeats/observed step times, demotes stragglers by scaling
  their capability rating (the same quantity Eq. 5 defines, floored at a
  fraction of the original rating so repeated demotions cannot collapse a
  worker to zero), drops dead workers, and re-plans over the survivors with
  the full :class:`~repro.api.Planner` search — every axis the planner
  knows (mode x fusion x subset x transport), not just neuron splitting,
  with Eq. 7's overflow redistribution and the RAM/flash caps enforced
  inside the search.

  Worker *identity* is preserved across replans: the produced
  :class:`~repro.api.Plan` indexes an alive-only subset cluster, and
  :attr:`ElasticCluster.plan_worker_ids` maps each plan worker slot back to
  the original worker id — so a coordinator can tell which physical worker
  inherits which shard, and ship only the delta
  (:meth:`~repro.runtime.Coordinator.replan_to`).

* **TPU pod**: checkpoints restore onto a smaller mesh (ckpt/checkpoint.py
  restores with new shardings); `plan_recovery_mesh` picks the largest
  (data, model) mesh that still divides the surviving chip count, and the
  caller rebuilds the train step against it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.allocation import WorkerParams


class ClusterCollapsed(RuntimeError):
    """Every worker is dead — no surviving workers to re-plan over."""


@dataclasses.dataclass
class WorkerHealth:
    params: WorkerParams
    alive: bool = True
    last_heartbeat: float = 0.0
    ema_step_time: float | None = None   # straggler signal


class ElasticCluster:
    """Rating-based elastic membership + re-planning for the MCU world.

    Holds the *policy* only (who is alive, how capable) — the transition
    mechanics (delta shipping, warm recompiles, atomic cutover) live in
    :class:`~repro.runtime.replan.ElasticCoordinator`.

    ``plan`` is a full :class:`repro.api.Plan` over the alive subset;
    ``plan_worker_ids[i]`` is the original worker id serving plan slot
    ``i`` (the planner may choose a strict subset of the living workers).
    """

    def __init__(self, model, workers: list[WorkerParams], *,
                 objective=None, sim_cfg=None,
                 heartbeat_timeout: float = 5.0,
                 straggler_factor: float = 1.5,
                 demotion_floor: float = 0.25,
                 clock=time.monotonic):
        if not 0.0 < demotion_floor <= 1.0:
            raise ValueError(f"demotion_floor must be in (0, 1], "
                             f"got {demotion_floor}")
        self.model = model
        self.objective = objective
        self.sim_cfg = sim_cfg
        self.timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.demotion_floor = demotion_floor
        # injectable clock: timeout policy is testable without sleeping
        self._clock = clock
        self.health = [WorkerHealth(p, last_heartbeat=self._clock())
                       for p in workers]
        self._original = tuple(workers)      # pre-demotion ratings basis
        self._planned_alive: tuple[int, ...] = tuple(range(len(workers)))
        self.plan_worker_ids: tuple[int, ...] = ()
        # one CostCache for the cluster's lifetime: replan keys fingerprint
        # worker *parameters*, so a churn event that drops one worker re-plans
        # over survivor subsets the initial search already costed — the warm
        # path the churn drill asserts on (hit rate > 0, lower search wall)
        from ..core.search import CostCache
        self.search_cache = CostCache()
        self.last_search_stats: dict | None = None
        self.plan = self._replan()

    # -- signals ------------------------------------------------------------
    def heartbeat(self, worker: int, now: float | None = None):
        # `if now is None`, not `now or ...`: t=0.0 is a valid fake-clock
        # timestamp and must not silently fall through to the real clock
        self.health[worker].last_heartbeat = (
            self._clock() if now is None else now)

    def report_step_time(self, worker: int, seconds: float, alpha=0.5):
        h = self.health[worker]
        h.ema_step_time = (seconds if h.ema_step_time is None
                           else alpha * seconds + (1 - alpha) * h.ema_step_time)

    def mark_failed(self, worker: int):
        self.health[worker].alive = False

    def rejoin(self, worker: int, params: WorkerParams | None = None,
               now: float | None = None):
        """A previously dead/demoted worker comes back (fresh process): it
        re-enters at its original (or newly measured) capability with a
        clean straggler history.  Call :meth:`check` to fold it into the
        plan."""
        h = self.health[worker]
        h.alive = True
        h.params = params if params is not None else self._original[worker]
        h.last_heartbeat = self._clock() if now is None else now
        h.ema_step_time = None

    # -- policy ---------------------------------------------------------------
    def check(self, now: float | None = None) -> bool:
        """Apply failure + straggler policy; returns True if the plan changed."""
        now = self._clock() if now is None else now
        changed = tuple(self.alive_indices) != self._planned_alive
        for h in self.health:
            if h.alive and now - h.last_heartbeat > self.timeout:
                h.alive = False
                changed = True
        times = [h.ema_step_time for h in self.health
                 if h.alive and h.ema_step_time]
        if times:
            med = float(np.median(times))
            for i, h in enumerate(self.health):
                if h.alive and h.ema_step_time and \
                        h.ema_step_time > self.straggler_factor * med:
                    # straggler: demote its effective clock so the rating —
                    # and therefore its Alg. 1/2 share — shrinks.  Floored
                    # at demotion_floor x the original clock so repeated
                    # demotions cannot compound a worker to zero.
                    floor = self.demotion_floor * self._original[i].f_mhz
                    h.params = dataclasses.replace(
                        h.params,
                        f_mhz=max(floor,
                                  h.params.f_mhz * med / h.ema_step_time))
                    h.ema_step_time = None
                    changed = True
        if changed:
            self.plan = self._replan()
        return changed

    def _replan(self):
        from ..api.cluster import Cluster
        from ..api.planner import Planner
        self._planned_alive = tuple(self.alive_indices)
        alive_ids = list(self._planned_alive)
        if not alive_ids:
            raise ClusterCollapsed("no surviving workers")
        sub = Cluster(tuple(self.health[i].params for i in alive_ids),
                      name=f"alive[{len(alive_ids)}]")
        planner = Planner(self.model, sub, self.sim_cfg,
                          cache=self.search_cache)
        plan = planner.plan(self.objective)
        self.last_search_stats = plan.search_stats
        # plan.worker_indices index the alive-only subset; map back to the
        # original ids so worker identity survives the replan
        self.plan_worker_ids = tuple(alive_ids[i]
                                     for i in plan.worker_indices)
        return plan

    @property
    def alive_indices(self) -> list[int]:
        return [i for i, h in enumerate(self.health) if h.alive]


def plan_recovery_mesh(n_surviving: int, model_axis: int = 16) -> tuple[int, int]:
    """Largest (data, model) mesh on the surviving chips, keeping the model
    axis intact (TP degree is baked into layer shardings); data shrinks."""
    if n_surviving < model_axis:
        raise ValueError(f"need >= {model_axis} chips, have {n_surviving}")
    return (n_surviving // model_axis, model_axis)
