"""Elastic runtime: failure handling, straggler mitigation, and re-allocation
— the paper's Eq. 7 overflow-redistribution reused as the recovery policy.

Two deployment worlds share this module:

* **MCU cluster** (the paper's): :class:`ElasticCluster` tracks per-worker
  health from heartbeats/observed step times, demotes stragglers by scaling
  their capability rating (the same quantity Eq. 5 defines), drops dead
  workers, and re-splits the model with the remaining ratings —
  `redistribute_overflow` guarantees the new plan still fits each worker's
  storage.
* **TPU pod**: checkpoints restore onto a smaller mesh (ckpt/checkpoint.py
  restores with new shardings); `plan_recovery_mesh` picks the largest
  (data, model) mesh that still divides the surviving chip count, and the
  caller rebuilds the train step against it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.allocation import WorkerParams, ratings_for, redistribute_overflow
from ..core.splitting import SplitPlan, split_model


@dataclasses.dataclass
class WorkerHealth:
    params: WorkerParams
    alive: bool = True
    last_heartbeat: float = 0.0
    ema_step_time: float | None = None   # straggler signal


class ElasticCluster:
    """Rating-based elastic coordinator for the networked-MCU world."""

    def __init__(self, model, workers: list[WorkerParams], k1: float,
                 kc: float, heartbeat_timeout: float = 5.0,
                 straggler_factor: float = 1.5,
                 clock=time.monotonic):
        self.model = model
        self.k1, self.kc = k1, kc
        self.timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        # injectable clock: timeout policy is testable without sleeping
        self._clock = clock
        self.health = [WorkerHealth(p, last_heartbeat=self._clock())
                       for p in workers]
        self._planned_alive: tuple[int, ...] = tuple(range(len(workers)))
        self.plan: SplitPlan = self._replan()

    # -- signals ------------------------------------------------------------
    def heartbeat(self, worker: int, now: float | None = None):
        # `if now is None`, not `now or ...`: t=0.0 is a valid fake-clock
        # timestamp and must not silently fall through to the real clock
        self.health[worker].last_heartbeat = (
            self._clock() if now is None else now)

    def report_step_time(self, worker: int, seconds: float, alpha=0.5):
        h = self.health[worker]
        h.ema_step_time = (seconds if h.ema_step_time is None
                           else alpha * seconds + (1 - alpha) * h.ema_step_time)

    def mark_failed(self, worker: int):
        self.health[worker].alive = False

    # -- policy ---------------------------------------------------------------
    def check(self, now: float | None = None) -> bool:
        """Apply failure + straggler policy; returns True if the plan changed."""
        now = self._clock() if now is None else now
        changed = tuple(self.alive_indices) != self._planned_alive
        for h in self.health:
            if h.alive and now - h.last_heartbeat > self.timeout:
                h.alive = False
                changed = True
        times = [h.ema_step_time for h in self.health
                 if h.alive and h.ema_step_time]
        if times:
            med = float(np.median(times))
            for h in self.health:
                if h.alive and h.ema_step_time and \
                        h.ema_step_time > self.straggler_factor * med:
                    # straggler: demote its effective clock so the rating —
                    # and therefore its Alg. 1/2 share — shrinks.
                    h.params = dataclasses.replace(
                        h.params, f_mhz=h.params.f_mhz * med / h.ema_step_time)
                    h.ema_step_time = None
                    changed = True
        if changed:
            self.plan = self._replan()
        return changed

    def _replan(self) -> SplitPlan:
        self._planned_alive = tuple(self.alive_indices)
        alive = [h.params for h in self.health if h.alive]
        if not alive:
            raise RuntimeError("no surviving workers")
        r = ratings_for(alive, self.k1, self.kc)
        caps = np.array([p.flash_bytes for p in alive], dtype=np.float64)
        r = redistribute_overflow(r, caps, self.model.total_weight_bytes(1))
        return split_model(self.model, r)

    @property
    def alive_indices(self) -> list[int]:
        return [i for i, h in enumerate(self.health) if h.alive]


def plan_recovery_mesh(n_surviving: int, model_axis: int = 16) -> tuple[int, int]:
    """Largest (data, model) mesh on the surviving chips, keeping the model
    axis intact (TP degree is baked into layer shardings); data shrinks."""
    if n_surviving < model_axis:
        raise ValueError(f"need >= {model_axis} chips, have {n_surviving}")
    return (n_surviving // model_axis, model_axis)
