"""Plan diffing + the elastic serving loop: replan under churn, ship deltas.

Two layers:

* :func:`diff_plans` / :class:`PlanDiff` — pure, analytic comparison of two
  :class:`~repro.core.splitting.SplitPlan` setups at shard granularity.
  Every worker-setup segment spec carries a content ``fingerprint``
  (geometry + array contents, independent of group index — see
  ``shards._fingerprint_spec``), so classification is exact:

  - ``unchanged``: the mapped physical worker already holds this exact
    segment (same geometry, same weights) — zero bytes shipped, warm
    compiled cache hit;
  - ``moved``: the segment exists verbatim on some *other* old worker —
    re-shipped, but recognizable (a future peer-transfer optimization);
  - ``resized``: the worker served this group before with different
    geometry — only arrays it doesn't hold are re-shipped;
  - ``new``: the group/worker pair did not exist in the old plan.

  ``reshipped_bytes`` is computed per *worker* over the union of its
  segments (an array shared by two segments ships once), matching exactly
  what :meth:`~repro.runtime.Coordinator.replan_to` puts on the wire.

* :class:`ElasticCoordinator` — the serve-through-churn loop, composing an
  :class:`~repro.runtime.elastic.ElasticCluster` (membership + Planner
  policy) with a live :class:`~repro.runtime.Coordinator` (transition
  mechanics).  ``infer`` retries through worker failure: a dead worker
  fails the in-flight request typed, the cluster re-plans over survivors,
  ``replan_to`` cuts over atomically under the coordinator's request lock
  (queued submissions simply run under the new plan), and the request is
  re-run — bit-exact, never silently dropped.  Past ``queue_cap``
  concurrent requests, submissions shed with typed
  ``Overloaded(reason="rebalancing")``.
"""
from __future__ import annotations

import dataclasses

from ..core.quantize import QuantizedModel
from ..core.splitting import SplitPlan
from .coordinator import Coordinator
from .elastic import ElasticCluster
from .shards import build_worker_setup, delta_setup, setup_array_bytes

__all__ = ["SegmentDiff", "PlanDiff", "diff_plans", "ElasticCoordinator"]


@dataclasses.dataclass(frozen=True)
class SegmentDiff:
    """One (new-plan worker, group) shard, classified against the old plan."""

    worker: int            # new plan worker slot
    gi: int                # block group index
    status: str            # "unchanged" | "moved" | "resized" | "new"
    nbytes: int            # total array bytes of this segment, new plan
    reship_bytes: int      # array bytes the mapped worker must receive


@dataclasses.dataclass(frozen=True)
class PlanDiff:
    """Shard-granular diff between two split plans."""

    entries: tuple[SegmentDiff, ...]
    removed: int                    # old segments with no successor
    full_setup_bytes: int           # shipping the new plan cold
    reshipped_bytes: int            # shipping only what mapped workers lack

    def count(self, status: str) -> int:
        return sum(1 for e in self.entries if e.status == status)

    @property
    def unchanged(self) -> int:
        return self.count("unchanged")

    @property
    def moved(self) -> int:
        return self.count("moved")

    @property
    def resized(self) -> int:
        return self.count("resized")

    @property
    def new(self) -> int:
        return self.count("new")

    def summary(self) -> str:
        return (f"PlanDiff: {self.unchanged} unchanged, {self.moved} moved, "
                f"{self.resized} resized, {self.new} new, "
                f"{self.removed} removed; reship "
                f"{self.reshipped_bytes}/{self.full_setup_bytes} B "
                f"({self.reshipped_bytes / max(self.full_setup_bytes, 1):.0%})")


def _worker_setups(split: SplitPlan, qmodel, precision: str) -> dict:
    out = {}
    for w in range(split.n_workers):
        meta, arrays = build_worker_setup(split, qmodel, precision, w)
        out[w] = (meta, arrays)
    return out


def diff_plans(old_split: SplitPlan, new_split: SplitPlan,
               qmodel: QuantizedModel | None = None,
               precision: str = "int8",
               worker_map: dict[int, int] | None = None) -> PlanDiff:
    """Classify every shard of ``new_split`` against ``old_split``.

    ``worker_map`` maps new worker slots to the old slots whose warm state
    they inherit (identity by default — slot ``w`` keeps slot ``w``'s
    stores).  Unmapped slots are fresh workers: everything they need ships.
    """
    old = _worker_setups(old_split, qmodel, precision)
    new = _worker_setups(new_split, qmodel, precision)
    if worker_map is None:
        worker_map = {w: w for w in new if w in old}

    old_seg_fps: dict[int, dict[str, int]] = {}   # worker -> {seg fp: gi}
    old_arr_fps: dict[int, set[str]] = {}
    all_old_segs: set[str] = set()
    for w, (meta, arrays) in old.items():
        segs, fps = {}, set()
        for spec in meta["segments"]:
            if "fingerprint" in spec:
                segs[spec["fingerprint"]] = spec["gi"]
                all_old_segs.add(spec["fingerprint"])
            fps.update(spec.get("array_fps", {}).values())
        old_seg_fps[w] = segs
        old_arr_fps[w] = fps

    entries: list[SegmentDiff] = []
    matched_old: set[tuple[int, str]] = set()
    full_bytes = 0
    reship_bytes = 0
    for w, (meta, arrays) in new.items():
        full_bytes += setup_array_bytes(arrays)
        old_w = worker_map.get(w)
        held = old_arr_fps.get(old_w, set()) if old_w is not None else set()
        reship_bytes += setup_array_bytes(delta_setup(meta, arrays, held))
        old_segs = old_seg_fps.get(old_w, {}) if old_w is not None else {}
        old_gis = set(old_segs.values())
        for spec in meta["segments"]:
            if spec["kind"] == "skip":
                continue
            fp, gi = spec["fingerprint"], spec["gi"]
            seg_keys = spec.get("array_fps", {})
            nbytes = sum(arrays[k].nbytes for k in seg_keys)
            seg_reship = sum(arrays[k].nbytes
                             for k, afp in seg_keys.items()
                             if afp not in held)
            if fp in old_segs:
                status = "unchanged"
                matched_old.add((old_w, fp))
            elif fp in all_old_segs:
                status = "moved"
            elif gi in old_gis:
                status = "resized"
                matched_old.add((old_w, fp))   # successor exists at this gi
            else:
                status = "new"
            entries.append(SegmentDiff(worker=w, gi=gi, status=status,
                                       nbytes=int(nbytes),
                                       reship_bytes=int(seg_reship)))
    inherited_old = set(worker_map.values())
    new_gis_by_old: dict[int, set[int]] = {}
    for e in entries:
        old_w = worker_map.get(e.worker)
        if old_w is not None:
            new_gis_by_old.setdefault(old_w, set()).add(e.gi)
    removed = 0
    for w, segs in old_seg_fps.items():
        if w not in inherited_old:
            removed += len(segs)
            continue
        removed += sum(1 for fp, gi in segs.items()
                       if (w, fp) not in matched_old
                       and gi not in new_gis_by_old.get(w, set()))
    return PlanDiff(entries=tuple(entries), removed=removed,
                    full_setup_bytes=int(full_bytes),
                    reshipped_bytes=int(reship_bytes))


class ElasticCoordinator:
    """Serve through churn: an ElasticCluster's policy driving a live
    Coordinator's mechanics.

    Async context manager::

        cluster = ElasticCluster(model, workers)
        async with ElasticCoordinator(cluster, qmodel) as ec:
            y = await ec.infer(x)          # survives worker death
            ec.cluster.mark_failed(2)      # or heartbeat staleness
            await ec.rebalance()           # explicit, or lazily on failure

    ``infer`` never silently drops a request: a worker failure triggers
    mark-failed + replan + retry (up to ``max_replans`` transitions per
    request); past ``queue_cap`` concurrent requests it sheds with typed
    ``Overloaded(reason="rebalancing")``.
    """

    def __init__(self, cluster: ElasticCluster,
                 qmodel: QuantizedModel | None = None, *,
                 precision: str = "int8", spawn: str = "process",
                 max_replans: int = 3, queue_cap: int = 16,
                 **coord_kwargs):
        self.cluster = cluster
        self.qmodel = qmodel
        self.precision = precision
        self.spawn = spawn
        self.max_replans = max_replans
        self.queue_cap = queue_cap
        self._coord_kwargs = coord_kwargs
        self.coord = Coordinator(cluster.plan.split, qmodel,
                                 precision=precision, spawn=spawn,
                                 **coord_kwargs)
        # split slot -> original (physical) worker id
        self._physical: dict[int, int] = dict(
            enumerate(cluster.plan_worker_ids))
        self.reports: list[dict] = []
        self._depth = 0

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> None:
        await self.coord.start()

    async def close(self) -> None:
        await self.coord.close()

    async def __aenter__(self) -> "ElasticCoordinator":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- views -------------------------------------------------------------
    @property
    def split(self) -> SplitPlan:
        return self.coord.split

    @property
    def plan(self):
        return self.cluster.plan

    @property
    def physical_ids(self) -> dict[int, int]:
        """Live mapping: coordinator worker slot -> physical worker id."""
        return dict(self._physical)

    # -- churn signals -------------------------------------------------------
    def report_step_time(self, slot: int, seconds: float) -> None:
        pid = self._physical.get(slot)
        if pid is not None:
            self.cluster.report_step_time(pid, seconds)

    async def inject_failure(self, slot: int) -> None:
        """Kill the worker serving plan slot ``slot`` (fault injection)."""
        h = self.coord.handles[slot]
        if h.proc is not None:
            h.proc.kill()
        elif h.writer is not None:
            h.writer.close()

    async def rejoin(self, physical_id: int, params=None) -> dict:
        """A physical worker comes back; fold it into the plan."""
        self.cluster.rejoin(physical_id, params)
        return await self.rebalance()

    # -- the transition ------------------------------------------------------
    def _mark_failed_handles(self) -> list[int]:
        """Propagate coordinator-observed worker deaths into the cluster."""
        failed = []
        for slot, h in self.coord.handles.items():
            pid = self._physical.get(slot)
            if pid is None:
                continue
            if h.failed is not None:
                self.cluster.mark_failed(pid)
                failed.append(pid)
            else:
                self.cluster.heartbeat(pid)
        return failed

    async def rebalance(self) -> dict:
        """Re-plan over the cluster's current health and cut the live
        coordinator over, shipping only deltas.  Returns the transition
        report (downtime, reshipped vs full bytes, warm-cache hit rate)."""
        self._mark_failed_handles()
        self.cluster.check()
        new_ids = self.cluster.plan_worker_ids
        by_pid = {pid: slot for slot, pid in self._physical.items()}
        worker_map: dict[int, int] = {}
        for slot, pid in enumerate(new_ids):
            old_slot = by_pid.get(pid)
            if old_slot is None:
                continue
            h = self.coord.handles.get(old_slot)
            if h is not None and h.failed is None:
                worker_map[slot] = old_slot
        report = await self.coord.replan_to(self.cluster.plan.split,
                                            worker_map=worker_map)
        self._physical = dict(enumerate(new_ids))
        report["plan_worker_ids"] = list(new_ids)
        # decompose churn downtime: how much of it was plan *search* (and
        # how warm the cluster's persistent CostCache made that search)
        search = self.cluster.last_search_stats or {}
        report["replan_search_wall_s"] = search.get("search_wall_s", 0.0)
        report["replan_candidates_evaluated"] = search.get(
            "candidates_evaluated", 0)
        report["replan_cache_hits"] = search.get("cache_hits", 0)
        report["replan_cache_hit_rate"] = search.get("cache_hit_rate", 0.0)
        self.reports.append(report)
        return report

    # -- serving -------------------------------------------------------------
    async def infer(self, x) -> "object":
        """One request, served through any number of topology transitions
        (up to ``max_replans``) — bit-exact vs a single-process Session on
        the surviving topology, or a typed error; never a silent drop."""
        if self._depth >= self.queue_cap:
            from ..serve.admission import Overloaded
            raise Overloaded("elastic", "rebalancing",
                             queue_depth=self._depth)
        self._depth += 1
        try:
            for attempt in range(self.max_replans + 1):
                try:
                    return await self.coord.infer(x)
                except RuntimeError as e:
                    from ..serve.admission import Overloaded
                    if isinstance(e, Overloaded):
                        raise
                    dead = [slot for slot, h in self.coord.handles.items()
                            if h.failed is not None]
                    if not dead or attempt == self.max_replans:
                        raise
                    await self.rebalance()
        finally:
            self._depth -= 1

    async def infer_many(self, xs) -> list:
        return [await self.infer(x) for x in xs]
