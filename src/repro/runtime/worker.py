"""Worker process for the distributed runtime.

One worker owns the shard fragments a :class:`~repro.core.splitting.SplitPlan`
assigns it, connects to the coordinator over TCP, and serves per-segment
compute requests.  Lifecycle::

    connect -> hello{worker} -> (setup frame: specs + weight fragments)
            -> compile + warm every segment fn -> ready{setup_s}
            -> serve: infer_input{seq,gi}+x  ->  result{seq,gi}+y
                      ping -> pong · collect{seq} -> events · shutdown -> exit
            -> (setup frame mid-serve: delta re-setup for a replan)
            -> ready{...} again, with warm-cache stats

Concurrency shape (all on one event loop):

* the **reader** loop pulls frames off the socket and dispatches; it never
  blocks on compute, so the next segment's input downloads while the current
  one computes — the worker-side half of the pipelined overlap.
* **compute** runs in a single-thread executor (XLA releases the GIL), so
  computes serialize in arrival order while the loop stays responsive.
* the **writer** task drains a FIFO queue — result uploads keep link order,
  and upload timing is measured around the actual ``write + drain``.
* a **heartbeat** task pings the coordinator every ``heartbeat_s`` so
  liveness is observable independently of request traffic.

Elastic re-setup: the worker keeps two warm stores across setups — an
**array store** (content fingerprint -> ndarray) so a replan only ships
arrays the worker does not already hold (the setup frame's specs name the
fingerprints; missing entries are resolved from the store), and a
**compiled-segment cache** (segment fingerprint -> jitted fn) so unchanged
shard geometry never re-traces.  A mid-serve ``setup`` frame rebuilds the
segment table in the compute pool (heartbeats keep flowing) and answers
with a fresh ``ready`` frame carrying ``cache_hits``/``cache_misses`` /
``received_bytes`` so the coordinator can assert warm-recompile and
delta-shipping invariants.

Event bookkeeping: download windows come from ``read_frame``'s receive
timestamps, compute windows bracket the jitted call (``block_until_ready``
via ``np.asarray``), upload windows bracket the socket write.  ``collect``
is answered from the *writer* queue (a marker sentinel), so the snapshot is
taken only after every previously queued result frame — and its upload
event — has flushed.  All timestamps are raw ``time.monotonic()``; the
coordinator normalizes to request start when assembling the Timeline.

Workers are stateless across requests (every ``infer_input`` carries its
full input slice), so a coordinator retry is an idempotent recompute.
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from .protocol import ConnectionClosed, read_frame, write_frame
from .shards import _array_fp, build_segment_fns, warmup_segments

_SHUTDOWN = object()


class _WorkerLoop:
    def __init__(self, worker_id: int, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, heartbeat_s: float):
        self.worker_id = worker_id
        self.reader = reader
        self.writer = writer
        self.heartbeat_s = heartbeat_s
        self.segments: dict = {}
        self.events: list[dict] = []
        self.out_q: asyncio.Queue = asyncio.Queue()
        self.pool = ThreadPoolExecutor(max_workers=1)
        self.tasks: set[asyncio.Task] = set()
        # warm stores, persistent across setups (elastic replans)
        self.array_store: dict[str, np.ndarray] = {}
        self.seg_cache: collections.OrderedDict = collections.OrderedDict()

    def _event(self, kind: str, gi: int, layer: int, t0: float, t1: float,
               nbytes: int = 0) -> None:
        self.events.append({"worker": self.worker_id, "kind": kind,
                            "segment": gi, "layer": layer,
                            "start_s": t0, "end_s": t1, "nbytes": nbytes})

    # -- setup -------------------------------------------------------------
    def _resolve_arrays(self, meta: dict,
                        shipped: dict[str, np.ndarray]) -> dict:
        """Merge shipped arrays with the warm store.

        Arrays present in the frame are stored under their content
        fingerprint; keys the frame omitted must resolve from the store via
        the spec's ``array_fps`` — a miss is a coordinator protocol error.
        """
        fps: dict[str, str] = {}
        for spec in meta["segments"]:
            fps.update(spec.get("array_fps", {}))
        arrays: dict[str, np.ndarray] = {}
        for key, fp in fps.items():
            if key in shipped:
                arrays[key] = shipped[key]
                self.array_store[fp] = shipped[key]
            elif fp in self.array_store:
                arrays[key] = self.array_store[fp]
            else:
                raise RuntimeError(
                    f"worker {self.worker_id}: setup omitted array {key!r} "
                    f"(fp {fp}) but it is not in the local store")
        # legacy payloads without fingerprints ship everything
        for key, a in shipped.items():
            arrays.setdefault(key, a)
            self.array_store.setdefault(_array_fp(a), a)
        return arrays

    def _apply_setup(self, meta: dict, shipped: dict[str, np.ndarray],
                     received_bytes: int) -> dict:
        """Build + warm the segment table; returns the ready-frame meta."""
        self.worker_id = int(meta.get("worker", self.worker_id))
        arrays = self._resolve_arrays(meta, shipped)
        stats: dict = {}
        self.segments = build_segment_fns(meta, arrays,
                                          cache=self.seg_cache, stats=stats)
        setup_s = warmup_segments(self.segments, meta["precision"])
        return {"worker": self.worker_id, "setup_s": setup_s,
                "segments": sorted(self.segments),
                "cache_hits": stats.get("cache_hits", 0),
                "cache_misses": stats.get("cache_misses", 0),
                "received_bytes": int(received_bytes)}

    # -- writer ------------------------------------------------------------
    async def _writer_loop(self) -> None:
        while True:
            item = await self.out_q.get()
            if item is _SHUTDOWN:
                return
            if item[0] == "collect":
                # marker: every result queued before it has flushed, so the
                # snapshot includes their upload events
                snapshot, self.events = self.events, []
                await write_frame(self.writer, "events",
                                  {"worker": self.worker_id, "seq": item[1],
                                   "events": snapshot})
                continue
            _, ftype, meta, arrays, record = item
            t0 = time.monotonic()
            n = await write_frame(self.writer, ftype, meta, arrays)
            t1 = time.monotonic()
            if record is not None:
                gi, layer = record
                self._event("upload", gi, layer, t0, t1, n)

    # -- heartbeat ---------------------------------------------------------
    async def _heartbeat_loop(self) -> None:
        while True:
            await asyncio.sleep(self.heartbeat_s)
            self.out_q.put_nowait(("frame", "heartbeat",
                                   {"worker": self.worker_id,
                                    "t": time.monotonic()}, None, None))

    # -- compute -----------------------------------------------------------
    async def _compute_and_send(self, seq: int, gi: int,
                                x: np.ndarray) -> None:
        seg = self.segments[gi]
        loop = asyncio.get_running_loop()

        def run():
            t0 = time.monotonic()
            y = np.asarray(seg.fn(x))       # np.asarray blocks until ready
            return t0, time.monotonic(), y

        t0, t1, y = await loop.run_in_executor(self.pool, run)
        self._event("compute", gi, seg.layer_first, t0, t1)
        self.out_q.put_nowait(("frame", "result",
                               {"seq": seq, "gi": gi,
                                "worker": self.worker_id},
                               {"y": y}, (gi, seg.layer_first)))

    async def _resetup_and_ack(self, frame) -> None:
        """Mid-serve re-setup: rebuild segments off-loop, then ack ready."""
        loop = asyncio.get_running_loop()
        ready_meta = await loop.run_in_executor(
            self.pool, self._apply_setup, frame.meta["plan"], frame.arrays,
            frame.nbytes)
        self.out_q.put_nowait(("frame", "ready", ready_meta, None, None))

    # -- main --------------------------------------------------------------
    async def run(self) -> None:
        await write_frame(self.writer, "hello", {"worker": self.worker_id})
        setup = await read_frame(self.reader)
        if setup.type != "setup":
            raise RuntimeError(f"worker {self.worker_id}: expected setup "
                               f"frame, got {setup.type!r}")
        ready_meta = self._apply_setup(setup.meta["plan"], setup.arrays,
                                       setup.nbytes)
        for coro in (self._writer_loop(), self._heartbeat_loop()):
            t = asyncio.create_task(coro)
            self.tasks.add(t)
            t.add_done_callback(self.tasks.discard)
        self.out_q.put_nowait(("frame", "ready", ready_meta, None, None))
        try:
            while True:
                frame = await read_frame(self.reader)
                if frame.type == "infer_input":
                    seq, gi = frame.meta["seq"], frame.meta["gi"]
                    self._event("download", gi,
                                self.segments[gi].layer_first,
                                frame.recv_start, frame.recv_end,
                                frame.nbytes)
                    t = asyncio.create_task(self._compute_and_send(
                        seq, gi, frame.arrays["x"]))
                    self.tasks.add(t)
                    t.add_done_callback(self.tasks.discard)
                elif frame.type == "setup":
                    # elastic replan: adopt the new plan without dropping
                    # the connection; build runs in the compute pool so
                    # heartbeats keep flowing during compilation
                    t = asyncio.create_task(self._resetup_and_ack(frame))
                    self.tasks.add(t)
                    t.add_done_callback(self.tasks.discard)
                elif frame.type == "collect":
                    # wait for in-flight computes so their results (and
                    # upload events) precede the snapshot marker
                    pending = [t for t in self.tasks
                               if not t.done()
                               and t.get_coro().__name__
                               == "_compute_and_send"]
                    if pending:
                        await asyncio.gather(*pending)
                    self.out_q.put_nowait(("collect",
                                           frame.meta.get("seq", 0)))
                elif frame.type == "ping":
                    self.out_q.put_nowait(("frame", "pong",
                                           {"worker": self.worker_id},
                                           None, None))
                elif frame.type == "shutdown":
                    return
                else:
                    raise RuntimeError(
                        f"worker {self.worker_id}: unexpected frame "
                        f"{frame.type!r}")
        except ConnectionClosed:
            return                          # coordinator went away cleanly
        finally:
            for t in self.tasks:
                t.cancel()
            self.pool.shutdown(wait=False)
            self.writer.close()


async def run_worker(host: str, port: int, worker_id: int,
                     heartbeat_s: float = 0.5) -> None:
    """Connect to the coordinator and serve until shutdown/EOF."""
    reader, writer = await asyncio.open_connection(host, port)
    await _WorkerLoop(worker_id, reader, writer, heartbeat_s).run()


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description="distributed runtime worker")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--id", type=int, required=True)
    p.add_argument("--heartbeat-s", type=float, default=0.5)
    args = p.parse_args(argv)
    print(f"[worker {args.id}] connecting to {args.host}:{args.port}",
          file=sys.stderr, flush=True)
    asyncio.run(run_worker(args.host, args.port, args.id,
                           heartbeat_s=args.heartbeat_s))
    print(f"[worker {args.id}] exit", file=sys.stderr, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
