"""Measured-vs-predicted validation for the distributed runtime.

Turns the transport simulator from an unfalsifiable oracle into a
calibrated model: run the same plan through (a) the single-process
:class:`~repro.api.session.Session` (the bit-exactness reference), (b) the
pipelined simulator (the prediction), and (c) the real asyncio runtime
(the measurement), then compare on three axes:

* **bit-exact output** — hard invariant, machine-independent;
* **dependency structure** — the runtime's realized ``(segment, consumer,
  producer)`` edges must be a superset of
  :func:`~repro.core.simulator.dependency_edges`; also hard;
* **makespan calibration** — measured / predicted ratio, reported but never
  hard-gated (localhost sockets are not 11.5 kB/s serial links).
"""
from __future__ import annotations

import asyncio
import dataclasses

import numpy as np

from ..core.allocation import WorkerParams
from ..core.simulator import SimConfig, Timeline, dependency_edges, simulate
from ..core.splitting import SplitPlan
from .coordinator import Coordinator


@dataclasses.dataclass
class ValidationReport:
    """One runtime-vs-reference comparison at a fixed worker count."""

    n_workers: int
    n_requests: int
    bitexact: bool
    max_abs_diff: float
    predicted_edges: set[tuple[int, int, int]]
    measured_edges: set[tuple[int, int, int]]
    edges_superset: bool
    makespan_s: float               # measured, best over requests
    predicted_s: float              # simulator pipelined makespan
    calibration_ratio: float        # measured / predicted
    setup_s: float
    timeline: Timeline | None = None

    @property
    def missing_edges(self) -> set[tuple[int, int, int]]:
        return self.predicted_edges - self.measured_edges

    def row(self) -> dict:
        """JSON-friendly summary (benchmarks / CI artifacts)."""
        return {"n_workers": self.n_workers,
                "n_requests": self.n_requests,
                "bitexact": bool(self.bitexact),
                "max_abs_diff": float(self.max_abs_diff),
                "edges_superset": bool(self.edges_superset),
                "n_predicted_edges": len(self.predicted_edges),
                "n_measured_edges": len(self.measured_edges),
                "missing_edges": sorted(self.missing_edges),
                "makespan_s": float(self.makespan_s),
                "predicted_s": float(self.predicted_s),
                "calibration_ratio": float(self.calibration_ratio),
                "setup_s": float(self.setup_s)}


async def validate_distributed(split: SplitPlan, qmodel=None, *,
                               precision: str = "int8",
                               reference=None,
                               n_requests: int = 2, seed: int = 0,
                               spawn: str = "process",
                               workers: list[WorkerParams] | None = None,
                               log_dir: str | None = None,
                               request_timeout: float = 60.0,
                               ) -> ValidationReport:
    """Run ``n_requests`` random inputs through the distributed runtime and
    compare against the single-process Session and the pipelined simulator.

    ``reference`` may carry a prebuilt :class:`~repro.api.session.Session`
    (sharing its qmodel with the coordinator keeps the comparison honest —
    same calibration, same weights).
    """
    if reference is None:
        from ..api.session import Session
        reference = Session(split, precision=precision, qmodel=qmodel,
                            seed=seed)
    if qmodel is None:
        qmodel = reference.qmodel
    model = split.model
    rng = np.random.default_rng(seed)
    xs = [rng.standard_normal(model.layers[0].in_shape,
                              dtype=np.float32) for _ in range(n_requests)]
    want = reference.submit_many(xs)

    params = workers or [WorkerParams() for _ in range(split.n_workers)]
    sim = simulate(model, params, split.ratings,
                   SimConfig(transport="pipelined"), plan=split)
    predicted = dependency_edges(split)

    async with Coordinator(split, qmodel, precision=precision, spawn=spawn,
                           log_dir=log_dir,
                           request_timeout=request_timeout) as coord:
        got = []
        makespans = []
        for x in xs:
            got.append(await coord.infer(x))
            makespans.append(coord.last_timeline.makespan_s)
        measured = set(coord.measured_edges)
        timeline = coord.last_timeline
        setup_s = coord.setup_s

    diffs = [np.max(np.abs(np.asarray(a, np.float32)
                           - np.asarray(b, np.float32)))
             for a, b in zip(want, got)]
    max_abs_diff = float(max(diffs)) if diffs else 0.0
    bitexact = all(np.array_equal(a, b) for a, b in zip(want, got))
    makespan = float(min(makespans)) if makespans else 0.0
    predicted_s = float(sim.total_time)
    return ValidationReport(
        n_workers=split.n_workers, n_requests=n_requests,
        bitexact=bitexact, max_abs_diff=max_abs_diff,
        predicted_edges=predicted, measured_edges=measured,
        edges_superset=predicted <= measured,
        makespan_s=makespan, predicted_s=predicted_s,
        calibration_ratio=(makespan / predicted_s if predicted_s else 0.0),
        setup_s=setup_s, timeline=timeline)


def run_distributed(split: SplitPlan, qmodel=None, **kwargs,
                    ) -> ValidationReport:
    """Synchronous wrapper around :func:`validate_distributed`."""
    return asyncio.run(validate_distributed(split, qmodel, **kwargs))
