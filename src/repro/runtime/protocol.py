"""Length-prefixed frame protocol for coordinator <-> worker sockets.

Pure stdlib + numpy (no msgpack: the pinned-minimum CI cell installs only
jax/numpy/pytest, and activations are raw int8 buffers anyway — JSON headers
plus raw array payloads are both simpler and faster than re-encoding tensor
bytes).  One frame on the wire is

    u32 body_len (little-endian)
    body:
        u32 header_len
        header_len bytes of UTF-8 JSON:
            {"type": str, "meta": {...},
             "arrays": [[name, dtype_str, shape, nbytes], ...]}
        concatenated raw array buffers, in header order

Arrays round-trip by dtype string (``np.dtype.str``, e.g. ``"|i1"``,
``"<f4"``) and shape; payload bytes are the C-contiguous buffer.  Frames are
bounded by :data:`MAX_FRAME_BYTES` — a corrupt length prefix surfaces as a
:class:`ProtocolError` instead of an attempt to allocate garbage gigabytes.

EOF semantics: end-of-stream on a frame boundary raises
:class:`ConnectionClosed` (a clean shutdown the caller may expect); EOF
*inside* a frame raises :class:`ProtocolError` naming how far the frame got
— the truncated-frame signal the coordinator turns into a worker-death
error.

:func:`read_frame` timestamps the wire transfer (``recv_start`` after the
length prefix landed, ``recv_end`` once the body is in) with
``time.monotonic()`` — on Linux a system-wide clock, so worker-side receive
windows and coordinator-side events are directly comparable when both run
on one host (the localhost validation harness).
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import struct
import time

import numpy as np

MAX_FRAME_BYTES = 1 << 30          # 1 GiB: far above any shard payload
_LEN = struct.Struct("<I")


class ProtocolError(RuntimeError):
    """Malformed, truncated, or oversized frame."""


class ConnectionClosed(ProtocolError):
    """EOF on a clean frame boundary (peer went away between frames)."""


@dataclasses.dataclass
class Frame:
    """One decoded frame plus its measured receive window."""

    type: str
    meta: dict
    arrays: dict[str, np.ndarray]
    nbytes: int = 0                 # full frame size incl. length prefix
    recv_start: float = 0.0         # monotonic, after the length prefix landed
    recv_end: float = 0.0           # monotonic, after the full body landed


def encode_frame(type: str, meta: dict | None = None,
                 arrays: dict[str, np.ndarray] | None = None) -> bytes:
    """Serialize one frame to wire bytes (length prefix included)."""
    specs = []
    payloads = []
    for name, arr in (arrays or {}).items():
        arr = np.ascontiguousarray(arr)
        buf = arr.tobytes()
        specs.append([name, arr.dtype.str, list(arr.shape), len(buf)])
        payloads.append(buf)
    header = json.dumps({"type": type, "meta": meta or {},
                         "arrays": specs}).encode("utf-8")
    body_len = _LEN.size + len(header) + sum(len(p) for p in payloads)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame body of {body_len} bytes exceeds "
                            f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    parts = [_LEN.pack(body_len), _LEN.pack(len(header)), header, *payloads]
    return b"".join(parts)


def decode_body(body: bytes) -> tuple[str, dict, dict[str, np.ndarray]]:
    """Decode a frame body (everything after the outer length prefix)."""
    if len(body) < _LEN.size:
        raise ProtocolError(f"frame body of {len(body)} bytes is shorter "
                            "than its header length field")
    (header_len,) = _LEN.unpack_from(body, 0)
    header_end = _LEN.size + header_len
    if header_end > len(body):
        raise ProtocolError(f"frame header of {header_len} bytes overruns "
                            f"the {len(body)}-byte body")
    try:
        header = json.loads(body[_LEN.size:header_end].decode("utf-8"))
        ftype = header["type"]
        meta = header.get("meta", {})
        specs = header.get("arrays", [])
    except (ValueError, KeyError, UnicodeDecodeError) as e:
        raise ProtocolError(f"undecodable frame header: {e}") from e
    arrays: dict[str, np.ndarray] = {}
    off = header_end
    for name, dtype_str, shape, nbytes in specs:
        if off + nbytes > len(body):
            raise ProtocolError(
                f"array {name!r} ({nbytes} bytes) overruns the frame body")
        dt = np.dtype(dtype_str)
        count = int(np.prod(shape, dtype=np.int64))
        if count * dt.itemsize != nbytes:
            raise ProtocolError(
                f"array {name!r}: {nbytes} payload bytes != "
                f"{count} x {dt.itemsize}-byte elements of shape {shape}")
        arrays[name] = np.frombuffer(body, dtype=dt, count=count,
                                     offset=off).reshape(shape)
        off += nbytes
    if off != len(body):
        raise ProtocolError(f"{len(body) - off} trailing bytes after the "
                            "last declared array")
    return ftype, meta, arrays


async def write_frame(writer: asyncio.StreamWriter, type: str,
                      meta: dict | None = None,
                      arrays: dict[str, np.ndarray] | None = None,
                      drain: bool = True) -> int:
    """Encode and send one frame; returns bytes written (prefix included)."""
    wire = encode_frame(type, meta, arrays)
    writer.write(wire)
    if drain:
        await writer.drain()
    return len(wire)


async def read_frame(reader: asyncio.StreamReader,
                     max_bytes: int = MAX_FRAME_BYTES) -> Frame:
    """Read one frame.  Raises :class:`ConnectionClosed` on EOF between
    frames, :class:`ProtocolError` on truncation/corruption mid-frame."""
    try:
        prefix = await reader.readexactly(_LEN.size)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            raise ConnectionClosed("connection closed on a frame boundary") \
                from e
        raise ProtocolError(
            f"truncated frame: EOF after {len(e.partial)} of "
            f"{_LEN.size} length-prefix bytes") from e
    recv_start = time.monotonic()
    (body_len,) = _LEN.unpack(prefix)
    if body_len > max_bytes:
        raise ProtocolError(f"frame of {body_len} bytes exceeds the "
                            f"{max_bytes}-byte limit (corrupt length prefix?)")
    try:
        body = await reader.readexactly(body_len)
    except asyncio.IncompleteReadError as e:
        raise ProtocolError(
            f"truncated frame: EOF after {len(e.partial)} of "
            f"{body_len} body bytes") from e
    recv_end = time.monotonic()
    ftype, meta, arrays = decode_body(body)
    return Frame(type=ftype, meta=meta, arrays=arrays,
                 nbytes=_LEN.size + body_len,
                 recv_start=recv_start, recv_end=recv_end)
