"""Asyncio coordinator: drives one SplitPlan across socket workers.

The coordinator owns the model-level orchestration the paper assigns to the
gateway: it quantizes the request input, routes each worker its download
slice per block group, reassembles uploads (row-band concat for spatial
groups, flat-range concat otherwise), and keeps the coordinator-side ops —
residual adds, stash saves, global avgpool — exactly as the single-process
executors do (same jnp helpers), so distributed output is bit-identical to
``Session``.

Schedule realization (the PR 4 pipelined transport, for real): every block
group runs as its own asyncio task, and every (group, worker) feed is a
sub-task.  Per-worker send queues are FIFO links; a feed enqueues its
download as soon as its dependencies resolve, so downloads for group ``g+1``
overlap group ``g``'s compute and uploads.  Dependencies come from the
coordinator plan's boundary structure (``shards.build_coordinator_plan``):

* **clean seams** (spatial -> spatial, no coordinator-side post-op): the
  feed for worker ``w`` awaits only the band events of its
  ``_boundary_deps`` producers — the fine-grained row-overlap dependency.
* **everything else** barriers on the previous group's completion event
  (set after residual/stash post-ops), matching the simulator's model.

Each realized dependency is recorded as a ``(segment, consumer, producer)``
edge; validation checks the measured edge set is a superset of
``core.simulator.dependency_edges`` of the same plan.

Failure surfacing: every result await runs under a per-message timeout with
bounded resend (workers recompute idempotently); worker death (EOF,
truncated frame, protocol garbage) fails all of that worker's pending
futures; a heartbeat monitor catches silent wedges.  All of these surface
as ``RuntimeError`` naming the worker — never a hang.  ``close()`` cancels
every task the coordinator created (no orphans) and reaps spawned
processes.
"""
from __future__ import annotations

import asyncio
import os
import sys
import time

import jax.numpy as jnp
import numpy as np

from ..core.executor import _avgpool_int8, _residual_add_int8
from ..core.quantize import QuantizedModel, quantize_activation_jnp
from ..core.simulator import Timeline, TimelineEvent
from ..core.splitting import SplitPlan
from .protocol import ConnectionClosed, ProtocolError, read_frame, write_frame
from .shards import (SEGMENT_CACHE_CAP, build_coordinator_plan,
                     build_worker_setup, delta_setup, setup_array_bytes)

SPAWN_MODES = ("process", "inprocess", "external")


class WorkerHandle:
    """Coordinator-side state for one connected worker."""

    def __init__(self, worker: int, loop: asyncio.AbstractEventLoop):
        self.worker = worker
        self.reader: asyncio.StreamReader | None = None
        self.writer: asyncio.StreamWriter | None = None
        self.send_q: asyncio.Queue = asyncio.Queue()
        self.pending: dict[tuple, asyncio.Future] = {}
        self.ready_fut: asyncio.Future = loop.create_future()
        self.failed: BaseException | None = None
        self.last_heartbeat = time.monotonic()
        self.setup_s = 0.0
        self.proc = None                    # asyncio subprocess, if spawned
        self.log_file = None
        # warm-store bookkeeping for elastic delta setups.  held_segments
        # mirrors the worker's compiled-segment LRU (same order, same
        # SEGMENT_CACHE_CAP), so "expected cache hit" never claims a
        # fingerprint the worker has already evicted.
        self.held_arrays: dict[str, int] = {}    # content fp -> nbytes
        self.held_segments: dict[str, None] = {}  # fp -> None, LRU order


class _RequestCtx:
    """Per-request dataflow state."""

    def __init__(self, seq: int, x0: np.ndarray, n_groups: int,
                 n_workers: int):
        self.seq = seq
        self.x0 = x0
        self.raw: list[np.ndarray | None] = [None] * n_groups
        self.final: list[np.ndarray | None] = [None] * n_groups
        self.band_ev = [{w: asyncio.Event() for w in range(n_workers)}
                        for _ in range(n_groups)]
        self.complete = [asyncio.Event() for _ in range(n_groups)]
        self.stash: dict = {}
        self.edges: set[tuple[int, int, int]] = set()


class Coordinator:
    """Distributed executor for one compiled split plan.

    Async context manager::

        async with Coordinator(split, qmodel, spawn="process") as coord:
            y = await coord.infer(x)
            tl = coord.last_timeline
    """

    def __init__(self, split: SplitPlan, qmodel: QuantizedModel | None = None,
                 *, precision: str = "int8", spawn: str = "process",
                 host: str = "127.0.0.1", port: int = 0,
                 request_timeout: float = 60.0, max_retries: int = 2,
                 setup_timeout: float = 300.0, heartbeat_s: float = 0.5,
                 heartbeat_timeout: float = 30.0, log_dir: str | None = None):
        if spawn not in SPAWN_MODES:
            raise ValueError(f"unknown spawn mode {spawn!r} "
                             f"(want one of {SPAWN_MODES})")
        self.split = split
        self.qmodel = qmodel
        self.precision = precision
        self.spawn = spawn
        self.host = host
        self.port = port
        self.request_timeout = request_timeout
        self.max_retries = max_retries
        self.setup_timeout = setup_timeout
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout = heartbeat_timeout
        self.log_dir = log_dir
        self.cplan = build_coordinator_plan(split, qmodel, precision)
        self.expected = sorted({w for g in self.cplan.groups
                                for w in g.actives})
        self.handles: dict[int, WorkerHandle] = {}
        self.last_timeline: Timeline | None = None
        self.last_edges: set[tuple[int, int, int]] = set()
        self.measured_edges: set[tuple[int, int, int]] = set()
        self.setup_s = 0.0
        self._server: asyncio.AbstractServer | None = None
        self._tasks: set[asyncio.Task] = set()
        self._retired: list[WorkerHandle] = []
        self._seq = 0
        self._infer_lock = asyncio.Lock()
        self._fatal: asyncio.Future | None = None
        self._int8 = precision == "int8"
        self._started = False

    # -- lifecycle ---------------------------------------------------------

    async def __aenter__(self) -> "Coordinator":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    def _track(self, coro) -> asyncio.Task:
        t = asyncio.create_task(coro)
        self._tasks.add(t)
        t.add_done_callback(self._tasks.discard)
        return t

    async def start(self) -> None:
        """Bind the server, spawn/attach workers, ship setups, await ready."""
        loop = asyncio.get_running_loop()
        self._fatal = loop.create_future()
        self.handles = {w: WorkerHandle(w, loop) for w in self.expected}
        self._server = await asyncio.start_server(
            self._on_connect, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        t0 = time.monotonic()
        if self.spawn == "process":
            await self._spawn_processes()
        elif self.spawn == "inprocess":
            from .worker import run_worker
            for w in self.expected:
                self._track(run_worker(self.host, self.port, w,
                                       heartbeat_s=self.heartbeat_s))
        ready = asyncio.gather(*(h.ready_fut
                                 for h in self.handles.values()))
        done, _ = await asyncio.wait(
            {asyncio.ensure_future(ready), self._fatal},
            timeout=self.setup_timeout,
            return_when=asyncio.FIRST_COMPLETED)
        if self._fatal in done or not done:
            ready.cancel()
            await asyncio.gather(ready, return_exceptions=True)
            if self._fatal in done:
                raise RuntimeError(f"runtime setup failed: "
                                   f"{self._fatal.result()}")
            missing = [w for w, h in self.handles.items()
                       if not h.ready_fut.done()]
            raise RuntimeError(
                f"runtime setup timed out after {self.setup_timeout}s "
                f"waiting for workers {missing}")
        await ready                         # re-raise per-worker failures
        self.setup_s = time.monotonic() - t0
        self._track(self._monitor())
        self._started = True

    async def _spawn_one(self, w: int) -> None:
        import repro
        src = os.path.dirname(os.path.dirname(os.path.abspath(
            repro.__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        h = self.handles[w]
        if self.log_dir:
            os.makedirs(self.log_dir, exist_ok=True)
            h.log_file = open(os.path.join(self.log_dir,
                                           f"worker{w}.log"), "wb")
            out = h.log_file
        else:
            out = asyncio.subprocess.DEVNULL
        h.proc = await asyncio.create_subprocess_exec(
            sys.executable, "-m", "repro.runtime.worker",
            "--host", self.host, "--port", str(self.port),
            "--id", str(w), "--heartbeat-s", str(self.heartbeat_s),
            env=env, stdout=out, stderr=out)

    async def _spawn_processes(self) -> None:
        for w in self.expected:
            await self._spawn_one(w)

    async def close(self) -> None:
        """Shut everything down; cancels every coordinator-created task."""
        for h in self.handles.values():
            if h.writer is not None and h.failed is None:
                try:
                    await write_frame(h.writer, "shutdown", drain=False)
                except (ConnectionError, RuntimeError, OSError):
                    pass
        for t in list(self._tasks):
            t.cancel()
        if self._tasks:
            await asyncio.gather(*self._tasks, return_exceptions=True)
        for h in list(self.handles.values()) + self._retired:
            if h.writer is not None:
                h.writer.close()
            if h.proc is not None:
                try:
                    await asyncio.wait_for(h.proc.wait(), timeout=10)
                except asyncio.TimeoutError:
                    h.proc.kill()
                    await h.proc.wait()
            if h.log_file is not None:
                h.log_file.close()
        self._retired.clear()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    # -- connection handling -----------------------------------------------

    async def _on_connect(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            hello = await read_frame(reader)
            if hello.type != "hello":
                raise ProtocolError(f"expected hello, got {hello.type!r}")
            w = hello.meta["worker"]
            h = self.handles.get(w)
            if h is None or h.reader is not None:
                raise ProtocolError(f"unexpected worker id {w!r}")
        except (ProtocolError, KeyError, TypeError) as e:
            writer.close()
            if self._fatal is not None and not self._fatal.done():
                self._fatal.set_result(
                    f"unidentified peer rejected during attach: {e}")
            return
        h.reader, h.writer = reader, writer
        h.last_heartbeat = time.monotonic()
        self._track(self._sender_loop(h))
        self._track(self._reader_loop(h))
        meta, arrays = build_worker_setup(self.split, self.qmodel,
                                          self.precision, w)
        meta["worker"] = w
        self._record_held(h, meta, arrays)
        h.send_q.put_nowait(("setup", {"plan": meta}, arrays))

    @staticmethod
    def _record_held(h: WorkerHandle, meta: dict, arrays: dict) -> None:
        """Track which array contents / segment geometries a worker holds,
        so a later replan ships only the delta."""
        for spec in meta["segments"]:
            for key, fp in spec.get("array_fps", {}).items():
                h.held_arrays[fp] = int(arrays[key].nbytes)
            fp = spec.get("fingerprint")
            if fp is None:
                continue
            # replay the worker's LRU: hit -> most-recent, miss -> insert,
            # evict oldest beyond the cap (build_segment_fns does the same
            # in the same spec order)
            h.held_segments.pop(fp, None)
            h.held_segments[fp] = None
            while len(h.held_segments) > SEGMENT_CACHE_CAP:
                del h.held_segments[next(iter(h.held_segments))]

    async def _sender_loop(self, h: WorkerHandle) -> None:
        try:
            while True:
                ftype, meta, arrays = await h.send_q.get()
                await write_frame(h.writer, ftype, meta, arrays)
        except asyncio.CancelledError:
            raise
        except (ConnectionError, OSError, RuntimeError) as e:
            self._fail_worker(h, f"send to worker {h.worker} failed: {e}")

    async def _reader_loop(self, h: WorkerHandle) -> None:
        try:
            while True:
                frame = await read_frame(h.reader)
                t = frame.type
                if t == "result":
                    key = (frame.meta["seq"], frame.meta["gi"])
                    fut = h.pending.get(key)
                    if fut is not None and not fut.done():
                        fut.set_result(frame)
                elif t in ("heartbeat", "pong"):
                    h.last_heartbeat = time.monotonic()
                elif t == "events":
                    key = ("events", frame.meta.get("seq"))
                    fut = h.pending.get(key)
                    if fut is not None and not fut.done():
                        fut.set_result(frame.meta.get("events", []))
                elif t == "ready":
                    h.setup_s = float(frame.meta.get("setup_s", 0.0))
                    h.last_heartbeat = time.monotonic()
                    fut = h.pending.get(("ready",))
                    if fut is not None and not fut.done():
                        fut.set_result(frame.meta)   # replan re-setup ack
                    elif not h.ready_fut.done():
                        h.ready_fut.set_result(frame.meta)
                else:
                    raise ProtocolError(f"unexpected frame {t!r}")
        except asyncio.CancelledError:
            raise
        except ConnectionClosed:
            self._fail_worker(
                h, f"worker {h.worker} closed its connection "
                   f"({len(h.pending)} request(s) in flight)")
        except (ProtocolError, OSError, Exception) as e:
            self._fail_worker(
                h, f"worker {h.worker} stream failure: {e}")

    def _fail_worker(self, h: WorkerHandle, msg: str) -> None:
        if h.failed is not None:
            return
        exc = RuntimeError(msg)
        h.failed = exc
        if not h.ready_fut.done():
            h.ready_fut.set_exception(exc)
        else:
            h.ready_fut.exception()         # may be unretrieved; silence
        for fut in h.pending.values():
            if not fut.done():
                fut.set_exception(exc)

    async def _monitor(self) -> None:
        """Heartbeat-staleness watchdog: a silent worker fails loudly."""
        while True:
            await asyncio.sleep(self.heartbeat_timeout / 4)
            now = time.monotonic()
            for h in self.handles.values():
                if (h.failed is None and h.ready_fut.done()
                        and not h.ready_fut.cancelled()
                        and h.ready_fut.exception() is None
                        and now - h.last_heartbeat > self.heartbeat_timeout):
                    self._fail_worker(
                        h, f"worker {h.worker} heartbeat silent for "
                           f"{now - h.last_heartbeat:.1f}s "
                           f"(timeout {self.heartbeat_timeout}s)")

    # -- elastic replan ----------------------------------------------------

    def _retire(self, h: WorkerHandle) -> None:
        """Queue a handle for teardown: polite shutdown if still healthy,
        process reaped in close().  Never blocks the replan."""
        if h.failed is None and h.writer is not None:
            h.send_q.put_nowait(("shutdown", {}, None))
        self._retired.append(h)

    async def replan_to(self, split: SplitPlan, *,
                        worker_map: dict[int, int] | None = None) -> dict:
        """Atomically cut the cluster over to a new SplitPlan.

        Runs entirely under the infer lock: in-flight requests finish (or
        fail) under the old plan, queued submissions resume under the new
        one — no request ever observes a half-shipped topology.

        ``worker_map`` maps each *new* plan worker index to the *old* index
        whose live connection it inherits.  Inherited workers get a delta
        setup (arrays they already hold are omitted; unchanged segment
        geometry reuses their warm compiled cache); unmapped indices get
        freshly spawned workers; old workers with no successor are retired.

        Returns a transition report: ``downtime_s``, ``reshipped_bytes``
        vs ``full_setup_bytes``, warm-cache ``cache_hits`` /
        ``cache_misses`` vs ``expected_cache_hits`` and the resulting
        ``hit_rate``.
        """
        if not self._started:
            raise RuntimeError("Coordinator.start() has not completed")
        worker_map = dict(worker_map or {})
        loop = asyncio.get_running_loop()
        async with self._infer_lock:
            t0 = time.monotonic()
            cplan = build_coordinator_plan(split, self.qmodel,
                                           self.precision)
            expected = sorted({w for g in cplan.groups for w in g.actives})
            new_handles: dict[int, WorkerHandle] = {}
            waiters: dict[int, asyncio.Future] = {}
            inherited: list[int] = []
            fresh: list[int] = []
            full_setup_bytes = 0
            reshipped_bytes = 0
            expected_cache_hits = 0
            for w in expected:
                meta, arrays = build_worker_setup(split, self.qmodel,
                                                  self.precision, w)
                meta["worker"] = w
                full_setup_bytes += setup_array_bytes(arrays)
                old = worker_map.get(w)
                h = self.handles.get(old) if old is not None else None
                if (h is not None and h.failed is None
                        and h.reader is not None):
                    ship = delta_setup(meta, arrays, set(h.held_arrays))
                    reshipped_bytes += setup_array_bytes(ship)
                    expected_cache_hits += sum(
                        1 for spec in meta["segments"]
                        if spec.get("fingerprint") in h.held_segments)
                    fut = loop.create_future()
                    h.pending[("ready",)] = fut
                    waiters[w] = fut
                    self._record_held(h, meta, arrays)
                    h.worker = w
                    h.send_q.put_nowait(("setup", {"plan": meta}, ship))
                    new_handles[w] = h
                    inherited.append(w)
                else:
                    nh = WorkerHandle(w, loop)
                    self._record_held(nh, meta, arrays)
                    reshipped_bytes += setup_array_bytes(arrays)
                    new_handles[w] = nh
                    waiters[w] = nh.ready_fut
                    fresh.append(w)
            kept = {id(h) for h in new_handles.values()}
            retired = [w for w, h in self.handles.items()
                       if id(h) not in kept]
            for w in retired:
                self._retire(self.handles[w])
            # atomic cutover: requests queued on the infer lock see this
            self.split, self.cplan, self.expected = split, cplan, expected
            self.handles = new_handles
            if self.spawn == "process":
                for w in fresh:
                    await self._spawn_one(w)
            elif self.spawn == "inprocess":
                from .worker import run_worker
                for w in fresh:
                    self._track(run_worker(self.host, self.port, w,
                                           heartbeat_s=self.heartbeat_s))
            ready = asyncio.gather(*waiters.values())
            try:
                metas = await asyncio.wait_for(ready, self.setup_timeout)
            except asyncio.TimeoutError:
                missing = [w for w, f in waiters.items() if not f.done()]
                raise RuntimeError(
                    f"replan setup timed out after {self.setup_timeout}s "
                    f"waiting for workers {missing}") from None
            finally:
                for w in inherited:
                    new_handles[w].pending.pop(("ready",), None)
            cache_hits = sum(int(m.get("cache_hits", 0)) for m in metas)
            cache_misses = sum(int(m.get("cache_misses", 0)) for m in metas)
            received_bytes = sum(int(m.get("received_bytes", 0))
                                 for m in metas)
            downtime_s = time.monotonic() - t0
            return {
                "downtime_s": downtime_s,
                "full_setup_bytes": int(full_setup_bytes),
                "reshipped_bytes": int(reshipped_bytes),
                "received_bytes": int(received_bytes),
                "cache_hits": cache_hits,
                "cache_misses": cache_misses,
                "expected_cache_hits": int(expected_cache_hits),
                "hit_rate": (cache_hits / expected_cache_hits
                             if expected_cache_hits else 1.0),
                "inherited": inherited,
                "spawned": fresh,
                "retired": retired,
            }

    # -- request-level messaging -------------------------------------------

    async def _await_result(self, h: WorkerHandle, key: tuple, gi: int,
                            seq: int, send) -> "object":
        """Send and await one result with bounded retry.  Raises a
        RuntimeError naming the worker on failure or timeout — never hangs.
        """
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        h.pending[key] = fut
        try:
            if h.failed is not None:
                raise RuntimeError(str(h.failed)) from h.failed
            send()
            attempts = 0
            while True:
                attempts += 1
                done, _ = await asyncio.wait(
                    {fut}, timeout=self.request_timeout)
                if done:
                    return fut.result()     # worker-failure excs re-raise
                if attempts > self.max_retries:
                    age = time.monotonic() - h.last_heartbeat
                    raise RuntimeError(
                        f"worker {h.worker} timed out on segment {gi} of "
                        f"request {seq}: {attempts} attempt(s) x "
                        f"{self.request_timeout}s each, last heartbeat "
                        f"{age:.1f}s ago")
                send()                      # idempotent recompute on worker
        finally:
            h.pending.pop(key, None)
            if not fut.done():
                fut.cancel()

    # -- dataflow ----------------------------------------------------------

    def _slice_download(self, g, w: int, src: np.ndarray,
                        pad_cache: dict) -> np.ndarray:
        spec = g.downloads[w]
        if spec["kind"] == "rows":
            return src[:, spec["lo"]:spec["hi"], :]
        if spec["kind"] == "full":
            return src
        # conv: slice the padded-input row window the shard's rows need
        if "pad" not in pad_cache:
            ph, pw = spec["ph"], spec["pw"]
            pad_cache["pad"] = np.pad(src, ((0, 0), (ph, ph), (pw, pw)))
        x_pad = pad_cache["pad"]
        xs = x_pad[:, spec["r0"]:spec["r1"], :]
        if spec["c_lo"] is not None:
            xs = xs[spec["c_lo"]:spec["c_hi1"]]
        return xs

    def _post(self, g, cur: np.ndarray, ctx: _RequestCtx) -> np.ndarray:
        """Coordinator-side residual/stash bookkeeping (Alg. 4 line 9),
        bit-identical to the single-process executors."""
        if g.residual_from is not None:
            if self._int8:
                oth_scale, oth_q = ctx.stash[g.residual_from]
                cur = np.asarray(_residual_add_int8(
                    jnp.asarray(cur), g.out_scale,
                    jnp.asarray(oth_q), oth_scale))
            else:
                cur = np.asarray(jnp.asarray(cur)
                                 + jnp.asarray(ctx.stash[g.residual_from]))
        if g.save_as is not None:
            ctx.stash[g.save_as] = ((g.out_scale, cur) if self._int8
                                    else cur)
        return cur

    def _record_boundary(self, g, ctx: _RequestCtx,
                         workers=None) -> None:
        """Record realized dependency edges for the seam into group g.gi.
        A barrier (completion-event wait) happens-after every producer
        upload, so every predicted edge is realized; the clean path records
        per-consumer as each awaited band lands."""
        if g.deps is None:
            return
        for w, producers in enumerate(g.deps):
            if workers is not None and w not in workers:
                continue
            for p in producers:
                ctx.edges.add((g.gi, w, p))

    async def _run_group(self, gi: int, ctx: _RequestCtx) -> None:
        g = self.cplan.groups[gi]
        if g.kind == "local":
            if gi:
                await ctx.complete[gi - 1].wait()
                self._record_boundary(g, ctx)
            src = (ctx.final[gi - 1] if gi else ctx.x0).reshape(g.in_shape)
            _, in_scale, out_scale = g.local
            if self._int8:
                y = np.asarray(_avgpool_int8(jnp.asarray(src),
                                             in_scale, out_scale))
            else:
                y = np.asarray(jnp.mean(jnp.asarray(src), axis=(1, 2),
                                        keepdims=True))
            ctx.raw[gi] = y
            ctx.final[gi] = self._post(g, y, ctx)
            ctx.complete[gi].set()
            return

        dtype = np.int8 if self._int8 else np.float32
        buf = (np.zeros(g.out_shape, dtype) if g.kind == "spatial"
               else np.zeros(int(np.prod(g.out_shape)), dtype))
        ctx.raw[gi] = buf
        pad_cache: dict = {}
        fine = g.clean and gi > 0

        async def feed_gather(w: int) -> None:
            h = self.handles[w]
            if gi == 0:
                src = ctx.x0.reshape(g.in_shape)
            elif fine:
                for p in g.deps[w]:
                    await ctx.band_ev[gi - 1][p].wait()
                    ctx.edges.add((gi, w, p))
                src = ctx.raw[gi - 1]       # clean seam: post is identity
            else:
                await ctx.complete[gi - 1].wait()
                src = ctx.final[gi - 1].reshape(g.in_shape)
            xs = self._slice_download(g, w, src, pad_cache)
            key = (ctx.seq, gi)

            def send() -> None:
                h.send_q.put_nowait(("infer_input",
                                     {"seq": ctx.seq, "gi": gi}, {"x": xs}))

            frame = await self._await_result(h, key, gi, ctx.seq, send)
            y = np.asarray(frame.arrays["y"])
            spec = g.assembly[w]
            if spec["kind"] == "rows":
                buf[:, spec["lo"]:spec["hi"], :] = y.reshape(
                    buf.shape[0], spec["hi"] - spec["lo"], buf.shape[2])
            else:
                buf[spec["start"]:spec["stop"]] = y.reshape(-1)
            ctx.band_ev[gi][w].set()

        feeds = [asyncio.ensure_future(feed_gather(w)) for w in g.actives]
        try:
            await asyncio.gather(*feeds)
        except BaseException:
            for f in feeds:
                f.cancel()
            await asyncio.gather(*feeds, return_exceptions=True)
            raise
        if gi and not fine:
            self._record_boundary(g, ctx)
        elif fine:
            # inactive consumers have no download; their predicted edges
            # hold vacuously
            self._record_boundary(
                g, ctx, workers=set(range(self.split.n_workers))
                - set(g.actives))
        cur = buf if g.kind == "spatial" else buf.reshape(g.out_shape)
        ctx.final[gi] = self._post(g, cur, ctx)
        ctx.complete[gi].set()

    async def infer(self, x: np.ndarray) -> np.ndarray:
        """Run one request through the cluster; bit-exact vs ``Session``.

        Also populates ``last_timeline`` (measured per-worker events in the
        simulator's schema) and ``last_edges`` (realized dependency edges).
        """
        if not self._started:
            raise RuntimeError("Coordinator.start() has not completed")
        async with self._infer_lock:
            seq = self._seq
            self._seq += 1
            t0 = time.monotonic()
            if self._int8:
                x0 = np.asarray(quantize_activation_jnp(
                    jnp.asarray(x), self.cplan.input_scale))
            else:
                x0 = np.asarray(x, np.float32)
            ctx = _RequestCtx(seq, x0, len(self.cplan.groups),
                              self.split.n_workers)
            tasks = [asyncio.ensure_future(self._run_group(gi, ctx))
                     for gi in range(len(self.cplan.groups))]
            try:
                await asyncio.gather(*tasks)
            except BaseException:
                for t in tasks:
                    t.cancel()
                await asyncio.gather(*tasks, return_exceptions=True)
                raise
            t_end = time.monotonic()
            out = np.asarray(ctx.final[-1])
            self.last_timeline = await self._collect_timeline(seq, t0, t_end)
            self.last_edges = set(ctx.edges)
            self.measured_edges |= ctx.edges
            return out

    async def infer_many(self, xs) -> list[np.ndarray]:
        return [await self.infer(x) for x in xs]

    async def _collect_timeline(self, seq: int, t0: float,
                                t_end: float) -> Timeline:
        """Pull each worker's event log and assemble a measured Timeline in
        the simulator's schema, normalized to request start."""
        loop = asyncio.get_running_loop()
        futs: dict[int, asyncio.Future] = {}
        for w, h in self.handles.items():
            if h.failed is not None:
                continue
            fut = loop.create_future()
            h.pending[("events", seq)] = fut
            h.send_q.put_nowait(("collect", {"seq": seq}, None))
            futs[w] = fut
        events: list[TimelineEvent] = []
        for w, fut in futs.items():
            h = self.handles[w]
            try:
                done, _ = await asyncio.wait(
                    {fut}, timeout=self.request_timeout)
                if not done or fut.exception() is not None:
                    continue                # timeline stays partial, not fatal
                for ev in fut.result():
                    events.append(TimelineEvent(
                        worker=ev["worker"], kind=ev["kind"],
                        segment=ev["segment"], layer=ev["layer"],
                        start_s=max(ev["start_s"] - t0, 0.0),
                        end_s=max(ev["end_s"] - t0, 0.0),
                        nbytes=ev.get("nbytes", 0)))
            finally:
                h.pending.pop(("events", seq), None)
                if not fut.done():
                    fut.cancel()
        events.sort(key=lambda e: (e.start_s, e.worker, e.segment))
        return Timeline(n_workers=self.split.n_workers,
                        events=tuple(events), makespan_s=t_end - t0)
