from .elastic import ElasticCluster, WorkerHealth, plan_recovery_mesh
