from .elastic import ElasticCluster, WorkerHealth, plan_recovery_mesh
from .coordinator import Coordinator, WorkerHandle
from .protocol import (ConnectionClosed, Frame, ProtocolError, encode_frame,
                       decode_body, read_frame, write_frame)
from .shards import (build_coordinator_plan, build_segment_fns,
                     build_worker_setup, worker_geometry_summary)
from .validate import ValidationReport, run_distributed, validate_distributed
