from .elastic import (ClusterCollapsed, ElasticCluster, WorkerHealth,
                      plan_recovery_mesh)
from .coordinator import Coordinator, WorkerHandle
from .protocol import (ConnectionClosed, Frame, ProtocolError, encode_frame,
                       decode_body, read_frame, write_frame)
from .replan import ElasticCoordinator, PlanDiff, SegmentDiff, diff_plans
from .shards import (build_coordinator_plan, build_segment_fns,
                     build_worker_setup, delta_setup, setup_array_bytes,
                     worker_geometry_summary)
from .validate import ValidationReport, run_distributed, validate_distributed
