"""Per-worker shard payloads and segment compilation for the distributed
runtime.

Three host-side views of one :class:`~repro.core.splitting.SplitPlan` live
here, all derived from the same compiled geometry the single-process
executors use (``mapping.compile_shard_geometry`` /
``splitting.spatial_band_geometry``):

* :func:`build_worker_setup` — the setup frame shipped to one worker at
  attach time: plain-JSON segment specs plus the weight fragments (int8
  ``w_q`` / int32 epilogue bias / f32 scale in int8 mode, f32 weights in
  float mode).  A worker only ever receives the fragments its own shards
  touch (spatial bands replicate full block weights, exactly as the plan's
  ``weight_bytes`` accounting says).

* :func:`build_segment_fns` — the worker-side half: lower each received
  segment spec into one ``jax.jit``-ed function over the routed input slice.
  The traced bodies are the *same primitives* the single-process executors
  run (``_conv_chw``/``_spatial_stage_acc`` accumulation, multiply-only
  ``requantize`` epilogue), so distributed int8 output is bit-identical to
  the eager oracle and the compiled ``Session`` — the runtime's correctness
  contract.

* :func:`build_coordinator_plan` — the coordinator-side routing table: per
  block group, which workers are active, how to slice the current activation
  into each worker's download, how to place uploads back into the output
  buffer (row bands / flat ranges), the residual/stash bookkeeping that
  stays coordinator-side (Alg. 4 line 9), and the boundary dependency
  structure (exact ``pipelined_dependencies`` row-overlap deps for clean
  spatial seams, a barrier everywhere else) realized by the per-link queues
  in ``runtime.coordinator``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import time

import numpy as np

from ..core.executor import _conv_chw
from ..core.fusion import apply_activation
from ..core.mapping import compile_shard_geometry
from ..core.quantize import QuantizedModel, epilogue_params, requantize
from ..core.simulator import _segments, pipelined_dependencies
from ..core.splitting import SplitPlan, spatial_band_geometry

PRECISIONS = ("int8", "float")


def _check_precision(precision: str) -> bool:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r} "
                         f"(want one of {PRECISIONS})")
    return precision == "int8"


def _array_fp(a: np.ndarray) -> str:
    """Content fingerprint of one wire array (dtype + shape + bytes)."""
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(repr(tuple(a.shape)).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()[:16]


def _array_role(key: str) -> str:
    """Wire key with the group index stripped (``w3_1`` -> ``w_1``,
    ``b7`` -> ``b``): array identity is content + role, never group
    numbering, so a segment that lands at a different ``gi`` after a replan
    still fingerprints identically."""
    prefix, rest = key[0], key[1:]
    if "_" in rest:
        return prefix + "_" + rest.split("_", 1)[1]
    return prefix


def _fingerprint_spec(spec: dict, arrays: dict[str, np.ndarray],
                      keys: list[str]) -> None:
    """Annotate one segment spec in place with content fingerprints.

    ``array_fps`` maps each wire array key to its content fingerprint (the
    unit of re-ship avoidance: a worker that already holds the bytes is not
    sent them again); ``fingerprint`` hashes the spec minus its group index
    plus the array contents — the unit of warm recompilation: an identical
    fingerprint means the jitted segment function can be reused verbatim.
    """
    spec["array_fps"] = {k: _array_fp(arrays[k]) for k in keys}
    clean = {k: v for k, v in spec.items()
             if k not in ("gi", "array_fps", "fingerprint")}
    h = hashlib.sha256(json.dumps(clean, sort_keys=True).encode())
    for k in sorted(keys, key=_array_role):
        h.update(_array_role(k).encode())
        h.update(spec["array_fps"][k].encode())
    spec["fingerprint"] = h.hexdigest()[:16]


def setup_array_bytes(arrays: dict[str, np.ndarray]) -> int:
    """Total payload bytes of a setup frame's arrays."""
    return int(sum(a.nbytes for a in arrays.values()))


def delta_setup(meta: dict, arrays: dict[str, np.ndarray],
                held_array_fps: set[str]) -> dict[str, np.ndarray]:
    """The arrays a worker that already holds ``held_array_fps`` actually
    needs — content the worker has (by fingerprint) is dropped, and the
    worker resolves the omitted keys from its local store via the specs'
    ``array_fps``.  The meta is shipped unchanged (specs are cheap JSON)."""
    fps: dict[str, str] = {}
    for spec in meta["segments"]:
        fps.update(spec.get("array_fps", {}))
    return {k: v for k, v in arrays.items()
            if fps.get(k) not in held_array_fps}


def _layer_consts(layer, ql, int8: bool):
    """(weight, bias, scale) arrays for one layer in the wire layout."""
    if int8:
        scale, b_q = epilogue_params(ql)
        return ql.w_q, b_q, scale
    bias = (layer.bias if layer.bias is not None
            else np.zeros(layer.out_shape[0], np.float32))
    return np.asarray(layer.weight, np.float32), \
        np.asarray(bias, np.float32), None


# ---------------------------------------------------------------------------
# Worker setup payloads
# ---------------------------------------------------------------------------

def build_worker_setup(split: SplitPlan, qmodel: QuantizedModel | None,
                       precision: str, worker: int) -> tuple[dict, dict]:
    """The setup frame for one worker: ``(meta, arrays)``.

    ``meta["segments"]`` has one spec per block group of the plan, in group
    order; groups where this worker computes nothing (empty shard,
    coordinator-local layers) are ``{"kind": "skip"}``.  Arrays are keyed
    ``w{gi}_{li}`` / ``b{gi}_{li}`` / ``s{gi}_{li}`` (weight / bias /
    epilogue scale; flat groups drop the ``_li``).
    """
    int8 = _check_precision(precision)
    if int8 and qmodel is None:
        raise ValueError("precision='int8' requires a QuantizedModel")
    model = split.model
    segments: list[dict] = []
    arrays: dict[str, np.ndarray] = {}
    for gi, idxs in enumerate(split.block_groups):
        sp0 = split.splits[idxs[0]]
        if sp0.mode == "spatial":
            geoms = [spatial_band_geometry(split.splits[i].layer,
                                           split.splits[i]) for i in idxs]
            if geoms[-1][worker] is None:
                segments.append({"gi": gi, "kind": "skip"})
                continue
            g0 = geoms[0][worker]
            first_layer = model.layers[idxs[0]]
            in_rows = (g0.in_hi - g0.in_lo) if g0 is not None else 0
            stages: list[dict] = []
            seg_keys: list[str] = []
            for li, i in enumerate(idxs):
                layer = model.layers[i]
                g = geoms[li][worker]
                if g is None:
                    # degenerate interior stage (zero-height band): the next
                    # stage pads the empty band up to its window, exactly as
                    # the eager oracle's _run_block_spatial does
                    stages.append({"empty": True,
                                   "out_channels": layer.out_shape[0],
                                   "out_width": layer.out_shape[2]})
                    continue
                ql = qmodel.layers[i] if int8 else None
                w, b, s = _layer_consts(layer, ql, int8)
                arrays[f"w{gi}_{li}"] = w
                arrays[f"b{gi}_{li}"] = b
                seg_keys += [f"w{gi}_{li}", f"b{gi}_{li}"]
                stage = {"layer": i, "stride": list(layer.stride),
                         "pw": layer.padding[1],
                         "pad_top": g.pad_top, "pad_bot": g.pad_bot,
                         "activation": layer.activation}
                if int8:
                    arrays[f"s{gi}_{li}"] = s
                    seg_keys.append(f"s{gi}_{li}")
                    stage["out_scale"] = float(ql.out_scale)
                stages.append(stage)
            spec = {"gi": gi, "kind": "spatial",
                    "layer_first": idxs[0],
                    "in_shape": [first_layer.in_shape[0], in_rows,
                                 first_layer.in_shape[2]],
                    "stages": stages}
            _fingerprint_spec(spec, arrays, seg_keys)
            segments.append(spec)
            continue
        # flat group: singleton layer (conv/dwconv/linear shard, or
        # coordinator-local avgpool)
        (i,) = idxs
        layer = model.layers[i]
        shard = sp0.shard_of(worker)
        if layer.kind == "avgpool" or shard.n_positions == 0:
            segments.append({"gi": gi, "kind": "skip"})
            continue
        ql = qmodel.layers[i] if int8 else None
        w, b, s = _layer_consts(layer, ql, int8)
        if layer.kind == "linear":
            sl, e = shard.start, shard.stop
            arrays[f"w{gi}"] = w[:, sl:e]
            arrays[f"b{gi}"] = b[sl:e]
            spec = {"gi": gi, "kind": "linear", "layer_first": i,
                    "cols": [int(sl), int(e)],
                    "in_len": int(np.prod(layer.in_shape)),
                    "activation": layer.activation}
            seg_keys = [f"w{gi}", f"b{gi}"]
            if int8:
                arrays[f"s{gi}"] = s[sl:e]
                seg_keys.append(f"s{gi}")
                spec["out_scale"] = float(ql.out_scale)
            _fingerprint_spec(spec, arrays, seg_keys)
            segments.append(spec)
            continue
        geom = compile_shard_geometry(layer, sp0)[worker]
        assert geom is not None
        ph, pw = layer.padding
        c_in = layer.in_shape[0]
        n_ch_in = (geom.n_channels if layer.kind == "dwconv" else c_in)
        arrays[f"w{gi}"] = w[geom.c_lo:geom.c_hi + 1]
        arrays[f"b{gi}"] = b[geom.c_lo:geom.c_hi + 1]
        spec = {"gi": gi, "kind": "conv", "layer_first": i,
                "stride": list(layer.stride),
                "in_shape": [n_ch_in, geom.in_r1 - geom.in_r0,
                             layer.in_shape[2] + 2 * pw],
                "bbox_start": int(geom.bbox_start),
                "n_positions": int(geom.n_positions),
                "activation": layer.activation}
        seg_keys = [f"w{gi}", f"b{gi}"]
        if int8:
            # per-position epilogue scale over the shard's flat range — the
            # eager oracle requantizes the concatenated accumulator with
            # scale[flat_idx // hw]; requantization is elementwise, so each
            # worker applying its own slice commutes with the concat
            hw = layer.out_shape[1] * layer.out_shape[2]
            idx = np.arange(shard.start, shard.stop)
            arrays[f"s{gi}"] = s[idx // hw]
            seg_keys.append(f"s{gi}")
            spec["out_scale"] = float(ql.out_scale)
        _fingerprint_spec(spec, arrays, seg_keys)
        segments.append(spec)
    meta = {"precision": precision, "segments": segments}
    if int8:
        meta["input_scale"] = float(qmodel.input_scale)
    return meta, arrays


# ---------------------------------------------------------------------------
# Worker-side segment compilation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledSegment:
    """One jitted per-segment function on the worker."""

    gi: int
    layer_first: int
    input_shape: tuple[int, ...]
    fn: "object"                    # jitted callable, input slice -> output

    def warmup(self, dtype) -> None:
        np.asarray(self.fn(np.zeros(self.input_shape, dtype)))


# Upper bound on warm compiled segments a worker keeps across replans.
# Sized for several topology epochs of the full MobileNetV2 split (~30
# segments per worker per epoch): the coordinator mirrors this LRU in
# ``WorkerHandle.held_segments``, so the bound is also what the hit-rate
# accounting promises — an undersized cap shows up as a gated hit-rate
# miss, not a silent recompile.
SEGMENT_CACHE_CAP = 256


def build_segment_fns(meta: dict, arrays: dict[str, np.ndarray],
                      cache: "collections.OrderedDict | None" = None,
                      stats: dict | None = None) -> dict[int, CompiledSegment]:
    """Lower a setup payload into jitted segment functions (worker side).

    Each function's body is the same accumulation + epilogue the
    single-process executors trace, restricted to this worker's geometry.

    ``cache`` (an ``OrderedDict`` the caller keeps across setups, LRU up to
    ``SEGMENT_CACHE_CAP``) enables warm recompilation across replans: a spec
    whose content ``fingerprint`` matches a cached entry reuses the already
    jitted (and warmed) function instead of re-tracing — geometry that did
    not change never recompiles.  ``stats`` (a dict, filled in place) gets
    ``cache_hits`` / ``cache_misses`` counters for the coordinator's
    hit-rate accounting.
    """
    import jax
    import jax.numpy as jnp

    int8 = _check_precision(meta["precision"])
    out: dict[int, CompiledSegment] = {}
    hits = misses = 0
    for spec in meta["segments"]:
        if spec["kind"] == "skip":
            continue
        gi = spec["gi"]
        fp = spec.get("fingerprint")
        if cache is not None and fp is not None and fp in cache:
            cache.move_to_end(fp)
            out[gi] = dataclasses.replace(cache[fp], gi=gi)
            hits += 1
            continue
        misses += 1
        if spec["kind"] == "spatial":
            stages = spec["stages"]

            def make_spatial(gi=gi, stages=stages):
                consts = []
                for li, st in enumerate(stages):
                    if st.get("empty"):
                        consts.append(None)
                        continue
                    consts.append((jnp.asarray(arrays[f"w{gi}_{li}"]),
                                   jnp.asarray(arrays[f"b{gi}_{li}"]),
                                   jnp.asarray(arrays[f"s{gi}_{li}"])
                                   if int8 else None))

                def fn(band):
                    for li, st in enumerate(stages):
                        if st.get("empty"):
                            dt = jnp.int8 if int8 else jnp.float32
                            band = jnp.zeros((st["out_channels"], 0,
                                              st["out_width"]), dt)
                            continue
                        w, b, s = consts[li]
                        x = jnp.pad(band, ((0, 0),
                                           (st["pad_top"], st["pad_bot"]),
                                           (st["pw"], st["pw"])))
                        acc = _conv_chw(x, w, tuple(st["stride"]), int8)
                        acc = acc + b[:, None, None]
                        if int8:
                            band = requantize(acc, s[:, None, None],
                                              st["out_scale"],
                                              st["activation"])
                        else:
                            band = apply_activation(acc, st["activation"])
                    return band
                return fn

            body = make_spatial()
        elif spec["kind"] == "conv":
            def make_conv(gi=gi, spec=spec):
                w = jnp.asarray(arrays[f"w{gi}"])
                b = jnp.asarray(arrays[f"b{gi}"])
                s = jnp.asarray(arrays[f"s{gi}"]) if int8 else None
                stride = tuple(spec["stride"])
                o, n = spec["bbox_start"], spec["n_positions"]

                def fn(x):
                    acc = _conv_chw(x, w, stride, int8)
                    acc = acc + b[:, None, None]
                    flat = acc.reshape(-1)[o:o + n]
                    if int8:
                        return requantize(flat, s, spec["out_scale"],
                                          spec["activation"])
                    return apply_activation(flat, spec["activation"])
                return fn

            body = make_conv()
        elif spec["kind"] == "linear":
            def make_linear(gi=gi, spec=spec):
                w = jnp.asarray(arrays[f"w{gi}"])
                b = jnp.asarray(arrays[f"b{gi}"])
                s = jnp.asarray(arrays[f"s{gi}"]) if int8 else None

                def fn(x):
                    xv = x.reshape(-1)
                    if int8:
                        acc = xv.astype(jnp.int32) @ w.astype(jnp.int32) + b
                        return requantize(acc, s, spec["out_scale"],
                                          spec["activation"])
                    acc = xv.astype(jnp.float32) @ w + b
                    return apply_activation(acc, spec["activation"])
                return fn

            body = make_linear()
            spec = dict(spec, in_shape=[spec["in_len"]])
        else:
            raise ValueError(f"unknown segment kind {spec['kind']!r}")
        out[gi] = CompiledSegment(gi=gi, layer_first=spec["layer_first"],
                                  input_shape=tuple(spec["in_shape"]),
                                  fn=jax.jit(body))
        if cache is not None and fp is not None:
            cache[fp] = out[gi]
            while len(cache) > SEGMENT_CACHE_CAP:
                cache.popitem(last=False)
    if stats is not None:
        stats["cache_hits"] = hits
        stats["cache_misses"] = misses
    return out


def warmup_segments(segments: dict[int, CompiledSegment],
                    precision: str) -> float:
    """Compile every segment function ahead of serving; returns seconds."""
    dtype = np.int8 if precision == "int8" else np.float32
    t0 = time.monotonic()
    for seg in segments.values():
        seg.warmup(dtype)
    return time.monotonic() - t0


# ---------------------------------------------------------------------------
# Coordinator routing plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GroupPlan:
    """Routing/bookkeeping for one block group on the coordinator."""

    gi: int
    idxs: tuple[int, ...]
    kind: str                       # "spatial" | "flat" | "local"
    layer_first: int
    in_shape: tuple[int, ...]       # first layer's input shape
    out_shape: tuple[int, ...]
    actives: tuple[int, ...]        # workers with nonempty shards
    downloads: dict[int, dict]      # worker -> slice spec
    assembly: dict[int, dict]       # worker -> placement spec
    residual_from: str | None = None
    save_as: str | None = None
    out_scale: float | None = None  # last layer's activation scale (int8)
    local: tuple | None = None      # ("avgpool", in_scale, out_scale)
    # boundary (gi-1 -> gi) structure: ``deps[w]`` is the simulator's
    # predicted producer set for consumer worker w (``_boundary_deps``
    # evaluated at this seam; None for the input boundary gi == 0).  When
    # ``clean`` the coordinator's per-worker feed awaits exactly those
    # producers' band events; otherwise it barriers on the previous group's
    # completion — which happens-after every producer, so each predicted
    # edge is realized either way (the fine-grained path just waits on less).
    deps: list[list[int]] | None = None
    clean: bool = False


@dataclasses.dataclass
class CoordinatorPlan:
    precision: str
    groups: list[GroupPlan]
    input_scale: float | None = None


def build_coordinator_plan(split: SplitPlan, qmodel: QuantizedModel | None,
                           precision: str) -> CoordinatorPlan:
    int8 = _check_precision(precision)
    if int8 and qmodel is None:
        raise ValueError("precision='int8' requires a QuantizedModel")
    model = split.model
    groups: list[GroupPlan] = []
    segs = _segments(split)
    assert list(segs) == list(split.block_groups), \
        "simulator segments must coincide with executor block groups"
    all_deps = pipelined_dependencies(split)
    modes = split.group_modes
    for gi, idxs in enumerate(split.block_groups):
        sp0 = split.splits[idxs[0]]
        last = model.layers[idxs[-1]]
        first = model.layers[idxs[0]]
        out_scale = float(qmodel.layers[idxs[-1]].out_scale) if int8 else None
        downloads: dict[int, dict] = {}
        assembly: dict[int, dict] = {}
        local = None
        if sp0.mode == "spatial":
            kind = "spatial"
            geoms_first = spatial_band_geometry(first, sp0)
            sp_last = split.splits[idxs[-1]]
            geoms_last = spatial_band_geometry(last, sp_last)
            actives = tuple(w for w in range(split.n_workers)
                            if geoms_last[w] is not None)
            for w in actives:
                g0 = geoms_first[w]
                lo, hi = (g0.in_lo, g0.in_hi) if g0 is not None else (0, 0)
                downloads[w] = {"kind": "rows", "lo": lo, "hi": hi}
                gl = geoms_last[w]
                assembly[w] = {"kind": "rows", "lo": gl.row_lo,
                               "hi": gl.row_hi}
        elif last.kind == "avgpool":
            kind = "local"
            actives = ()
            if int8:
                ql = qmodel.layers[idxs[-1]]
                local = ("avgpool", float(ql.in_scale), float(ql.out_scale))
            else:
                local = ("avgpool", None, None)
        else:
            kind = "flat"
            actives = tuple(s.worker for s in sp0.shards if s.n_positions)
            geom = (compile_shard_geometry(first, sp0)
                    if first.kind in ("conv", "dwconv") else None)
            for w in actives:
                shard = sp0.shard_of(w)
                if first.kind == "linear":
                    downloads[w] = {"kind": "full"}
                else:
                    g = geom[w]
                    downloads[w] = {
                        "kind": "conv", "r0": g.in_r0, "r1": g.in_r1,
                        "ph": first.padding[0], "pw": first.padding[1],
                        "c_lo": (g.c_lo if first.kind == "dwconv" else None),
                        "c_hi1": (g.c_hi + 1 if first.kind == "dwconv"
                                  else None)}
                assembly[w] = {"kind": "flat", "start": shard.start,
                               "stop": shard.stop}
        # boundary structure gi-1 -> gi
        deps = None
        clean = False
        if gi > 0:
            prev_last = model.layers[split.block_groups[gi - 1][-1]]
            deps = all_deps[gi - 1]
            clean = (modes[gi - 1] == "spatial" and kind == "spatial"
                     and prev_last.residual_from is None
                     and prev_last.save_as is None)
        groups.append(GroupPlan(
            gi=gi, idxs=tuple(idxs), kind=kind, layer_first=idxs[0],
            in_shape=tuple(first.in_shape), out_shape=tuple(last.out_shape),
            actives=actives, downloads=downloads, assembly=assembly,
            residual_from=last.residual_from, save_as=last.save_as,
            out_scale=out_scale, local=local, deps=deps, clean=clean))
    return CoordinatorPlan(
        precision=precision, groups=groups,
        input_scale=float(qmodel.input_scale) if int8 else None)


def worker_geometry_summary(split: SplitPlan) -> list[dict]:
    """JSON-serializable per-worker geometry: what each worker holds and
    computes, per block group — the serialized form ``Plan.worker_geometry``
    exposes and the distributed example reports."""
    model = split.model
    out: list[dict] = []
    for w in range(split.n_workers):
        segs: list[dict] = []
        for gi, idxs in enumerate(split.block_groups):
            sp0 = split.splits[idxs[0]]
            if sp0.mode == "spatial":
                sp_last = split.splits[idxs[-1]]
                g = spatial_band_geometry(model.layers[idxs[-1]], sp_last)[w]
                if g is None:
                    continue
                segs.append({"segment": gi, "mode": "spatial",
                             "layers": list(idxs),
                             "rows": [g.row_lo, g.row_hi]})
            else:
                shard = sp0.shard_of(w)
                if not shard.n_positions:
                    continue
                segs.append({"segment": gi, "mode": sp0.mode,
                             "layers": list(idxs),
                             "flat_range": [shard.start, shard.stop]})
        out.append({"worker": w,
                    "weight_bytes": int(split.worker_weight_bytes(w)),
                    "segments": segs})
    return out
