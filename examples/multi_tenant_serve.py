"""Multi-tenant serving driver: one ``repro.serve.Server`` hosting two
split-CNN tenants (the same MobileNetV2 family at two input resolutions),
driven by the open-loop Poisson load generator.

The serving subsystem stacks three pieces on top of the ``Session`` facade:

* **continuous batching** — a scheduler thread drains the per-tenant queues
  into bucket-padded micro-batches through in-flight dispatch slots; no
  client ever calls ``flush()``;
* **admission control** — per-tenant :class:`~repro.serve.SLO`; overload is
  shed with a typed ``Overloaded`` response instead of queueing requests
  into a tail that cannot meet its target;
* **QoS monitoring** — rolling per-tenant p50/p99/throughput and
  accept/reject counters (``server.stats()``).

The driver verifies the serving invariants end to end and exits non-zero if
any fails: bit-exactness vs the plain ``Session`` path, zero dispatch
failures under steady Poisson load, typed shedding under 2x overload with
the accepted population's p99 staying bounded near the SLO target.

Run:  PYTHONPATH=src python examples/multi_tenant_serve.py [--input-hw 56]
      (--smoke: reduced models + shorter drive — the CI examples job)
"""
import argparse

import numpy as np

from repro.api import Session
from repro.core import split_model
from repro.models import mobilenet_v2, mobilenet_v2_smoke
from repro.serve import SLO, Server, run_open_loop, saturation_throughput

# 4 simulated MCUs with heterogeneous compute ratings (relative speed)
RATINGS = (3.0, 1.0, 2.0, 0.5)
P99_TARGET_S = 0.25             # tenant B's SLO under the overload phase
P99_BOUND_S = 4 * P99_TARGET_S  # accepted-tail bound the driver enforces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--input-hw", type=int, default=56,
                    help="tenant A input resolution (56 keeps CPU latency "
                         "low; the paper uses 112)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="steady-phase Poisson drive duration (s)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced models + shorter drive (CI examples job)")
    args = ap.parse_args()
    if args.smoke:
        args.duration = min(args.duration, 1.5)

    rng = np.random.default_rng(0)
    print("== two tenants: one model family, two resolutions ==")
    if args.smoke:
        model_a = mobilenet_v2_smoke()
        model_b = mobilenet_v2(input_hw=(24, 24), width_mult=0.25,
                               num_classes=10,
                               cfg=[(1, 8, 1, 1), (6, 16, 2, 2),
                                    (6, 24, 2, 2)])
    else:
        model_a = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
        model_b = mobilenet_v2_smoke()
    plan_a = split_model(model_a, np.asarray(RATINGS), mode="neuron")
    plan_b = split_model(model_b, np.asarray(RATINGS), mode="neuron")
    for name, m in (("A", model_a), ("B", model_b)):
        print(f"tenant {name}: input {m.input_shape}, "
              f"{m.total_macs() / 1e6:.0f}M MACs, "
              f"split across {len(RATINGS)} MCUs (neuron mode)")

    # the reference Session shares tenant A's shard geometry: warming it
    # first means the tenant warmup below hits the cross-instance
    # executable cache instead of re-tracing
    base = Session(plan_a, precision="int8", max_batch=8)
    base.warmup()
    hits0 = Server.cache_stats()["hits"]

    print("\n== host both tenants on one continuous-batching server ==")
    server = Server(max_inflight=2)
    server.add_tenant("a", plan_a, precision="int8", max_batch=8,
                      slo=SLO(p99_target_s=None, queue_cap=1024))
    server.add_tenant("b", plan_b, precision="int8", max_batch=8,
                      slo=SLO(p99_target_s=P99_TARGET_S, queue_cap=1024))
    hits = Server.cache_stats()["hits"] - hits0
    print("tenant A SLO: queue_cap=1024 (no latency target)")
    print(f"tenant B SLO: p99<={P99_TARGET_S * 1e3:.0f}ms, queue_cap=1024")
    print(f"executable-cache hits while warming tenants: {hits} "
          f"(tenant A shares the reference session's compiled buckets)")

    failures: list[str] = []
    with server:
        print("\n== bit-exactness: server path vs Session.run ==")
        probes = [rng.standard_normal(model_a.input_shape).astype(np.float32)
                  for _ in range(4)]
        bitexact = all(np.array_equal(server.run("a", p, timeout=120.0),
                                      base.run(p)) for p in probes)
        print(f"4 probe requests through the running scheduler: "
              f"bit-exact vs Session.run = {bitexact}")
        if not bitexact:
            failures.append("server output diverged from Session.run")

        print("\n== per-tenant saturation (closed-burst ceiling) ==")
        n_burst = 64 if args.smoke else 96
        sat_a = saturation_throughput(server, "a", lambda: probes[0],
                                      n_requests=n_burst)
        xb = rng.standard_normal(model_b.input_shape).astype(np.float32)
        sat_b = saturation_throughput(server, "b", lambda: xb,
                                      n_requests=n_burst)
        print(f"tenant A: {sat_a:.0f} req/s   tenant B: {sat_b:.0f} req/s")

        print("\n== steady state: open-loop Poisson at 0.4x saturation ==")
        steady = run_open_loop(
            server, {"a": 0.4 * sat_a, "b": 0.4 * sat_b},
            {"a": lambda: probes[0], "b": lambda: xb},
            duration_s=args.duration, seed=1)
        for name in ("a", "b"):
            r = steady[name]
            print(f"  {r.describe()}")
            if r.completed == 0:
                failures.append(f"steady phase: tenant {name} completed "
                                f"nothing")
            if r.failed:
                failures.append(f"steady phase: tenant {name} had "
                                f"{r.failed} failed tickets")

        print("\n== overload: tenant B at 2x saturation, SLO defended ==")
        over = run_open_loop(server, {"b": 2.0 * sat_b}, {"b": lambda: xb},
                             duration_s=args.duration, seed=2)["b"]
        print(f"  offered {over.offered_rps:.0f} req/s: "
              f"shed {over.rejection_rate:.1%} (typed Overloaded), "
              f"accepted p99={over.p99_s * 1e3:.1f}ms "
              f"(target {P99_TARGET_S * 1e3:.0f}ms, "
              f"bound {P99_BOUND_S * 1e3:.0f}ms)")
        if not over.rejection_rate > 0:
            failures.append("overload phase shed nothing — admission "
                            "control did not engage")
        if not over.p99_s <= P99_BOUND_S:
            failures.append(f"accepted p99 {over.p99_s:.3f}s blew through "
                            f"the {P99_BOUND_S}s bound — queueing unbounded")

        print("\n== per-tenant QoS snapshots ==")
        for name, qos in server.stats().items():
            print(f"  {qos.describe()}")

    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    print("\nall serving invariants hold")


if __name__ == "__main__":
    main()
