"""Elastic heterogeneous cluster demo: rating-based allocation (paper §V)
plus the beyond-paper elastic runtime — a worker dies mid-service, a second
straggles, and the coordinator re-plans with Eq. 7 while keeping every
surviving worker inside its memory budget.

Run:  PYTHONPATH=src python examples/heterogeneous_cluster.py
"""
import numpy as np

from repro.core import SimConfig, WorkerParams, peak_ram_per_worker, simulate
from repro.models import mobilenet_v2_smoke
from repro.runtime.elastic import ElasticCluster


def show(cluster, tag):
    plan = cluster.plan
    peaks = peak_ram_per_worker(plan)
    macs = [plan.worker_macs(w) / 1e3 for w in range(plan.n_workers)]
    print(f"{tag}: workers={cluster.alive_indices} "
          f"share(kMACs)={np.round(macs).astype(int).tolist()} "
          f"peakRAM(KB)={np.round(peaks/1024, 1).tolist()}")


def main():
    model = mobilenet_v2_smoke()
    workers = [WorkerParams(f_mhz=600, flash_bytes=64 << 10),
               WorkerParams(f_mhz=600, flash_bytes=24 << 10),   # small flash
               WorkerParams(f_mhz=450, flash_bytes=64 << 10),
               WorkerParams(f_mhz=150, flash_bytes=64 << 10)]
    cluster = ElasticCluster(model, workers, k1=0.133, kc=2.5,
                             heartbeat_timeout=0.5)
    show(cluster, "initial plan   ")
    print("  (worker 1's small flash forced Eq. 7 overflow redistribution)")

    # steady state: heartbeats + step times flow in
    for w in cluster.alive_indices:
        cluster.heartbeat(w)
        cluster.report_step_time(w, 1.0)

    # worker 3 starts straggling (thermal throttle, contention, ...)
    for _ in range(3):
        cluster.report_step_time(3, 4.0)
    if cluster.check():
        show(cluster, "post-straggler ")

    # worker 2 dies (no heartbeat)
    cluster.mark_failed(2)
    cluster.check()
    show(cluster, "post-failure   ")

    alive = [cluster.health[i].params for i in cluster.alive_indices]
    res = simulate(model, alive, cluster.plan.ratings, plan=cluster.plan)
    print(f"re-planned inference latency: {res.total_time*1e3:.1f} ms")
    piped = simulate(model, alive, cluster.plan.ratings, plan=cluster.plan,
                     cfg=SimConfig(transport="pipelined"))
    print(f"with pipelined transport:     {piped.total_time*1e3:.1f} ms "
          f"(overlap saves {piped.overlap_saved_s*1e3:.1f} ms; mean link "
          f"utilization {piped.timeline.link_utilization.mean():.0%})")


if __name__ == "__main__":
    main()
