"""Elastic heterogeneous cluster demo: rating-based allocation (paper §V)
plus the beyond-paper elastic runtime — a worker dies mid-service, a second
straggles, and the cluster re-plans with the full Planner search (mode x
fusion x subset x transport, Eq. 7 overflow redistribution inside) while
keeping every surviving worker inside its memory budget.

Run:  PYTHONPATH=src python examples/heterogeneous_cluster.py
"""
import numpy as np

from repro.core import WorkerParams
from repro.models import mobilenet_v2_smoke
from repro.runtime.elastic import ElasticCluster


def show(cluster, tag):
    plan = cluster.plan
    macs = [plan.split.worker_macs(slot) / 1e3
            for slot in range(plan.n_workers)]
    print(f"{tag}: alive={cluster.alive_indices} "
          f"serving={list(cluster.plan_worker_ids)} "
          f"mode={plan.mode}/{plan.transport} "
          f"share(kMACs)={np.round(macs).astype(int).tolist()} "
          f"peakRAM(KB)={np.round(plan.peak_ram / 1024, 1).tolist()}")


def main():
    model = mobilenet_v2_smoke()
    workers = [WorkerParams(f_mhz=600, flash_bytes=64 << 10),
               WorkerParams(f_mhz=600, flash_bytes=24 << 10),   # small flash
               WorkerParams(f_mhz=450, flash_bytes=64 << 10),
               WorkerParams(f_mhz=150, flash_bytes=64 << 10)]
    cluster = ElasticCluster(model, workers, heartbeat_timeout=0.5)
    show(cluster, "initial plan   ")
    print("  (worker 1's small flash caps its share; the planner's Eq. 7 "
          "redistribution keeps every shard inside flash)")

    # steady state: heartbeats + step times flow in
    for w in cluster.alive_indices:
        cluster.heartbeat(w)
        cluster.report_step_time(w, 1.0)

    # worker 3 starts straggling (thermal throttle, contention, ...)
    for _ in range(3):
        cluster.report_step_time(3, 4.0)
    if cluster.check():
        show(cluster, "post-straggler ")
        print(f"  worker 3 demoted to {cluster.health[3].params.f_mhz:.0f} "
              f"MHz (floored at {cluster.demotion_floor:.0%} of original)")

    # worker 2 dies (no heartbeat); the rest keep heartbeating
    cluster.mark_failed(2)
    for w in cluster.alive_indices:
        cluster.heartbeat(w)
    cluster.check()
    show(cluster, "post-failure   ")

    print(f"re-planned inference latency: "
          f"{cluster.plan.latency_s * 1e3:.1f} ms "
          f"(simulated, transport={cluster.plan.transport})")

    # worker 2 comes back with a fresh process: original rating restored
    cluster.rejoin(2)
    for w in cluster.alive_indices:
        cluster.heartbeat(w)
    cluster.check()
    show(cluster, "post-rejoin    ")


if __name__ == "__main__":
    main()
