"""End-to-end serving driver (the paper's deployment): serve int8 MobileNetV2
classification over batched requests across 8 simulated heterogeneous MCUs,
with rating-based allocation and per-request latency/memory accounting.

Run:  PYTHONPATH=src python examples/split_mobilenetv2_serve.py [--requests 12]
"""
import argparse
import time

import numpy as np

from repro.core import (SplitExecutor, WorkerParams, calibrate_scales,
                        measured_kc, peak_ram_per_worker, quantize_model,
                        ratings_for, reference_forward, simulate,
                        simulated_k1, single_device_peak, split_model)
from repro.models import mobilenet_v2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--input-hw", type=int, default=56,
                    help="input resolution (56 keeps CPU latency low; the "
                         "paper uses 112)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    print("== offline preprocessing (Fig. 2) ==")
    model = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
    print(f"MobileNetV2@{args.input_hw}: {len(model.layers)} layers, "
          f"{model.total_macs()/1e6:.0f}M MACs")
    print(f"single-MCU peak RAM {single_device_peak(model)/1024:.0f} KB "
          f"(budget 512 KB) -> infeasible on one MCU")

    calib = [rng.standard_normal((3, args.input_hw, args.input_hw))
             .astype(np.float32) for _ in range(4)]
    scales = calibrate_scales(
        model, calib,
        lambda m, x: reference_forward(m, x, collect_activations=True)[1])
    qm = quantize_model(model, scales)

    print("\n== deployment initialization (8 heterogeneous MCUs) ==")
    freqs = [600, 600, 528, 450, 450, 396, 150, 150]
    delays = [0, 0.001, 0, 0.002, 0, 0.004, 0.001, 0]
    workers = [WorkerParams(f_mhz=f, d_s_per_kb=d)
               for f, d in zip(freqs, delays)]
    k1 = simulated_k1(model, 600)
    kc = measured_kc(model, 8)
    ratings = ratings_for(workers, k1, kc)
    plan = split_model(model, ratings)
    peaks = peak_ram_per_worker(plan)
    print(f"ratings: {np.round(ratings, 1)}")
    print(f"per-MCU peak RAM: {np.round(peaks/1024,1)} KB (all < 512)")

    sim = simulate(model, workers, ratings)
    print(f"modeled on-testbed latency/request: {sim.total_time:.2f} s "
          f"(comp {sim.comp_time:.2f} / comm {sim.comm_time:.2f})")

    print("\n== split inference execution (batched requests) ==")
    ex = SplitExecutor(plan, qm)
    lat = []
    agree = 0
    for i in range(args.requests):
        x = rng.standard_normal((3, args.input_hw, args.input_hw)).astype(np.float32)
        t0 = time.perf_counter()
        logits_q = ex.run(x, mode="int8")
        lat.append(time.perf_counter() - t0)
        pred_q = int(np.argmax(logits_q))
        pred_f = int(np.argmax(reference_forward(model, x)))
        agree += pred_q == pred_f
        print(f"request {i}: class={pred_q} "
              f"(float model: {pred_f}) {lat[-1]*1e3:.0f} ms host-side")
    print(f"\nint8-split vs float-monolithic top-1 agreement: "
          f"{agree}/{args.requests}")
    print(f"host-side execution latency p50={np.median(lat)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
