"""End-to-end serving driver (the paper's deployment): serve int8 MobileNetV2
classification over batched requests across 8 simulated heterogeneous MCUs,
with rating-based allocation and per-request latency/memory accounting.

Requests are served by the CompiledSplitExecutor: the whole SplitPlan is
jitted once per (mode, batch shape) and ``run_batch`` executes a batch in a
single fused dispatch, so compilation is amortized across all traffic.  The
eager SplitExecutor runs one reference request to demonstrate the bit-exact
int8 parity between the two engines.

Run:  PYTHONPATH=src python examples/split_mobilenetv2_serve.py [--requests 12]
"""
import argparse
import time

import numpy as np

from repro.core import (CompiledSplitExecutor, SplitExecutor, WorkerParams,
                        calibrate_scales, compare_modes, measured_kc,
                        peak_ram_per_worker, quantize_model, ratings_for,
                        reference_forward, simulate, simulated_k1,
                        single_device_peak, split_model)
from repro.models import mobilenet_v2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--input-hw", type=int, default=56,
                    help="input resolution (56 keeps CPU latency low; the "
                         "paper uses 112)")
    ap.add_argument("--mode", choices=("neuron", "kernel", "spatial"),
                    default="neuron",
                    help="partitioning mode: channel/neuron flat ranges "
                         "(paper Alg. 1/2) or spatial bands + fused blocks "
                         "(MCUNetV2-style patches)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    print("== offline preprocessing (Fig. 2) ==")
    model = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
    print(f"MobileNetV2@{args.input_hw}: {len(model.layers)} layers, "
          f"{model.total_macs()/1e6:.0f}M MACs")
    print(f"single-MCU peak RAM {single_device_peak(model)/1024:.0f} KB "
          f"(budget 512 KB) -> infeasible on one MCU")

    calib = [rng.standard_normal((3, args.input_hw, args.input_hw))
             .astype(np.float32) for _ in range(4)]
    scales = calibrate_scales(
        model, calib,
        lambda m, x: reference_forward(m, x, collect_activations=True)[1])
    qm = quantize_model(model, scales)

    print("\n== deployment initialization (8 heterogeneous MCUs) ==")
    freqs = [600, 600, 528, 450, 450, 396, 150, 150]
    delays = [0, 0.001, 0, 0.002, 0, 0.004, 0.001, 0]
    workers = [WorkerParams(f_mhz=f, d_s_per_kb=d)
               for f, d in zip(freqs, delays)]
    k1 = simulated_k1(model, 600)
    kc = measured_kc(model, 8)
    ratings = ratings_for(workers, k1, kc)
    plan = split_model(model, ratings, mode=args.mode)
    peaks = peak_ram_per_worker(plan)
    print(f"partitioning mode: {args.mode}")
    print(f"ratings: {np.round(ratings, 1)}")
    print(f"per-MCU peak RAM: {np.round(peaks/1024,1)} KB (all < 512)")

    sim = simulate(model, workers, ratings, plan=plan)
    print(f"modeled on-testbed latency/request: {sim.total_time:.2f} s "
          f"(comp {sim.comp_time:.2f} / comm {sim.comm_time:.2f})")

    print("\n== partitioning-mode tradeoff (simulator) ==")
    for mode, rep in compare_modes(model, workers, ratings).items():
        print(f"  {mode:8s} total={rep.total_time_s:6.2f}s "
              f"comm={rep.comm_time_s:6.2f}s "
              f"bytes={rep.total_bytes/1e6:5.2f}MB "
              f"peak={rep.max_peak_ram/1024:4.0f}KB "
              f"weights={rep.max_weight_bytes/1024:5.0f}KB")

    print("\n== compile the split plan (one jit per mode/batch) ==")
    engine = CompiledSplitExecutor(plan, qm)
    shape = (3, args.input_hw, args.input_hw)
    t0 = time.perf_counter()
    engine.warmup(shape, batch=args.requests, mode="int8")
    print(f"compiled int8 batch-{args.requests} plan in "
          f"{time.perf_counter()-t0:.1f} s (amortized over all traffic)")

    print("\n== split inference execution (batched requests) ==")
    xs = np.stack([rng.standard_normal(shape).astype(np.float32)
                   for _ in range(args.requests)])
    t0 = time.perf_counter()
    logits_q = engine.run_batch(xs, mode="int8")
    batch_s = time.perf_counter() - t0
    preds_q = np.argmax(logits_q.reshape(args.requests, -1), axis=1)
    agree = 0
    for i in range(args.requests):
        pred_f = int(np.argmax(reference_forward(model, xs[i])))
        agree += int(preds_q[i]) == pred_f
        print(f"request {i}: class={int(preds_q[i])} (float model: {pred_f})")
    print(f"\nint8-split vs float-monolithic top-1 agreement: "
          f"{agree}/{args.requests}")
    print(f"host-side batch latency {batch_s*1e3:.0f} ms "
          f"({batch_s/args.requests*1e3:.1f} ms/request amortized)")

    # one eager reference request: the compiled engine must agree bit-for-bit
    eager = SplitExecutor(plan, qm)
    t0 = time.perf_counter()
    eager_q = eager.run(xs[0], mode="int8")
    eager_s = time.perf_counter() - t0
    exact = np.array_equal(eager_q, logits_q[0])
    print(f"eager reference request: {eager_s*1e3:.0f} ms, "
          f"bit-exact vs compiled: {exact}")


if __name__ == "__main__":
    main()
