"""End-to-end serving driver (the paper's deployment) on the coordinator
facade: serve int8 MobileNetV2 classification over micro-batched requests
across 8 simulated heterogeneous MCUs.

The coordinator is ``repro.api``: ``Cluster`` holds the measured workers,
``Planner.plan`` searches partitioning mode x fusion x worker subsets under
the 512 KB RAM budget with the paper's analytic cost models, and
``plan.compile`` returns a ``Session`` that serves requests through the
jitted ``CompiledSplitExecutor`` with bucket-padded micro-batching — each
(precision, bucket) pair compiles once and is amortized over all traffic.
One eager reference request demonstrates the bit-exact int8 parity between
the serving engine and the step-for-step MCU protocol oracle.

Run:  PYTHONPATH=src python examples/split_mobilenetv2_serve.py [--requests 8]
      (--smoke: reduced model + 4 requests — the CI examples job)
"""
import argparse
import time

import numpy as np

from repro.api import Cluster, Objective, Planner, SEARCH_MODES
from repro.core import SplitExecutor, reference_forward, single_device_peak
from repro.models import mobilenet_v2, mobilenet_v2_smoke


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--input-hw", type=int, default=56,
                    help="input resolution (56 keeps CPU latency low; the "
                         "paper uses 112)")
    ap.add_argument("--mode",
                    choices=("auto", "neuron", "kernel", "spatial", "mixed"),
                    default="auto",
                    help="partitioning mode: 'auto' lets the planner search "
                         "all axes including the DP per-block 'mixed' "
                         "assignment; a named mode pins the search")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke model + 4 requests (CI examples job)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 4)

    rng = np.random.default_rng(0)
    print("== offline preprocessing (Fig. 2) ==")
    if args.smoke:
        model = mobilenet_v2_smoke()
        print(f"MobileNetV2-smoke: {len(model.layers)} layers, "
              f"{model.total_macs() / 1e6:.0f}M MACs")
    else:
        model = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
        print(f"MobileNetV2@{args.input_hw}: {len(model.layers)} layers, "
              f"{model.total_macs() / 1e6:.0f}M MACs")
    single = single_device_peak(model)
    verdict = ("-> infeasible on one MCU" if single > 512 * 1024
               else "(smoke config fits; the full model does not)")
    print(f"single-MCU peak RAM {single / 1024:.0f} KB "
          f"(budget 512 KB) {verdict}")

    print("\n== resource-aware planning (8 heterogeneous MCUs) ==")
    cluster = Cluster.heterogeneous_demo(8)
    modes = SEARCH_MODES if args.mode == "auto" else (args.mode,)
    t0 = time.perf_counter()
    plan = Planner(model, cluster).plan(
        Objective(minimize="latency", ram_cap_bytes=512 * 1024, modes=modes))
    print(f"plan search took {time.perf_counter() - t0:.2f} s")
    print(plan.report())

    print("\n== compile the plan into a serving session ==")
    calib = [rng.standard_normal(model.input_shape).astype(np.float32)
             for _ in range(4)]
    session = plan.compile(precision="int8", calibration=calib,
                           max_batch=max(args.requests, 1))
    t0 = time.perf_counter()
    session.warmup(buckets=(1, session.max_batch))
    print(f"compiled int8 buckets (1, {session.max_batch}) in "
          f"{time.perf_counter() - t0:.1f} s (amortized over all traffic)")

    print("\n== split inference serving (micro-batched requests) ==")
    xs = np.stack([rng.standard_normal(model.input_shape).astype(np.float32)
                   for _ in range(args.requests)])
    logits_q = session.submit_many(xs)
    preds_q = np.argmax(logits_q.reshape(args.requests, -1), axis=1)
    agree = 0
    for i in range(args.requests):
        pred_f = int(np.argmax(reference_forward(model, xs[i])))
        agree += int(preds_q[i]) == pred_f
        print(f"request {i}: class={int(preds_q[i])} (float model: {pred_f})")
    stats = session.stats()
    print(f"\nint8-split vs float-monolithic top-1 agreement: "
          f"{agree}/{args.requests}")
    print(f"served {stats.requests} requests in {stats.batches} dispatches "
          f"({stats.padded} padded slots): "
          f"{stats.wall_s * 1e3:.0f} ms total, "
          f"{stats.throughput_rps:.1f} req/s, "
          f"{stats.wall_s / stats.requests * 1e3:.1f} ms/request amortized")
    if stats.transport == "pipelined":
        print(f"planned transport: pipelined (per-link async queues; "
              f"predicted overlap saving "
              f"{stats.predicted_overlap_saved_s * 1e3:.1f} ms/inference "
              f"vs the serial coordinator)")
    else:
        print("planned transport: serial (Eq. 5-6 coordinator)")

    # one eager reference request: the serving engine must agree bit-for-bit
    # with the step-for-step MCU protocol oracle
    eager = SplitExecutor(plan.split, session.qmodel)
    t0 = time.perf_counter()
    eager_q = eager.run(xs[0], mode="int8")
    eager_s = time.perf_counter() - t0
    exact = np.array_equal(eager_q, logits_q[0])
    print(f"eager reference request: {eager_s * 1e3:.0f} ms, "
          f"bit-exact vs session: {exact}")
    if not exact:
        raise SystemExit("FAIL: session output diverged from the eager oracle")


if __name__ == "__main__":
    main()
