"""Quickstart: split a CNN's inference across simulated networked MCUs.

Reproduces the paper's core claim in ~40 lines: a model whose per-layer peak
RAM exceeds a single MCU becomes feasible when split at sub-layer
granularity, and the split execution is numerically identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (SplitExecutor, WorkerParams, peak_ram_per_worker,
                        ratings_for, reference_forward, simulate,
                        single_device_peak, split_model, measured_kc,
                        simulated_k1)
from repro.models import mobilenet_v2_smoke


def main():
    model = mobilenet_v2_smoke()
    print(f"model: {len(model.layers)} layers, "
          f"{model.total_macs()/1e6:.2f}M MACs, "
          f"{model.total_weight_bytes(1)/1024:.0f} KB int8 weights")

    # 1. single-device peak RAM (the bottleneck the paper attacks)
    single = single_device_peak(model)
    print(f"single-MCU peak RAM: {single/1024:.1f} KB")

    # 2. heterogeneous workers -> capability ratings (Eq. 5)
    workers = [WorkerParams(f_mhz=600), WorkerParams(f_mhz=450),
               WorkerParams(f_mhz=150, d_s_per_kb=0.002)]
    k1 = simulated_k1(model, 600)
    ratings = ratings_for(workers, k1, measured_kc(model, len(workers)))
    print(f"capability ratings: {np.round(ratings, 2)}")

    # 3. fine-grained split (Alg. 1/2) + peak RAM per worker
    plan = split_model(model, ratings)
    peaks = peak_ram_per_worker(plan)
    print(f"per-worker peak RAM: {np.round(peaks/1024, 1)} KB "
          f"({single/peaks.max():.1f}x reduction)")

    # 4. split execution == monolithic reference
    x = np.random.default_rng(0).standard_normal((3, 32, 32)).astype(np.float32)
    ref = reference_forward(model, x)
    out = SplitExecutor(plan).run(x)
    print(f"split vs monolithic max|err|: {np.max(np.abs(out-ref)):.2e}")

    # 5. end-to-end latency through the Eq. 1 timing model
    res = simulate(model, workers, ratings)
    print(f"simulated inference: total={res.total_time*1e3:.1f} ms "
          f"(comp {res.comp_time*1e3:.1f} + comm {res.comm_time*1e3:.1f})")


if __name__ == "__main__":
    main()
