"""Quickstart: split a CNN's inference across simulated networked MCUs.

Reproduces the paper's core claim through the coordinator facade in ~5 lines
of API: a model whose per-layer peak RAM exceeds a single MCU becomes
feasible when split at sub-layer granularity, the coordinator picks the
split/placement automatically, and the split execution is numerically
identical to the monolithic reference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import Cluster, Objective, Planner
from repro.core import WorkerParams, reference_forward, single_device_peak
from repro.models import mobilenet_v2_smoke


def main():
    # the whole coordinator pipeline (rating -> splitting -> allocation ->
    # feasibility -> placement) is these five lines:
    model = mobilenet_v2_smoke()
    cluster = Cluster((WorkerParams(f_mhz=600), WorkerParams(f_mhz=450),
                       WorkerParams(f_mhz=150, d_s_per_kb=0.002)))
    plan = Planner(model, cluster).plan(
        Objective(minimize="latency", ram_cap_bytes=512 * 1024))
    session = plan.compile(precision="float")
    out = session.run(x := np.random.default_rng(0)
                      .standard_normal(model.input_shape).astype(np.float32))

    print(f"model: {len(model.layers)} layers, "
          f"{model.total_macs() / 1e6:.2f}M MACs, "
          f"{model.total_weight_bytes(1) / 1024:.0f} KB int8 weights")

    # 1. single-device peak RAM (the bottleneck the paper attacks)
    single = single_device_peak(model)
    print(f"single-MCU peak RAM: {single / 1024:.1f} KB")

    # 2. the plan the coordinator chose (Eq. 5 ratings -> mode/subset search)
    print(f"chosen split: mode={plan.mode}, "
          f"{plan.n_workers}/{cluster.n_workers} workers, "
          f"ratings {np.round(np.asarray(plan.ratings), 2)}")
    print(f"per-worker peak RAM: {np.round(plan.peak_ram / 1024, 1)} KB "
          f"({single / plan.max_peak_ram:.1f}x reduction)")

    # 3. split execution == monolithic reference
    ref = reference_forward(model, x)
    print(f"split vs monolithic max|err|: {np.max(np.abs(out - ref)):.2e}")

    # 4. end-to-end latency through the Eq. 1 timing model; the planner also
    # searched the transport axis (serial coordinator vs per-link pipelining)
    print(f"simulated inference: total={plan.latency_s * 1e3:.1f} ms "
          f"(comp {plan.comp_s * 1e3:.1f} + comm {plan.comm_s * 1e3:.1f})")
    saved = (f", overlap saves {plan.overlap_saved_s * 1e3:.1f} ms vs serial"
             if plan.transport == "pipelined" else "")
    print(f"chosen transport: {plan.transport}{saved}")


if __name__ == "__main__":
    main()
